"""Kernel-level microbench: Pallas ops (interpret mode) vs jnp references.

On CPU, interpret-mode timing is NOT indicative of TPU performance — the
value here is (a) correctness at benchmark scale, (b) the analytic VMEM /
arithmetic-intensity table used in the roofline discussion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.hardware import V5E
from repro.kernels import (sgmv, sgmv_ref, ragged_linear,
                           decode_attn, decode_attn_ref)
from benchmarks.common import emit


def _intensity_rows():
    rows = []
    # sgmv: per 128-token block: 2*bt*din*r + 2*bt*r*dout flops,
    # bytes: x + A + B + y
    bt, din, r, dout = 128, 4096, 16, 4096
    flops = 2 * bt * din * r + 2 * bt * r * dout
    bts = (bt * din + din * r + r * dout + bt * dout) * 2
    rows.append({"kernel": "sgmv", "config": f"bt{bt}_d{din}_r{r}",
                 "flops": flops, "bytes": bts,
                 "intensity": round(flops / bts, 2),
                 "vmem_MB": round((bt * din + din * r + r * dout + bt * dout)
                                  * 2 / 1e6, 2)})
    # ragged_linear tile
    t, k, d = 256, 512, 512
    flops = 2 * t * k * d
    bts = (t * k + k * d + t * d) * 2
    rows.append({"kernel": "ragged_linear", "config": f"t{t}_k{k}_d{d}",
                 "flops": flops, "bytes": bts,
                 "intensity": round(flops / bts, 2),
                 "vmem_MB": round((t * k + k * d + t * d) * 2 / 1e6, 2)})
    # decode_attn block: G x block_kv
    G, bkv, hd = 8, 512, 128
    flops = 2 * G * bkv * hd * 2
    bts = (G * hd + 2 * bkv * hd) * 2
    rows.append({"kernel": "decode_attn", "config": f"G{G}_bkv{bkv}_hd{hd}",
                 "flops": flops, "bytes": bts,
                 "intensity": round(flops / bts, 2),
                 "vmem_MB": round((G * hd + 2 * bkv * hd) * 2 / 1e6, 2)})
    # flash_attn tile: block_q x block_kv (q stays VMEM-resident per row)
    bq, bkv, hd = 256, 512, 128
    flops = 2 * bq * bkv * hd * 2
    bts = (bq * hd + 2 * bkv * hd + bq * hd) * 2
    rows.append({"kernel": "flash_attn", "config": f"bq{bq}_bkv{bkv}_hd{hd}",
                 "flops": flops, "bytes": bts,
                 "intensity": round(flops / bts, 2),
                 "vmem_MB": round((bq * hd * 2 + 2 * bkv * hd) * 2 / 1e6
                                  + bq * (256 + hd) * 4 / 1e6, 2)})
    ridge = V5E.peak_flops_bf16 / V5E.hbm_bandwidth
    rows.append({"kernel": "v5e_ridge_point", "config": "flops/byte",
                 "flops": "-", "bytes": "-", "intensity": round(ridge, 1),
                 "vmem_MB": "-"})
    return rows


def run(quick: bool = False):
    rows = _intensity_rows()
    # correctness spot-checks at bench scale
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 256), jnp.float32)
    A = jax.random.normal(key, (4, 256, 8))
    B = jax.random.normal(key, (4, 8, 256))
    ids = jnp.array([0, 3], jnp.int32)
    err = float(jnp.abs(sgmv(x, A, B, ids) -
                        sgmv_ref(x, A, B, ids, block_t=128)).max())
    rows.append({"kernel": "sgmv", "config": "allclose_err", "flops": "-",
                 "bytes": "-", "intensity": f"{err:.1e}", "vmem_MB": "-"})
    return emit("kernels", rows)


if __name__ == "__main__":
    run()
