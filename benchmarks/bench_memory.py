"""Fig 9/10: memory consumption — memory-optimized backward (§3.6).

No GPU allocator here, so the measured quantity is the VJP residual
footprint (activation memory held for the backward pass) plus state sizes:
  Fig 9: single job, Symbiosis-MO vs non-optimized vs torch-like baseline.
  Fig 10: increasing clients — base-attributable residuals stay ~constant
          with MO; client state grows linearly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import AdapterConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.core.virtlayer import make_client_ctx
from repro.models import get_model
from repro.models.losses import lm_loss
from benchmarks.common import residual_bytes, tree_bytes, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))


def _residuals(cfg, mode, n_clients, seq=256):
    """mode: 'mo' (§3.6 frozen backward), 'no_mo' (plain frozen matmuls —
    JAX partial-eval still avoids saving x for non-differentiated W), or
    'torch_like' (differentiate base params too, grads discarded — forces
    the input-activation residuals torch autograd keeps, the paper's
    baseline)."""
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, n_clients, key)
    ctx = make_client_ctx(cfg, ACFG, memory_optimized=(mode == "mo"))
    batch = {"tokens": jnp.ones((n_clients, 2, seq), jnp.int32),
             "labels": jnp.ones((n_clients, 2, seq), jnp.int32)}

    def loss_adapter_only(bank):
        def one(adapter, b):
            logits, aux = model.forward(base, b, ctx, adapter, remat=False)
            return lm_loss(logits, b["labels"], None, aux)
        return jax.vmap(one, in_axes=(0, 0))(bank, batch).sum()

    def loss_with_base(args):
        bank, base_ = args
        def one(adapter, b):
            logits, aux = model.forward(base_, b, ctx, adapter, remat=False)
            return lm_loss(logits, b["labels"], None, aux)
        return jax.vmap(one, in_axes=(0, 0))(bank, batch).sum()

    if mode == "torch_like":
        res = residual_bytes(loss_with_base, (bank, base))
    else:
        res = residual_bytes(loss_adapter_only, bank)
    return res, tree_bytes(bank), tree_bytes(base)


def run(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    rows = []
    # Fig 9: single fine-tuning job — MO vs torch-like baseline
    res_mo, _, base_b = _residuals(cfg, "mo", 1)
    res_no, _, _ = _residuals(cfg, "no_mo", 1)
    res_torch, _, _ = _residuals(cfg, "torch_like", 1)
    for name, r in (("symbiosis_MO", res_mo), ("no_MO_jax_partial_eval", res_no),
                    ("torch_like_baseline", res_torch)):
        rows.append({"fig": "9", "config": name, "clients": 1,
                     "residual_MB": round(r / 1e6, 2),
                     "base_MB": round(base_b / 1e6, 2)})
    # Fig 10: increasing clients
    for c in (1, 2, 4) if quick else (1, 2, 4, 8):
        res, bank_b, _ = _residuals(cfg, "mo", c)
        rows.append({"fig": "10", "config": "symbiosis_MO", "clients": c,
                     "residual_MB": round(res / 1e6, 2),
                     "client_state_MB": round(bank_b / 1e6, 2)})
    # paper claims: MO cuts residuals vs the torch-like baseline; in JAX,
    # partial evaluation already implies MO when the base is frozen — the
    # custom_vjp makes that guarantee structural (equal footprints).
    rows.append({"fig": "check", "config": "MO_beats_torch_baseline",
                 "clients": "-", "residual_MB": bool(res_mo < res_torch)})
    rows.append({"fig": "check", "config": "jax_partial_eval_equals_MO",
                 "clients": "-",
                 "residual_MB": bool(abs(res_mo - res_no) < 0.1 * res_mo + 1e6)})
    return emit("fig9_10_memory", rows)


if __name__ == "__main__":
    run()
