"""Table 2: fine-tuning iteration latency for LoRA 1-4 adapter configs.

Paper finding (C3): adding fine-tuned layers ([q] -> [q,k,v,o]) costs more
than raising rank (8 -> 64). Reduced Llama2-13B-family model on CPU.
"""
from __future__ import annotations

import jax

from repro.config import AdapterConfig, TrainConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.data import make_client_batches
from benchmarks.common import timeit, emit

LORAS = {
    "LoRA1_r8_q": AdapterConfig(method="lora", rank=8, targets=("q",)),
    "LoRA2_r64_q": AdapterConfig(method="lora", rank=64, targets=("q",)),
    "LoRA3_r8_qkvo": AdapterConfig(method="lora", rank=8,
                                   targets=("q", "k", "v", "o")),
    "LoRA4_r64_qkvo": AdapterConfig(method="lora", rank=64,
                                    targets=("q", "k", "v", "o")),
}


def run(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2 if quick else 4, d_model=256 if quick else 512)
    tcfg = TrainConfig(n_clients=2, remat=False)
    rows = []
    for name, acfg in LORAS.items():
        key = jax.random.PRNGKey(0)
        base, bank, opt = symbiosis.init_system(cfg, acfg, 2, key)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, acfg, tcfg))
        batch = make_client_batches(cfg, 2, 2, 128).batch(0)
        t = timeit(lambda: step(base, bank, opt, batch, 0), reps=3)
        rows.append({"adapter": name, "iter_latency_s": round(t, 4)})
    # the paper's ordering: targets dominate rank
    r = {x["adapter"]: x["iter_latency_s"] for x in rows}
    rows.append({"adapter": "check_targets_cost_more_than_rank",
                 "iter_latency_s":
                 r["LoRA3_r8_qkvo"] >= r["LoRA2_r64_q"] * 0.9})
    return emit("table2_adapter_configs", rows)


if __name__ == "__main__":
    run()
