"""Ablations beyond the paper's tables.

1. wait_fraction sweep (the §3.7 knob the paper says "can be configured by
   the service provider"): latency/throughput/batch-size tradeoff curve.
2. remat on/off: activation-residual vs recompute tradeoff for fine-tuning.
3. token-budget packing utilization: compute saved vs per-client padding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scheduler import simulate
from benchmarks.common import emit, timeit
from benchmarks.bench_batching import _clients, N_LAYERS, EXEC_OVERHEAD_13B, PER_TOKEN_13B


def run(quick: bool = False):
    rows = []
    # 1. wait_fraction sweep
    for wf in (0.0, 0.05, 0.1, 0.25, 0.5, 1.0):
        r = simulate(_clients(), N_LAYERS, "opportunistic",
                     EXEC_OVERHEAD_13B, PER_TOKEN_13B, wait_fraction=wf)
        s = r.summary()
        rows.append({"ablation": "wait_fraction", "x": wf,
                     "latency_s": round(s["mean_latency_s"], 5),
                     "throughput": round(s["throughput_tok_s"]),
                     "avg_batch": round(s["avg_batch"], 2)})

    # 2. remat on/off (residual proxy + step time, reduced model)
    from repro.config import AdapterConfig, TrainConfig
    from repro.configs import get_config
    from repro.core import symbiosis
    cfg = get_config("granite-3-8b").reduced(n_layers=4, d_model=256)
    acfg = AdapterConfig(method="lora", rank=8, targets=("q", "v"))
    base, bank, opt = symbiosis.init_system(cfg, acfg, 2, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 2, 128), jnp.int32),
             "labels": jnp.ones((2, 2, 128), jnp.int32)}
    for remat in (False, True):
        step = jax.jit(symbiosis.make_multi_client_train_step(
            cfg, acfg, TrainConfig(n_clients=2, remat=remat)))
        t = timeit(lambda: step(base, bank, opt, batch, 1), reps=3)
        rows.append({"ablation": "remat", "x": remat,
                     "latency_s": round(t, 4), "throughput": "-",
                     "avg_batch": "-"})

    # 3. packing utilization: ragged clients into one budget vs padded batch
    from repro.core import packing
    import numpy as np
    lens = [37, 5, 122, 64, 9, 80]
    S_max, d = max(lens), 64
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(len(lens), S_max, d)).astype(np.float32))
    budget = sum(lens)
    p = packing.pack(x, jnp.asarray(lens, jnp.int32), budget)
    padded_tokens = len(lens) * S_max
    rows.append({"ablation": "packing", "x": f"{len(lens)}_ragged_clients",
                 "latency_s": "-",
                 "throughput": f"{budget}/{padded_tokens} tokens computed",
                 "avg_batch": round(padded_tokens / budget, 2)})
    return emit("ablations", rows)


if __name__ == "__main__":
    run()
