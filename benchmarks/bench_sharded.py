"""Fig 15/16/17 + §4.2.2: sharded base executor vs FSDP baseline (C5).

The paper's C5: Symbiosis fine-tunes 4x more adapters per GPU-set than FSDP
in the same time, because (a) only adapter grads sync (tiny) while FSDP
all-reduces full gradients, and (b) the §3.6 backward stores no base
activations. We reproduce the collective-traffic side of that argument from
the dry-run HLO: per-step synchronized bytes for Symbiosis multi-client
fine-tuning vs an FSDP-style baseline that differentiates the (sharded)
base. Runs in a subprocess (needs 8 placeholder devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import AdapterConfig, TrainConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.launch import shardings
from repro.launch.hlo_analysis import analyze_module
from repro.launch.mesh import _make_mesh
from repro.models import get_model
from repro.models.losses import lm_loss
from repro.optim import adamw_init

mesh = _make_mesh((4, 2), ("data", "model"))
cfg = get_config("symbiosis-llama2-13b").reduced(n_layers=2, d_model=512)
acfg = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))
C = 4

sys_shape = jax.eval_shape(lambda: symbiosis.init_system(cfg, acfg, C, jax.random.PRNGKey(0)))
base_s, bank_s, opt_s = sys_shape
base = shardings.attach(mesh, base_s, shardings.base_param_specs(cfg, mesh, base_s))
bank = shardings.attach(mesh, bank_s, shardings.client_state_specs(cfg, mesh, bank_s))
opt = shardings.attach(mesh, opt_s, shardings.client_state_specs(cfg, mesh, opt_s))
batch = {
    "tokens": jax.ShapeDtypeStruct((C, 2, 128), jnp.int32,
                                   sharding=NamedSharding(mesh, P("data"))),
    "labels": jax.ShapeDtypeStruct((C, 2, 128), jnp.int32,
                                   sharding=NamedSharding(mesh, P("data"))),
}
step = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))

# --- Symbiosis multi-client step ---
fn = symbiosis.make_multi_client_train_step(cfg, acfg, TrainConfig(n_clients=C, remat=False))
sym = analyze_module(jax.jit(fn).lower(base, bank, opt, batch, step).compile().as_text())

# --- FSDP-style baseline: differentiate through base, all-reduce base grads
model = get_model(cfg)
def fsdp_step(base, adapter, batch):
    def loss(ab):
        a, b = ab
        logits, aux = model.forward(b, batch, adapter=a, remat=False)
        return lm_loss(logits, batch["labels"], None, aux)
    l, (ga, gb) = jax.value_and_grad(loss)((adapter, base))
    # data-parallel grad sync happens implicitly via the batch sharding;
    # returning grads forces their materialization
    return l, ga, gb

one_bank = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype,
                        sharding=NamedSharding(mesh, P(*(s.sharding.spec[1:])))), bank)
fb = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32,
                                     sharding=NamedSharding(mesh, P("data"))),
      "labels": jax.ShapeDtypeStruct((8, 128), jnp.int32,
                                     sharding=NamedSharding(mesh, P("data")))}
fsdp = analyze_module(jax.jit(fsdp_step).lower(base, one_bank, fb).compile().as_text())

print(json.dumps({"symbiosis": sym, "fsdp": fsdp}))
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, env=env, timeout=560)
    if out.returncode != 0:
        return emit("fig15_17_sharded", [
            {"metric": "error", "value": out.stderr.strip()[-400:]}])
    data = json.loads(out.stdout.strip().splitlines()[-1])
    sym, fsdp = data["symbiosis"], data["fsdp"]
    rows = [
        {"metric": "symbiosis_collective_MB_per_step",
         "value": round(sym["coll_bytes"] / 1e6, 2)},
        {"metric": "fsdp_collective_MB_per_step",
         "value": round(fsdp["coll_bytes"] / 1e6, 2)},
        {"metric": "symbiosis_flops_per_dev", "value": f"{sym['flops']:.3e}"},
        {"metric": "fsdp_flops_per_dev", "value": f"{fsdp['flops']:.3e}"},
        {"metric": "collective_reduction_x",
         "value": round(fsdp["coll_bytes"] / max(sym["coll_bytes"], 1), 2)},
        {"metric": "check_C5_symbiosis_syncs_less",
         "value": bool(sym["coll_bytes"] < fsdp["coll_bytes"])},
    ]
    return emit("fig15_17_sharded", rows)


if __name__ == "__main__":
    run()
