"""Fig 11/12: single-GPU multi-client fine-tuning — latency & throughput —
plus the serving-engine continuous-batching comparison (§3.7).

Fine-tuning: baseline = N isolated jobs (N separate step calls, contending
for the one device, each with its own model instance in the paper — here
each pays its own dispatch+compute). Symbiosis = ONE batched multi-client
step. Paper finding (C2): baseline wins at 1-2 clients; Symbiosis wins
beyond.

Serving: the same request workload through (a) the seed-style engine
(bank-wide prefill per admitted request + one request per client at a
time) and (b) the continuous-batching engine (masked single-client
prefill, slot-level admission, mid-stream join/leave). Outputs are
byte-identical (exactness), throughput is not.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.config import AdapterConfig, ServeConfig, TrainConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.data import make_client_batches
from repro.serving.engine import ServingEngine, Request
from benchmarks.common import timeit, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))


def _serving_workload(cfg, n_clients, max_b, n_requests, prompt_len, max_new):
    rng = np.random.default_rng(0)
    return [Request(client_id=i % n_clients,
                    prompt=rng.integers(0, cfg.vocab,
                                        (1, prompt_len)).astype(np.int32),
                    max_new_tokens=max_new,
                    arrive_tick=i)            # staggered arrivals
            for i in range(n_requests)]


def run_serving(quick: bool = False):
    """Continuous batching vs seed-style engine, same workload."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = (2, 2) if quick else (4, 2)
    n_req, prompt_len, max_new = (8, 16, 12) if quick else (16, 32, 16)
    scfg = ServeConfig(n_clients=C, max_seq=prompt_len + max_new + 8)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))

    def measure(**engine_kw):
        eng = ServingEngine(cfg, ACFG, scfg, base, bank,
                            max_batch_per_client=max_b, **engine_kw)
        for r in _serving_workload(cfg, C, max_b, n_req, prompt_len, max_new):
            eng.submit(r)
        eng.run()                              # warm compile caches
        eng2 = ServingEngine(cfg, ACFG, scfg, base, bank,
                             max_batch_per_client=max_b, **engine_kw)
        reqs = _serving_workload(cfg, C, max_b, n_req, prompt_len, max_new)
        for r in reqs:
            eng2.submit(r)
        t0 = time.perf_counter()
        done = eng2.run()
        dt = time.perf_counter() - t0
        toks = sum(r.generated.size for r in done)
        return toks / dt, eng2.stats, done

    seed_tok_s, seed_stats, seed_done = measure(bank_prefill=True,
                                                max_inflight_per_client=1)
    cont_tok_s, cont_stats, cont_done = measure()

    rows = [
        {"engine": "seed_style", "tok_s": round(seed_tok_s),
         "ticks": seed_stats["ticks"], "prefill_tokens": seed_stats["prefill_tokens"]},
        {"engine": "continuous", "tok_s": round(cont_tok_s),
         "ticks": cont_stats["ticks"], "prefill_tokens": cont_stats["prefill_tokens"]},
        {"engine": "speedup", "tok_s": round(cont_tok_s / max(seed_tok_s, 1e-9), 2),
         "ticks": "-", "prefill_tokens": "-"},
    ]
    return emit("sec37_serving_continuous_batching", rows)


def run(quick: bool = False):
    # paper uses Llama3-1B for this comparison; reduced variant here
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    seq, B = (64, 2) if quick else (128, 2)
    rows = []
    clients = (1, 2, 4) if quick else (1, 2, 4, 6, 8)
    for C in clients:
        key = jax.random.PRNGKey(0)
        base, bank, opt = symbiosis.init_system(cfg, ACFG, C, key)
        tcfg = TrainConfig(n_clients=C, remat=False)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, ACFG, tcfg))
        batch = make_client_batches(cfg, C, B, seq).batch(0)

        t_sym = timeit(lambda: step(base, bank, opt, batch, 0), reps=3)

        # baseline: C isolated single-client jobs run back-to-back
        one_step = jax.jit(symbiosis.make_multi_client_train_step(
            cfg, ACFG, TrainConfig(n_clients=1, remat=False)))
        one_bank = jax.tree.map(lambda x: x[:1], bank)
        one_opt = jax.tree.map(lambda x: x[:1], opt)
        one_batch = jax.tree.map(lambda x: x[:1], batch)

        def baseline():
            outs = []
            for _ in range(C):
                outs.append(one_step(base, one_bank, one_opt, one_batch, 0))
            return outs

        t_base = timeit(baseline, reps=3)
        tokens = C * B * seq
        rows.append({
            "clients": C,
            "symbiosis_iter_s": round(t_sym, 4),
            "baseline_iter_s": round(t_base, 4),
            "symbiosis_tok_s": round(tokens / t_sym),
            "baseline_tok_s": round(tokens / t_base),
        })
    # C2: beyond 2 clients Symbiosis should win
    big = [r for r in rows if r["clients"] >= 4]
    rows.append({"clients": "check_C2",
                 "symbiosis_iter_s": all(r["symbiosis_iter_s"] <= r["baseline_iter_s"]
                                         for r in big),
                 "baseline_iter_s": "-", "symbiosis_tok_s": "-",
                 "baseline_tok_s": "-"})
    out = emit("fig11_12_multiclient", rows)
    return out + run_serving(quick)


if __name__ == "__main__":
    run()
