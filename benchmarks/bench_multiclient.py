"""Fig 11/12: single-GPU multi-client fine-tuning — latency & throughput —
plus the serving-engine continuous-batching comparison (§3.7).

Fine-tuning: baseline = N isolated jobs (N separate step calls, contending
for the one device, each with its own model instance in the paper — here
each pays its own dispatch+compute). Symbiosis = ONE batched multi-client
step. Paper finding (C2): baseline wins at 1-2 clients; Symbiosis wins
beyond.

Serving: the same request workload through (a) the seed-style engine
(bank-wide prefill per admitted request + one request per client at a
time) and (b) the continuous-batching engine (masked single-client
prefill, slot-level admission, mid-stream join/leave). Outputs are
byte-identical (exactness), throughput is not.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.config import AdapterConfig, ServeConfig, TrainConfig
from repro.configs import get_config
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.data import make_client_batches
from repro.serving import kvcache
from repro.serving.engine import ServingEngine, Request
from repro.serving.router import PlacementRouter, Slot
from benchmarks.common import timeit, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))

#: the Obs object behind the newest ``serving_latency`` section —
#: ``benchmarks.run`` exports it as BENCH_obs.jsonl / BENCH_obs.prom next
#: to the --json document (docs/observability.md)
LAST_LATENCY_OBS = None


def assert_byte_identical(a_done, b_done, label: str):
    """ONE oracle-diff path for every bench section's exactness claim:
    requests are keyed by (client, prompt bytes) and their generated
    streams must agree byte-for-byte between the two engine runs."""
    key = lambda r: (r.client_id, r.prompt.tobytes())
    a = {key(r): r.generated.tobytes() for r in a_done}
    b = {key(r): r.generated.tobytes() for r in b_done}
    assert set(a) == set(b), f"{label}: request sets differ"
    diverged = [k for k in a if a[k] != b[k]]
    assert not diverged, (
        f"{label}: {len(diverged)} request(s) diverged byte-wise "
        f"(first: client {diverged[0][0]})")


def _serving_workload(cfg, n_clients, max_b, n_requests, prompt_len, max_new):
    rng = np.random.default_rng(0)
    return [Request(client_id=i % n_clients,
                    prompt=rng.integers(0, cfg.vocab,
                                        (1, prompt_len)).astype(np.int32),
                    max_new_tokens=max_new,
                    arrive_tick=i)            # staggered arrivals
            for i in range(n_requests)]


def run_serving(quick: bool = False):
    """Continuous batching vs seed-style engine, same workload."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = (2, 2) if quick else (4, 2)
    n_req, prompt_len, max_new = (8, 16, 12) if quick else (16, 32, 16)
    scfg = ServeConfig(n_clients=C, max_seq=prompt_len + max_new + 8)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))

    def measure(sc=scfg, **engine_kw):
        eng = ServingEngine(cfg, ACFG, sc, base, bank,
                            max_batch_per_client=max_b, **engine_kw)
        for r in _serving_workload(cfg, C, max_b, n_req, prompt_len, max_new):
            eng.submit(r)
        eng.run()                              # warm compile caches
        eng2 = ServingEngine(cfg, ACFG, sc, base, bank,
                             max_batch_per_client=max_b, **engine_kw)
        reqs = _serving_workload(cfg, C, max_b, n_req, prompt_len, max_new)
        for r in reqs:
            eng2.submit(r)
        t0 = time.perf_counter()
        done = eng2.run()
        dt = time.perf_counter() - t0
        toks = sum(r.generated.size for r in done)
        return toks / dt, eng2.stats, done

    seed_tok_s, seed_stats, seed_done = measure(bank_prefill=True,
                                                max_inflight_per_client=1)
    cont_tok_s, cont_stats, cont_done = measure()
    paged_tok_s, paged_stats, paged_done = measure(
        dataclasses.replace(scfg, page_block=16))

    # exactness: the paged layout changes memory management, never outputs
    assert_byte_identical(cont_done, paged_done, "serving: paged vs dense")

    rows = [
        {"engine": "seed_style", "tok_s": round(seed_tok_s),
         "ticks": seed_stats["ticks"], "prefill_tokens": seed_stats["prefill_tokens"]},
        {"engine": "continuous", "tok_s": round(cont_tok_s),
         "ticks": cont_stats["ticks"], "prefill_tokens": cont_stats["prefill_tokens"]},
        {"engine": "continuous_paged", "tok_s": round(paged_tok_s),
         "ticks": paged_stats["ticks"], "prefill_tokens": paged_stats["prefill_tokens"]},
        {"engine": "speedup", "tok_s": round(cont_tok_s / max(seed_tok_s, 1e-9), 2),
         "ticks": "-", "prefill_tokens": "-"},
    ]
    return emit("sec37_serving_continuous_batching", rows)


def run_latency(quick: bool = False):
    """ISSUE 9 acceptance: tail latency under a mixed open-loop load.

    A paged engine with telemetry attached serves a request mix of short
    and long prompts with staggered (open-loop) arrivals; the section rows
    report p50/p99 queue-wait, TTFT, inter-token gap and E2E latency read
    straight from the log-bucketed telemetry histograms
    (docs/observability.md) — the numbers a latency SLO would be written
    against."""
    global LAST_LATENCY_OBS
    from repro.obs import Obs

    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C = 2 if quick else 4
    n_req = 8 if quick else 24
    scfg = ServeConfig(n_clients=C, max_seq=64, page_block=8, pool_pages=64)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))
    obs = Obs()
    eng = ServingEngine(cfg, ACFG, scfg, base, bank,
                        max_batch_per_client=2, obs=obs)
    rng = np.random.default_rng(7)
    for i in range(n_req):
        short = i % 2 == 0
        eng.submit(Request(
            client_id=i % C,
            prompt=rng.integers(1, cfg.vocab,
                                (1, 8 if short else 24)).astype(np.int32),
            max_new_tokens=8 if short else 16,
            arrive_tick=i // 2))               # open-loop staggered arrivals
    done = eng.run()
    assert all(r.status == "ok" for r in done)
    LAST_LATENCY_OBS = obs

    rows = []
    for label, name in (("queue_wait", "serve_queue_wait_seconds"),
                        ("ttft", "serve_ttft_seconds"),
                        ("intertoken", "serve_intertoken_seconds"),
                        ("e2e", "serve_e2e_seconds")):
        h = obs.metrics.merged_histogram(name)
        rows.append({"latency": label,
                     "p50_ms": round(h.percentile(50) * 1e3, 3),
                     "p99_ms": round(h.percentile(99) * 1e3, 3),
                     "n": h.n})
    return emit("serving_latency", rows)


def run_paged_admission(quick: bool = False):
    """ISSUE 2 acceptance: concurrently admitted clients at a FIXED fleet
    HBM budget — dense max_seq-deep slot rows vs paged (16-token pages) +
    int8 KV. The router charges what each layout pins, so the dense engine
    serializes on HBM while the paged engine packs many short requests into
    the same budget."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = (4, 2) if quick else (8, 4)
    prompt_len, max_new = 12, 12
    max_seq = 512 if quick else 1024
    n_req = C * max_b
    scfg_dense = ServeConfig(n_clients=C, max_seq=max_seq)
    scfg_paged = dataclasses.replace(scfg_dense, page_block=16, kv_quant=True)
    # budget fits ~2 (quick) / ~4 dense sessions — the dense ceiling
    dense_row = kvcache.cache_bytes(cfg, max_seq, 1)
    budget = dense_row * (2.5 if quick else 4.5)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))

    def peak_admitted(sc):
        router = PlacementRouter(cfg, [Slot(0, free_hbm=budget)],
                                 host_free_bytes=0)
        eng = ServingEngine(cfg, ACFG, sc, base, bank,
                            max_batch_per_client=max_b, router=router)
        rng = np.random.default_rng(0)
        for i in range(n_req):                 # all due at tick 0
            eng.submit(Request(client_id=i % C,
                               prompt=rng.integers(0, cfg.vocab,
                                                   (1, prompt_len)).astype(np.int32),
                               max_new_tokens=max_new))
        done = eng.run()
        assert len(done) == n_req
        return eng.stats["peak_inflight"]

    # (no oracle diff here: this section runs paged+int8, whose quantized
    # KV is tolerance-close to dense, not byte-identical — the byte
    # identity claims live in the serving/compaction/mixed sections'
    # shared assert_byte_identical path)
    dense_peak = peak_admitted(scfg_dense)
    paged_peak = peak_admitted(scfg_paged)
    ratio = paged_peak / max(dense_peak, 1)
    rows = [
        {"layout": "dense_rows", "peak_admitted": dense_peak,
         "hbm_budget_mb": round(budget / 1e6, 1)},
        {"layout": "paged16_int8", "peak_admitted": paged_peak,
         "hbm_budget_mb": round(budget / 1e6, 1)},
        {"layout": "ratio", "peak_admitted": round(ratio, 2),
         "hbm_budget_mb": "check>=1.5:" + str(ratio >= 1.5)},
    ]
    assert ratio >= 1.5, (
        f"paged+int8 admitted only {ratio:.2f}x the dense clients")
    return emit("paged_admission_fixed_hbm", rows)


def run_compaction(quick: bool = False):
    """ISSUE 3 acceptance: compute-proportional decode. The same bank serves
    workloads at several slot occupancies through (a) the masked bank-wide
    decode (every tick runs all C*max_b rows, inactive outputs discarded)
    and (b) the compacted decode (active rows gathered across clients into
    a bucketed dense batch; attention through the table-aware paged kernel,
    per-row LoRA through SGMV). Outputs are asserted byte-identical; at
    sparse occupancy the compacted path must deliver >= 2x decode tok/s,
    and at full occupancy it must not regress."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = (8, 2) if quick else (16, 4)
    max_new = 16 if quick else 32
    scfg = ServeConfig(n_clients=C, max_seq=64, page_block=16)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))
    total = C * max_b

    def workload(busy_rows):
        rng = np.random.default_rng(0)
        reqs, rows_left, c = [], busy_rows, 0
        while rows_left > 0:
            rows = min(max_b, rows_left)
            reqs.append(Request(client_id=c,
                                prompt=rng.integers(0, cfg.vocab,
                                                    (rows, 8)).astype(np.int32),
                                max_new_tokens=max_new))
            rows_left -= rows
            c += 1
        return reqs

    def measure(busy_rows, compact):
        def once():
            eng = ServingEngine(cfg, ACFG, scfg, base, bank,
                                max_batch_per_client=max_b,
                                compact_decode=compact)
            for r in workload(busy_rows):
                eng.submit(r)
            t0 = time.perf_counter()
            done = eng.run()
            dt = time.perf_counter() - t0
            return eng.stats["decode_tokens"] / dt, eng.stats, done
        once()                                 # warm the compile caches
        return max((once() for _ in range(2 if quick else 3)),
                   key=lambda r: r[0])

    rows = []
    sparse_ratios = {}
    # occupancies: 1/16, 1/8, 1/4 of the bank's rows, and the full bank
    for busy in sorted({max(1, total // 16), total // 8, total // 4, total}):
        m_tok, m_stats, m_done = measure(busy, compact=False)
        c_tok, c_stats, c_done = measure(busy, compact=True)
        assert_byte_identical(
            m_done, c_done,
            f"compaction: masked vs compact at occupancy {busy}/{total}")
        occ = busy / total
        ratio = c_tok / max(m_tok, 1e-9)
        if occ <= 0.25:
            sparse_ratios[occ] = ratio
        rows.append({"occupancy": f"{busy}/{total}",
                     "masked_tok_s": round(m_tok),
                     "compact_tok_s": round(c_tok),
                     "speedup": round(ratio, 2),
                     "compact_rows": c_stats["compact_rows"],
                     "compact_padded": c_stats["compact_padded"],
                     "admitted": c_stats["admitted"]})
    best_sparse = max(sparse_ratios.values())
    full_ratio = rows[-1]["speedup"]
    # acceptance: >=2x at <=25% occupancy at full size, no regression at
    # full occupancy. The quick/smoke shapes are too small for row count to
    # dominate CPU matmul efficiency, so the smoke floor is a sanity bound
    # (compaction must not LOSE at sparse occupancy); the 2x bar runs in
    # the non-quick bench and the CI tier2 job.
    floor = 1.0 if quick else 2.0
    full_floor = 0.7 if quick else 0.8
    rows.append({"occupancy": "check", "masked_tok_s": "-",
                 "compact_tok_s": "-",
                 "speedup": f"sparse>={floor}:{best_sparse:.2f}",
                 "compact_rows": f"full>={full_floor}:{full_ratio}",
                 "compact_padded": "-", "admitted": "-"})
    assert best_sparse >= floor, (
        f"compacted decode speedup {best_sparse:.2f}x at sparse occupancy "
        f"(need >= {floor}x)")
    assert full_ratio >= full_floor, (
        f"compacted decode regressed at full occupancy: {full_ratio:.2f}x")
    return emit("compact_decode_sparse_occupancy", rows)


def run_mixed(quick: bool = False):
    """ISSUE 5 acceptance: mixed-PEFT serving banks. One engine holds a
    LoRA + IA3 + prefix bank and decodes all three methods in each
    compacted tick; at EQUAL occupancy its decode tok/s must stay within
    10% of a single-method (all-LoRA) engine over the same base (the
    per-row method gathers ride the same bucketed batch — mixing methods
    costs gated gathers, not extra base passes), and every mixed client's
    stream is byte-identical to its solo single-method run."""
    import dataclasses as dc
    from repro.models import get_model
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    cpb = 1 if quick else 2                       # clients per bank
    C, max_b = 3 * cpb, 2
    prompt_len, max_new = 8, 12 if quick else 24
    scfg = ServeConfig(n_clients=C, max_seq=64, page_block=16)
    base = get_model(cfg).init_params(jax.random.PRNGKey(0))
    acfgs = [AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o")),
             AdapterConfig(method="ia3", targets=("k", "v", "down")),
             AdapterConfig(method="prefix", targets=("q", "v"), n_prefix=8)]
    banks = [ad_lib.init_client_bank(cfg, a, cpb, jax.random.PRNGKey(5 + i))
             for i, a in enumerate(acfgs)]
    lora_bank_full = ad_lib.init_client_bank(cfg, acfgs[0], C,
                                             jax.random.PRNGKey(9))

    def workload():
        rng = np.random.default_rng(0)
        return [Request(client_id=c,
                        prompt=rng.integers(0, cfg.vocab,
                                            (1, prompt_len)).astype(np.int32),
                        max_new_tokens=max_new) for c in range(C)]

    def measure(make_engine):
        def once():
            eng = make_engine()
            for r in workload():
                eng.submit(r)
            t0 = time.perf_counter()
            done = eng.run()
            dt = time.perf_counter() - t0
            return eng.stats["decode_tokens"] / dt, done
        once()                                    # warm the compile caches
        return max((once() for _ in range(2 if quick else 3)),
                   key=lambda r: r[0])

    mixed_tok, mixed_done = measure(
        lambda: ServingEngine(cfg, acfgs, scfg, base, banks,
                              max_batch_per_client=max_b))
    single_tok, _ = measure(
        lambda: ServingEngine(cfg, acfgs[0], scfg, base, lora_bank_full,
                              max_batch_per_client=max_b))

    # identity oracle: each mixed client against its solo single-method run
    solo_done = []
    for r in workload():
        m, local = r.client_id // cpb, r.client_id % cpb
        one_bank = jax.tree.map(lambda x: x[local:local + 1], banks[m])
        solo = ServingEngine(cfg, acfgs[m], dc.replace(scfg, n_clients=1),
                             base, one_bank, max_batch_per_client=max_b)
        ref = Request(client_id=0, prompt=r.prompt.copy(),
                      max_new_tokens=r.max_new_tokens)
        solo.submit(ref)
        solo.run()
        ref.client_id = r.client_id               # re-key for the oracle diff
        solo_done.append(ref)
    assert_byte_identical(mixed_done, solo_done,
                          "mixed-method vs solo single-method")

    ratio = mixed_tok / max(single_tok, 1e-9)
    floor = 0.5 if quick else 0.9
    rows = [
        {"mix": "mixed_lora_ia3_prefix", "decode_tok_s": round(mixed_tok),
         "clients": C, "identity": "byte-identical-to-solo"},
        {"mix": "single_method_lora", "decode_tok_s": round(single_tok),
         "clients": C, "identity": "-"},
        {"mix": "ratio", "decode_tok_s": round(ratio, 3),
         "clients": f"check>={floor}:{ratio >= floor}", "identity": "-"},
    ]
    assert ratio >= floor, (
        f"mixed-method decode tok/s only {ratio:.2f}x the single-method "
        f"engine at equal occupancy (floor {floor})")
    return emit("mixed_method_serving", rows)


def run_shared_prefix(quick: bool = False):
    """ISSUE 10 acceptance: many users, few templates. Each client serves a
    long-lived "publisher" request plus a stream of followers that share
    its 31-token prompt template and differ only in the final token. With
    shared-prefix page reuse every follower maps the template's 3 full
    blocks and CoW-copies the tail, allocating ONE exclusive prompt page
    instead of four — >= 2x fewer prompt pages per admitted request at
    byte-identical outputs and no admission-latency regression."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = 2, 4
    n_follow = 4 if quick else 8
    blk, tpl_len = 8, 31
    prompt_len = tpl_len + 1                       # 4 pages per admission
    scfg = ServeConfig(n_clients=C, max_seq=64, page_block=blk,
                       pool_pages=32)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tpls = [rng.integers(1, cfg.vocab, tpl_len).astype(np.int32)
            for _ in range(C)]

    def workload():
        reqs = []
        for c in range(C):
            # the publisher decodes long enough to still be live (holding
            # its published refs) when the last follower is admitted
            reqs.append(Request(
                client_id=c, max_new_tokens=2 * n_follow + 6, arrive_tick=0,
                prompt=np.concatenate(
                    [tpls[c], np.zeros(1, np.int32)])[None, :]))
            for i in range(n_follow):
                reqs.append(Request(
                    client_id=c, max_new_tokens=4, arrive_tick=1 + i,
                    prompt=np.concatenate(
                        [tpls[c], np.full(1, 1 + i, np.int32)])[None, :]))
        return reqs

    def measure(**engine_kw):
        def once():
            eng = ServingEngine(cfg, ACFG, scfg, base, bank,
                                max_batch_per_client=max_b, **engine_kw)
            for r in workload():
                eng.submit(r)
            done = eng.run()
            assert all(r.status == "ok" for r in done)
            admit = [r.admit_t - r.submit_t for r in done]
            return eng.stats, done, sum(admit) / len(admit)
        once()                                     # warm the compile caches
        return once()

    on_stats, on_done, on_admit = measure()
    off_stats, off_done, off_admit = measure(prefix_cache=False)
    assert_byte_identical(on_done, off_done, "shared-prefix vs no cache")

    n_req = C * (1 + n_follow)
    pages_per_req = -(-prompt_len // blk)          # 4
    total_pages = n_req * pages_per_req
    on_alloc = total_pages - on_stats["pages_shared"]
    ratio = total_pages / max(on_alloc, 1)
    rows = [
        {"sharing": "on", "prompt_pages_alloc": on_alloc,
         "pages_per_admission": round(on_alloc / n_req, 2),
         "prefix_hits": on_stats["prefix_hits"],
         "pages_shared": on_stats["pages_shared"],
         "cow_copies": on_stats["cow_copies"],
         "prefill_tok_computed": on_stats["prefill_tokens_computed"],
         "mean_admit_ms": round(on_admit * 1e3, 3)},
        {"sharing": "off", "prompt_pages_alloc": total_pages,
         "pages_per_admission": float(pages_per_req),
         "prefix_hits": 0, "pages_shared": 0, "cow_copies": 0,
         "prefill_tok_computed": off_stats["prefill_tokens_computed"],
         "mean_admit_ms": round(off_admit * 1e3, 3)},
        {"sharing": "ratio", "prompt_pages_alloc": round(ratio, 2),
         "pages_per_admission": "check>=2:" + str(ratio >= 2.0),
         "prefix_hits": "-", "pages_shared": "-", "cow_copies": "-",
         "prefill_tok_computed": "-", "mean_admit_ms": "-"},
    ]
    assert ratio >= 2.0, (
        f"shared-prefix allocated only {ratio:.2f}x fewer prompt pages "
        f"per admitted request (need >= 2x)")
    # the content-index lookup/publish is host-side hashing; it must not
    # show up in admission latency (generous bound — CI wall clocks jitter)
    assert on_admit <= off_admit * 2.0 + 5e-3, (
        f"admission latency regressed with sharing on: "
        f"{on_admit * 1e3:.2f}ms vs {off_admit * 1e3:.2f}ms")
    return emit("shared_prefix_template_mix", rows)


def run_sharded_serving(quick: bool = False, mesh=None):
    """ISSUE 7: the sharded serving path through the EngineSpec API.

    Same workload through an unsharded engine and one placed on a device
    mesh (``--mesh``, or the default: a 2x2 mesh when >= 4 devices are
    visible, else the 1-device host mesh — smoke-safe on CPU CI). The
    sharded run must be byte-identical (replicated base, client-axis
    partitioning only) and reports its tok/s next to the unsharded row."""
    from repro.core.engine_spec import BankSpec, EngineSpec
    from repro.launch.mesh import _make_mesh, make_host_mesh

    if mesh is None:
        mesh = (_make_mesh((2, 2), ("data", "model"))
                if jax.device_count() >= 4 else make_host_mesh())
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = 2, 2
    n_req, prompt_len, max_new = (6, 16, 8) if quick else (12, 32, 16)
    scfg = ServeConfig(n_clients=C, max_seq=prompt_len + max_new + 8,
                       page_block=16)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))

    def measure(m):
        spec = EngineSpec(cfg=cfg, banks=(BankSpec("tenants", ACFG, capacity=C),),
                          serve=scfg, mesh=m, replicate_base=m is not None,
                          max_batch_per_client=max_b)

        def once():
            eng = ServingEngine(spec, base, [bank])
            for r in _serving_workload(cfg, C, max_b, n_req, prompt_len,
                                       max_new):
                eng.submit(r)
            t0 = time.perf_counter()
            done = eng.run()
            dt = time.perf_counter() - t0
            return sum(r.generated.size for r in done) / dt, done
        once()                                 # warm the compile caches
        return max((once() for _ in range(2)), key=lambda r: r[0])

    plain_tok, plain_done = measure(None)
    mesh_tok, mesh_done = measure(mesh)
    assert_byte_identical(plain_done, mesh_done, "sharded vs unsharded")
    devs = mesh.devices.size
    rows = [
        {"sharded": "unsharded", "tok_s": round(plain_tok), "devices": 1,
         "identity": "-"},
        {"sharded": f"mesh{dict(mesh.shape)}", "tok_s": round(mesh_tok),
         "devices": devs, "identity": "byte-identical"},
    ]
    return emit("sharded_serving", rows)


def run(quick: bool = False):
    # paper uses Llama3-1B for this comparison; reduced variant here
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    seq, B = (64, 2) if quick else (128, 2)
    rows = []
    clients = (1, 2, 4) if quick else (1, 2, 4, 6, 8)
    for C in clients:
        key = jax.random.PRNGKey(0)
        base, bank, opt = symbiosis.init_system(cfg, ACFG, C, key)
        tcfg = TrainConfig(n_clients=C, remat=False)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, ACFG, tcfg))
        batch = make_client_batches(cfg, C, B, seq).batch(0)

        t_sym = timeit(lambda: step(base, bank, opt, batch, 0), reps=3)

        # baseline: C isolated single-client jobs run back-to-back
        one_step = jax.jit(symbiosis.make_multi_client_train_step(
            cfg, ACFG, TrainConfig(n_clients=1, remat=False)))
        one_bank = jax.tree.map(lambda x: x[:1], bank)
        one_opt = jax.tree.map(lambda x: x[:1], opt)
        one_batch = jax.tree.map(lambda x: x[:1], batch)

        def baseline(C=C):
            outs = []
            for _ in range(C):
                outs.append(one_step(base, one_bank, one_opt, one_batch, 0))
            return outs

        t_base = timeit(baseline, reps=3)
        tokens = C * B * seq
        rows.append({
            "clients": C,
            "symbiosis_iter_s": round(t_sym, 4),
            "baseline_iter_s": round(t_base, 4),
            "symbiosis_tok_s": round(tokens / t_sym),
            "baseline_tok_s": round(tokens / t_base),
        })
    # C2: beyond 2 clients Symbiosis should win
    big = [r for r in rows if r["clients"] >= 4]
    rows.append({"clients": "check_C2",
                 "symbiosis_iter_s": all(r["symbiosis_iter_s"] <= r["baseline_iter_s"]
                                         for r in big),
                 "baseline_iter_s": "-", "symbiosis_tok_s": "-",
                 "baseline_tok_s": "-"})
    out = emit("fig11_12_multiclient", rows)
    return (out + run_serving(quick) + run_latency(quick)
            + run_paged_admission(quick)
            + run_compaction(quick) + run_mixed(quick)
            + run_shared_prefix(quick)
            + run_sharded_serving(quick))


def run_smoke():
    """CI bench-smoke entry: a few real engine ticks on tiny configs —
    the serving comparison (incl. the paged engine), the tail-latency
    section (telemetry-backed), the paged-admission section, the
    compacted-decode occupancy sweep, the mixed-method bank section, the
    shared-prefix template-mix section, and the sharded-vs-unsharded
    serving identity."""
    return (run_serving(quick=True) + run_latency(quick=True)
            + run_paged_admission(quick=True)
            + run_compaction(quick=True) + run_mixed(quick=True)
            + run_shared_prefix(quick=True)
            + run_sharded_serving(quick=True))


def main():
    import argparse

    from repro.launch.mesh import _make_mesh

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh", nargs=2, type=int, default=None,
                    metavar=("DATA", "MODEL"),
                    help="run the sharded_serving section on a "
                         "(data, model) device mesh (e.g. --mesh 2 2)")
    args = ap.parse_args()
    if args.mesh:
        mesh = _make_mesh(tuple(args.mesh), ("data", "model"))
        run_sharded_serving(quick=args.quick, mesh=mesh)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()
