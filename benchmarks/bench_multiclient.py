"""Fig 11/12: single-GPU multi-client fine-tuning — latency & throughput.

Baseline = N isolated jobs (N separate step calls, contending for the one
device, each with its own model instance in the paper — here each pays its
own dispatch+compute). Symbiosis = ONE batched multi-client step.
Paper finding (C2): baseline wins at 1-2 clients; Symbiosis wins beyond.
"""
from __future__ import annotations

import jax

from repro.config import AdapterConfig, TrainConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.data import make_client_batches
from benchmarks.common import timeit, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))


def run(quick: bool = False):
    # paper uses Llama3-1B for this comparison; reduced variant here
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    seq, B = (64, 2) if quick else (128, 2)
    rows = []
    clients = (1, 2, 4) if quick else (1, 2, 4, 6, 8)
    for C in clients:
        key = jax.random.PRNGKey(0)
        base, bank, opt = symbiosis.init_system(cfg, ACFG, C, key)
        tcfg = TrainConfig(n_clients=C, remat=False)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, ACFG, tcfg))
        batch = make_client_batches(cfg, C, B, seq).batch(0)

        t_sym = timeit(lambda: step(base, bank, opt, batch, 0), reps=3)

        # baseline: C isolated single-client jobs run back-to-back
        one_step = jax.jit(symbiosis.make_multi_client_train_step(
            cfg, ACFG, TrainConfig(n_clients=1, remat=False)))
        one_bank = jax.tree.map(lambda x: x[:1], bank)
        one_opt = jax.tree.map(lambda x: x[:1], opt)
        one_batch = jax.tree.map(lambda x: x[:1], batch)

        def baseline():
            outs = []
            for _ in range(C):
                outs.append(one_step(base, one_bank, one_opt, one_batch, 0))
            return outs

        t_base = timeit(baseline, reps=3)
        tokens = C * B * seq
        rows.append({
            "clients": C,
            "symbiosis_iter_s": round(t_sym, 4),
            "baseline_iter_s": round(t_base, 4),
            "symbiosis_tok_s": round(tokens / t_sym),
            "baseline_tok_s": round(tokens / t_base),
        })
    # C2: beyond 2 clients Symbiosis should win
    big = [r for r in rows if r["clients"] >= 4]
    rows.append({"clients": "check_C2",
                 "symbiosis_iter_s": all(r["symbiosis_iter_s"] <= r["baseline_iter_s"]
                                         for r in big),
                 "baseline_iter_s": "-", "symbiosis_tok_s": "-",
                 "baseline_tok_s": "-"})
    return emit("fig11_12_multiclient", rows)


if __name__ == "__main__":
    run()
