"""Fig 11/12: single-GPU multi-client fine-tuning — latency & throughput —
plus the serving-engine continuous-batching comparison (§3.7).

Fine-tuning: baseline = N isolated jobs (N separate step calls, contending
for the one device, each with its own model instance in the paper — here
each pays its own dispatch+compute). Symbiosis = ONE batched multi-client
step. Paper finding (C2): baseline wins at 1-2 clients; Symbiosis wins
beyond.

Serving: the same request workload through (a) the seed-style engine
(bank-wide prefill per admitted request + one request per client at a
time) and (b) the continuous-batching engine (masked single-client
prefill, slot-level admission, mid-stream join/leave). Outputs are
byte-identical (exactness), throughput is not.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.config import AdapterConfig, ServeConfig, TrainConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.data import make_client_batches
from repro.serving import kvcache
from repro.serving.engine import ServingEngine, Request
from repro.serving.router import PlacementRouter, Slot
from benchmarks.common import timeit, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))


def _serving_workload(cfg, n_clients, max_b, n_requests, prompt_len, max_new):
    rng = np.random.default_rng(0)
    return [Request(client_id=i % n_clients,
                    prompt=rng.integers(0, cfg.vocab,
                                        (1, prompt_len)).astype(np.int32),
                    max_new_tokens=max_new,
                    arrive_tick=i)            # staggered arrivals
            for i in range(n_requests)]


def run_serving(quick: bool = False):
    """Continuous batching vs seed-style engine, same workload."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = (2, 2) if quick else (4, 2)
    n_req, prompt_len, max_new = (8, 16, 12) if quick else (16, 32, 16)
    scfg = ServeConfig(n_clients=C, max_seq=prompt_len + max_new + 8)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))

    def measure(sc=scfg, **engine_kw):
        eng = ServingEngine(cfg, ACFG, sc, base, bank,
                            max_batch_per_client=max_b, **engine_kw)
        for r in _serving_workload(cfg, C, max_b, n_req, prompt_len, max_new):
            eng.submit(r)
        eng.run()                              # warm compile caches
        eng2 = ServingEngine(cfg, ACFG, sc, base, bank,
                             max_batch_per_client=max_b, **engine_kw)
        reqs = _serving_workload(cfg, C, max_b, n_req, prompt_len, max_new)
        for r in reqs:
            eng2.submit(r)
        t0 = time.perf_counter()
        done = eng2.run()
        dt = time.perf_counter() - t0
        toks = sum(r.generated.size for r in done)
        return toks / dt, eng2.stats, done

    seed_tok_s, seed_stats, seed_done = measure(bank_prefill=True,
                                                max_inflight_per_client=1)
    cont_tok_s, cont_stats, cont_done = measure()
    paged_tok_s, paged_stats, paged_done = measure(
        dataclasses.replace(scfg, page_block=16))

    # exactness: the paged layout changes memory management, never outputs
    key = lambda r: (r.client_id, r.prompt.tobytes())
    assert ({key(r): r.generated.tobytes() for r in cont_done}
            == {key(r): r.generated.tobytes() for r in paged_done}), \
        "paged outputs diverged from dense"

    rows = [
        {"engine": "seed_style", "tok_s": round(seed_tok_s),
         "ticks": seed_stats["ticks"], "prefill_tokens": seed_stats["prefill_tokens"]},
        {"engine": "continuous", "tok_s": round(cont_tok_s),
         "ticks": cont_stats["ticks"], "prefill_tokens": cont_stats["prefill_tokens"]},
        {"engine": "continuous_paged", "tok_s": round(paged_tok_s),
         "ticks": paged_stats["ticks"], "prefill_tokens": paged_stats["prefill_tokens"]},
        {"engine": "speedup", "tok_s": round(cont_tok_s / max(seed_tok_s, 1e-9), 2),
         "ticks": "-", "prefill_tokens": "-"},
    ]
    return emit("sec37_serving_continuous_batching", rows)


def run_paged_admission(quick: bool = False):
    """ISSUE 2 acceptance: concurrently admitted clients at a FIXED fleet
    HBM budget — dense max_seq-deep slot rows vs paged (16-token pages) +
    int8 KV. The router charges what each layout pins, so the dense engine
    serializes on HBM while the paged engine packs many short requests into
    the same budget."""
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    C, max_b = (4, 2) if quick else (8, 4)
    prompt_len, max_new = 12, 12
    max_seq = 512 if quick else 1024
    n_req = C * max_b
    scfg_dense = ServeConfig(n_clients=C, max_seq=max_seq)
    scfg_paged = dataclasses.replace(scfg_dense, page_block=16, kv_quant=True)
    # budget fits ~2 (quick) / ~4 dense sessions — the dense ceiling
    dense_row = kvcache.cache_bytes(cfg, max_seq, 1)
    budget = dense_row * (2.5 if quick else 4.5)
    base, bank, _ = symbiosis.init_system(cfg, ACFG, C, jax.random.PRNGKey(0))

    def peak_admitted(sc):
        router = PlacementRouter(cfg, [Slot(0, free_hbm=budget)],
                                 host_free_bytes=0)
        eng = ServingEngine(cfg, ACFG, sc, base, bank,
                            max_batch_per_client=max_b, router=router)
        rng = np.random.default_rng(0)
        for i in range(n_req):                 # all due at tick 0
            eng.submit(Request(client_id=i % C,
                               prompt=rng.integers(0, cfg.vocab,
                                                   (1, prompt_len)).astype(np.int32),
                               max_new_tokens=max_new))
        done = eng.run()
        assert len(done) == n_req
        return eng.stats["peak_inflight"]

    dense_peak = peak_admitted(scfg_dense)
    paged_peak = peak_admitted(scfg_paged)
    ratio = paged_peak / max(dense_peak, 1)
    rows = [
        {"layout": "dense_rows", "peak_admitted": dense_peak,
         "hbm_budget_mb": round(budget / 1e6, 1)},
        {"layout": "paged16_int8", "peak_admitted": paged_peak,
         "hbm_budget_mb": round(budget / 1e6, 1)},
        {"layout": "ratio", "peak_admitted": round(ratio, 2),
         "hbm_budget_mb": "check>=1.5:" + str(ratio >= 1.5)},
    ]
    assert ratio >= 1.5, (
        f"paged+int8 admitted only {ratio:.2f}x the dense clients")
    return emit("paged_admission_fixed_hbm", rows)


def run(quick: bool = False):
    # paper uses Llama3-1B for this comparison; reduced variant here
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    seq, B = (64, 2) if quick else (128, 2)
    rows = []
    clients = (1, 2, 4) if quick else (1, 2, 4, 6, 8)
    for C in clients:
        key = jax.random.PRNGKey(0)
        base, bank, opt = symbiosis.init_system(cfg, ACFG, C, key)
        tcfg = TrainConfig(n_clients=C, remat=False)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, ACFG, tcfg))
        batch = make_client_batches(cfg, C, B, seq).batch(0)

        t_sym = timeit(lambda: step(base, bank, opt, batch, 0), reps=3)

        # baseline: C isolated single-client jobs run back-to-back
        one_step = jax.jit(symbiosis.make_multi_client_train_step(
            cfg, ACFG, TrainConfig(n_clients=1, remat=False)))
        one_bank = jax.tree.map(lambda x: x[:1], bank)
        one_opt = jax.tree.map(lambda x: x[:1], opt)
        one_batch = jax.tree.map(lambda x: x[:1], batch)

        def baseline():
            outs = []
            for _ in range(C):
                outs.append(one_step(base, one_bank, one_opt, one_batch, 0))
            return outs

        t_base = timeit(baseline, reps=3)
        tokens = C * B * seq
        rows.append({
            "clients": C,
            "symbiosis_iter_s": round(t_sym, 4),
            "baseline_iter_s": round(t_base, 4),
            "symbiosis_tok_s": round(tokens / t_sym),
            "baseline_tok_s": round(tokens / t_base),
        })
    # C2: beyond 2 clients Symbiosis should win
    big = [r for r in rows if r["clients"] >= 4]
    rows.append({"clients": "check_C2",
                 "symbiosis_iter_s": all(r["symbiosis_iter_s"] <= r["baseline_iter_s"]
                                         for r in big),
                 "baseline_iter_s": "-", "symbiosis_tok_s": "-",
                 "baseline_tok_s": "-"})
    out = emit("fig11_12_multiclient", rows)
    return out + run_serving(quick) + run_paged_admission(quick)


def run_smoke():
    """CI bench-smoke entry: a few real engine ticks on tiny configs —
    the serving comparison (incl. the paged engine) and the paged-admission
    section."""
    return run_serving(quick=True) + run_paged_admission(quick=True)


if __name__ == "__main__":
    run()
