"""Tables 4/5: lockstep vs no-lockstep vs opportunistic batching.

The event-driven engine (core.scheduler) is calibrated with measured
per-op costs from this host (core.base_executor.calibrate_layer_cost), then
replays the paper's Table 5 setting: 8 inference clients with batch sizes
2..256 and different adapters.
"""
from __future__ import annotations

from repro.core.base_executor import calibrate_layer_cost
from repro.core.scheduler import ClientSpec, simulate
from benchmarks.common import emit

N_LAYERS = 40      # Llama2-13B


# The paper's regime: the shared base executor (Llama2-13B layers on an
# A100) is the expensive resource; client-side attention+adapter work is
# lighter. Per-layer costs modeled at that scale — a ~100us dispatch+launch
# overhead amortized by batching, ~2us/token of layer matmul, client-side
# 20us..1ms depending on batch and adapter (LoRA1 vs LoRA4).
EXEC_OVERHEAD_13B = 1e-4
PER_TOKEN_13B = 2e-6


def _clients():
    sizes = [2, 4, 8, 16, 32, 64, 128, 256]
    out = []
    for i, s in enumerate(sizes):
        heavy = 1 + (i % 2) * 3        # LoRA1 vs LoRA4
        out.append(ClientSpec(
            client_id=i, n_tokens=s,
            client_side_time=2e-5 + 1e-6 * s * heavy,
            n_iterations=6, latency_sensitive=(s <= 4)))
    return out


def run(quick: bool = False):
    host_overhead, host_per_token = calibrate_layer_cost(din=256, dout=256, reps=2)
    overhead, per_token = EXEC_OVERHEAD_13B, PER_TOKEN_13B
    rows = []
    # Table 4: lockstep co-batching penalty (vLLM-style)
    small = ClientSpec(0, n_tokens=1, client_side_time=1e-5, n_iterations=4)
    large = ClientSpec(1, n_tokens=512, client_side_time=1e-3, n_iterations=4)
    for policy in ("lockstep", "opportunistic"):
        r = simulate([small, large], N_LAYERS, policy, overhead, per_token,
                     wait_fraction=0.1)
        rows.append({"table": "4", "policy": policy,
                     "small_latency_s": round(r.per_client_latency[0], 5),
                     "large_latency_s": round(r.per_client_latency[1], 5),
                     "throughput": round(r.throughput),
                     "avg_batch": round(r.avg_batch_size, 2)})
    # Table 5: 8 heterogeneous inference clients. wait_fraction 0.5: the
    # paper lets the 256-batch client wait up to 50ms/iter — a sizeable
    # fraction of its naturally long iteration.
    for policy in ("nolockstep", "lockstep", "opportunistic"):
        r = simulate(_clients(), N_LAYERS, policy, overhead, per_token,
                     wait_fraction=0.5)
        s = r.summary()
        rows.append({"table": "5", "policy": policy,
                     "small_latency_s": round(s["mean_latency_s"], 5),
                     "large_latency_s": "-",
                     "throughput": round(s["throughput_tok_s"]),
                     "avg_batch": round(s["avg_batch"], 2)})
    rows.append({"table": "calib", "policy": "host_measured",
                 "small_latency_s": round(host_overhead, 6),
                 "large_latency_s": round(host_per_token, 9),
                 "throughput": "-", "avg_batch": "-"})
    t5 = {r["policy"]: r for r in rows if r["table"] == "5"}
    rows.append({"table": "check", "policy": "opportunistic_best",
                 "small_latency_s":
                     t5["opportunistic"]["small_latency_s"]
                     <= t5["lockstep"]["small_latency_s"],
                 "large_latency_s": "-",
                 "throughput":
                     t5["opportunistic"]["throughput"]
                     >= min(t5["nolockstep"]["throughput"],
                            t5["lockstep"]["throughput"]),
                 "avg_batch": "-"})
    return emit("table4_5_batching", rows)


if __name__ == "__main__":
    run()
