"""Fig 22/23: mixed inference + fine-tuning against one shared base.

8 inference clients alone vs 6 inference + 2 fine-tuning clients: the mixed
workload should raise total token throughput (fine-tuning fills the
generation phase's idle capacity) while inference latency stays flat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import AdapterConfig, TrainConfig, ServeConfig
from repro.configs import get_config
from repro.core import symbiosis
from benchmarks.common import timeit, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "v"))


def run(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    n_inf, n_ft = (4, 2) if quick else (6, 2)
    B, S_ft = 2, 128
    key = jax.random.PRNGKey(0)
    rows = []

    # inference-only: 8 decode clients
    base, inf_bank, _ = symbiosis.init_system(cfg, ACFG, n_inf + n_ft, key)
    caches = symbiosis.init_client_caches(cfg, n_inf + n_ft, B, 64)
    scfg = ServeConfig(n_clients=n_inf + n_ft, max_seq=64)
    decode = jax.jit(symbiosis.make_multi_client_decode_step(cfg, ACFG, scfg))
    toks = jnp.ones((n_inf + n_ft, B), jnp.int32)
    t_inf = timeit(lambda: decode(base, inf_bank, caches, toks), reps=3)
    inf_tok_s = (n_inf + n_ft) * B / t_inf
    rows.append({"fig": "22", "workload": f"{n_inf + n_ft}_inference",
                 "tok_s": round(inf_tok_s),
                 "inference_latency_s": round(t_inf, 4)})

    # mixed: n_inf inference + n_ft fine-tuning
    _, ft_bank, ft_opt = symbiosis.init_system(cfg, ACFG, n_ft,
                                               jax.random.PRNGKey(1))
    inf_bank2 = jax.tree.map(lambda x: x[:n_inf], inf_bank)
    caches2 = symbiosis.init_client_caches(cfg, n_inf, B, 64)
    tcfg = TrainConfig(n_clients=n_ft, remat=False)
    mixed = jax.jit(symbiosis.make_mixed_step(cfg, ACFG, tcfg, scfg))
    ft_batch = {"tokens": jnp.ones((n_ft, B, S_ft), jnp.int32),
                "labels": jnp.ones((n_ft, B, S_ft), jnp.int32)}
    toks2 = jnp.ones((n_inf, B), jnp.int32)

    t_mixed = timeit(lambda: mixed(base, ft_bank, ft_opt, ft_batch,
                                   inf_bank2, caches2, toks2, 0), reps=3)
    mixed_tok_s = (n_inf * B + n_ft * B * S_ft) / t_mixed
    rows.append({"fig": "23", "workload": f"{n_inf}_inf+{n_ft}_ft",
                 "tok_s": round(mixed_tok_s),
                 "inference_latency_s": round(t_mixed, 4)})
    rows.append({"fig": "check", "workload": "mixed_improves_utilization",
                 "tok_s": bool(mixed_tok_s > inf_tok_s),
                 "inference_latency_s": "-"})
    return emit("fig22_23_mixed", rows)


if __name__ == "__main__":
    run()
