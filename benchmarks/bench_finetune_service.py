"""Fine-tuning-as-a-service benchmark (ISSUE 4 acceptance).

The paper's §5 economics: N concurrent fine-tuning jobs against ONE shared
frozen base vs N dedicated deployments, each holding its own base replica.
The shared engine's base-weight HBM is constant in N (the whole point of
Symbiosis), and aggregate step throughput stays comparable — one batched
multi-job step against N dispatches of the same math.

Sections (rows persisted by ``benchmarks/run.py --json`` into
``BENCH_training.json``):

* ``finetune_service_shared_base`` — N jobs in a FinetuneEngine (ONE base)
  vs N dedicated replicas (N real copies of the base tree, each stepped by
  its own ``make_baseline_train_step``). Reports base-weight HBM and
  aggregate optimizer steps/s; asserts >= 3x lower base HBM at 4 jobs with
  comparable aggregate step/s.
* ``finetune_service_bank_mix`` — heterogeneous service: LoRA + IA3 +
  prefix jobs in one engine (three banks, one base), to show the
  multi-bank path carries mixed PEFT methods at service throughput.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.config import AdapterConfig, FinetuneConfig, TrainConfig
from repro.configs import get_config
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.models import get_model
from repro.optim import adamw_init
from repro.training import FinetuneEngine, FinetuneJob, make_job_stream
from benchmarks.common import emit, tree_bytes

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))


def _jobs(cfg, n, steps, batch, seq, method="lora"):
    acfg = AdapterConfig(method=method, rank=8,
                         targets=ad_lib.DEFAULT_TARGETS[method])
    return [FinetuneJob(acfg=acfg, data=make_job_stream(cfg, batch, seq, seed=i),
                        batch_size=batch, seq_len=seq, steps=steps, seed=i,
                        lr=1e-2, warmup_steps=1, name=f"{method}-{i}")
            for i in range(n)]


def run_shared_vs_replicas(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    N = 4
    batch, seq = 2, 32 if quick else 64
    steps = 6 if quick else 10
    base = get_model(cfg).init_params(jax.random.PRNGKey(0))
    base_b = tree_bytes(base)

    def shared():
        eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=N))
        for j in _jobs(cfg, N, steps, batch, seq):
            eng.submit(j)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        assert len(done) == N
        return N * steps / dt

    # N dedicated deployments: N REAL base replicas (allocated copies — the
    # HBM a per-job serving stack actually pins), each stepped by its own
    # solo trainer (the §3.6 client path, so compute per job is identical
    # to the shared engine's rows — the comparison isolates batching +
    # dispatch, not backward flavor)
    tcfg = TrainConfig(lr=1e-2, warmup_steps=1, total_steps=steps)
    step_fn = jax.jit(symbiosis.make_baseline_train_step(
        cfg, ACFG, tcfg, memory_optimized=True))
    replicas = [jax.tree.map(lambda x: x + 0, base) for _ in range(N)]
    replica_b = sum(tree_bytes(r) for r in replicas)

    def dedicated():
        states = []
        for i in range(N):
            a = ad_lib.init_adapter(cfg, ACFG, jax.random.PRNGKey(i))
            states.append((a, adamw_init(a), make_job_stream(cfg, batch, seq,
                                                             seed=i)))
        t0 = time.perf_counter()
        for t in range(steps):
            for i in range(N):
                a, o, stream = states[i]
                a, o, _ = step_fn(replicas[i], a, o, stream.batch(t), t)
                states[i] = (a, o, stream)
        jax.block_until_ready([s[0] for s in states])
        return N * steps / (time.perf_counter() - t0)

    shared()                                   # warm compile caches
    dedicated()
    shared_sps = max(shared() for _ in range(2))
    dedicated_sps = max(dedicated() for _ in range(2))
    hbm_ratio = replica_b / base_b
    sps_ratio = shared_sps / dedicated_sps
    rows = [
        {"workload": "shared_base", "jobs": N, "steps_s": round(shared_sps, 2),
         "base_hbm_mb": round(base_b / 1e6, 2)},
        {"workload": "dedicated_replicas", "jobs": N,
         "steps_s": round(dedicated_sps, 2),
         "base_hbm_mb": round(replica_b / 1e6, 2)},
        {"workload": "ratio", "jobs": N,
         "steps_s": f"shared/dedicated={sps_ratio:.2f}",
         "base_hbm_mb": f"check>=3:{hbm_ratio:.1f}"},
    ]
    assert hbm_ratio >= 3.0, (
        f"shared base must hold >=3x less base-weight HBM ({hbm_ratio:.1f}x)")
    # "comparable aggregate step/s": shared batching must not collapse
    # throughput (it usually WINS — one dispatch for N jobs)
    assert sps_ratio >= 0.5, (
        f"shared-base step/s collapsed to {sps_ratio:.2f}x of dedicated")
    return emit("finetune_service_shared_base", rows)


def run_bank_mix(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    batch, seq, steps = 2, 32 if quick else 64, 4 if quick else 8
    base = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = FinetuneEngine(cfg, base)
    jobs = (_jobs(cfg, 2, steps, batch, seq, "lora")
            + _jobs(cfg, 2, steps, batch, seq, "ia3")
            + _jobs(cfg, 2, steps, batch, seq, "prefix"))
    for j in jobs:
        eng.submit(j)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    assert len(done) == 6 and len(eng._banks) == 3
    drops = {}
    for m in ("lora", "ia3", "prefix"):
        ls = [j.result.losses for j in jobs if j.acfg.method == m]
        drops[m] = round(float(np.mean([l[0] - l[-1] for l in ls])), 4)
    rows = [{"bankmix": "lora+ia3+prefix", "jobs": 6, "banks": 3,
             "steps_s": round(6 * steps / dt, 2),
             "loss_drop": str(drops)}]
    return emit("finetune_service_bank_mix", rows)


def run_sharded_service(quick: bool = False, mesh=None):
    """ISSUE 7: the fine-tuning service on a device mesh via EngineSpec.

    The same job set through an unsharded FinetuneEngine and one placed on
    a mesh (``--mesh``, default 2x2 when >= 4 devices else the 1-device
    host mesh): final adapter + optimizer state must match bitwise
    (replicated base, bank-row partitioning only); steps/s reported next
    to the unsharded row. Rows land in the ``sharded_serving`` section of
    ``BENCH_serving.json``."""
    from repro.core.engine_spec import BankSpec, EngineSpec
    from repro.launch.mesh import _make_mesh, make_host_mesh

    if mesh is None:
        mesh = (_make_mesh((2, 2), ("data", "model"))
                if jax.device_count() >= 4 else make_host_mesh())
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    N, batch, seq = 2, 2, 32 if quick else 64
    steps = 4 if quick else 8
    base = get_model(cfg).init_params(jax.random.PRNGKey(0))

    def measure(m):
        spec = EngineSpec(cfg=cfg,
                          banks=(BankSpec("jobs", ACFG, capacity=N),),
                          finetune=FinetuneConfig(max_jobs=N), mesh=m,
                          replicate_base=m is not None)
        eng = FinetuneEngine(spec, base)
        jobs = _jobs(cfg, N, steps, batch, seq)
        for j in jobs:
            eng.submit(j)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        assert len(done) == N
        return N * steps / dt, jobs

    plain_sps, plain_jobs = measure(None)
    mesh_sps, mesh_jobs = measure(mesh)
    for a, b in zip(jax.tree.leaves([j.result.adapter for j in plain_jobs]),
                    jax.tree.leaves([j.result.adapter for j in mesh_jobs])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="sharded train state diverged")
    rows = [
        {"sharded": "finetune_unsharded", "tok_s": round(plain_sps, 2),
         "devices": 1, "identity": "-"},
        {"sharded": f"finetune_mesh{dict(mesh.shape)}",
         "tok_s": round(mesh_sps, 2), "devices": mesh.devices.size,
         "identity": "bitwise"},
    ]
    return emit("sharded_serving", rows)


def run(quick: bool = False):
    return (run_shared_vs_replicas(quick) + run_bank_mix(quick)
            + run_sharded_service(quick))


def run_smoke():
    """CI bench-smoke entry: the shared-vs-replicas section (with its >=3x
    base-HBM assertion), the heterogeneous bank mix, and the sharded
    service identity, on tiny configs."""
    return run(quick=True)


def main():
    import argparse

    from repro.launch.mesh import _make_mesh

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh", nargs=2, type=int, default=None,
                    metavar=("DATA", "MODEL"),
                    help="run the sharded service section on a "
                         "(data, model) device mesh (e.g. --mesh 2 2)")
    args = ap.parse_args()
    if args.mesh:
        mesh = _make_mesh(tuple(args.mesh), ("data", "model"))
        run_sharded_service(quick=args.quick, mesh=mesh)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()
