"""Fig 21: privacy overhead — noise add/subtract is nearly free, outputs
bit-comparable (the paper's 'exact output' claim)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig
from repro.configs import get_config
from repro.core import adapters as ad_lib, privacy, symbiosis
from repro.core.virtlayer import make_client_ctx, attach_privacy
from repro.models import get_model
from benchmarks.common import timeit, emit

ACFG = AdapterConfig(method="lora", rank=8, targets=("q", "v"))


def run(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b").reduced(
        n_layers=2, d_model=256 if quick else 512)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    base = model.init_params(key)
    adapter = ad_lib.init_adapter(cfg, ACFG, jax.random.PRNGKey(1))
    dims = {p: d for p, d in ad_lib.resolve_targets(cfg, ACFG)}
    noise = privacy.make_noise(jax.random.PRNGKey(2), dims, n_variants=2,
                               scale=3.0)
    adapter_p = attach_privacy(adapter, cfg, base, noise)
    ctx0 = make_client_ctx(cfg, ACFG)
    ctx1 = make_client_ctx(cfg, ACFG, privacy_noise=noise, privacy_variant=0)
    batch = {"tokens": jnp.ones((2, 128), jnp.int32)}

    f0 = jax.jit(lambda: model.forward(base, batch, ctx0, adapter)[0])
    f1 = jax.jit(lambda: model.forward(base, batch, ctx1, adapter_p)[0])
    t0, t1 = timeit(f0, reps=5), timeit(f1, reps=5)
    y0, y1 = np.asarray(f0()), np.asarray(f1())
    max_err = float(np.abs(y0 - y1).max())
    noise_setup_s = timeit(
        jax.jit(lambda: privacy.noise_effect(
            noise, {"q": base["layers"]["attn"]["wq"],
                    "v": base["layers"]["attn"]["wv"]})), reps=3)
    rows = [
        {"metric": "forward_s_plain", "value": round(t0, 4)},
        {"metric": "forward_s_private", "value": round(t1, 4)},
        {"metric": "overhead_pct", "value": round(100 * (t1 - t0) / t0, 1)},
        {"metric": "max_abs_logit_err", "value": f"{max_err:.2e}"},
        {"metric": "noise_effect_precompute_s", "value": round(noise_setup_s, 4)},
        {"metric": "check_output_exact", "value": bool(max_err < 1e-2)},
    ]
    return emit("fig21_privacy", rows)


if __name__ == "__main__":
    run()
