"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--smoke]
                                          [--json PATH]

Emits CSV lines (bench,key=value,...) and writes experiments/bench/*.json.

``--smoke`` is the CI guard against benchmark rot: it imports EVERY bench
module (so stale imports/APIs fail loudly) and runs a few real ticks of
bench_multiclient on tiny configs — the serving comparison, the
paged-admission-at-fixed-HBM section, and the compacted-decode occupancy
sweep.

``--json PATH`` persists the serving-side sections (continuous-batching
tok/s, paged admission counts, compacted-decode speedups) as one combined
JSON document, so the bench trajectory is machine-readable across PRs —
the CI bench-smoke job writes ``BENCH_serving.json`` from the same run.
"""
from __future__ import annotations

import argparse
import json
import platform
import time
import traceback

BENCHES = [
    ("table2_adapter_configs", "benchmarks.bench_adapter_configs"),
    ("fig9_10_memory", "benchmarks.bench_memory"),
    ("fig11_12_multiclient", "benchmarks.bench_multiclient"),
    ("fig15_17_sharded", "benchmarks.bench_sharded"),
    ("fig18_19_heterogeneous", "benchmarks.bench_heterogeneous"),
    ("fig21_privacy", "benchmarks.bench_privacy"),
    ("fig22_23_mixed", "benchmarks.bench_mixed"),
    ("table4_5_batching", "benchmarks.bench_batching"),
    ("kernels", "benchmarks.bench_kernels"),
    ("ablations", "benchmarks.bench_ablations"),
]

# sections whose rows carry the serving trajectory (tok/s, admission and
# compaction counts) persisted by --json
SERVING_SECTIONS = (
    "sec37_serving_continuous_batching",
    "paged_admission_fixed_hbm",
    "compact_decode_sparse_occupancy",
)


def _write_serving_json(path: str, rows: list):
    """Split a flat row list back into its sections by schema and persist."""
    import jax

    schema_of = {
        "engine": "sec37_serving_continuous_batching",
        "layout": "paged_admission_fixed_hbm",
        "occupancy": "compact_decode_sparse_occupancy",
    }
    sections = {name: [] for name in SERVING_SECTIONS}
    for row in rows:
        for key, name in schema_of.items():
            if key in row:
                sections[name].append(row)
                break
    doc = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "unix_time": int(time.time()),
        "sections": sections,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    print(f"serving bench trajectory written to {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller models / fewer points")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: import every bench, run bench_multiclient "
                         "serving + paged-admission + compaction sections on "
                         "tiny configs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist the serving/paged/compaction sections' rows "
                         "(tok/s, admission counts) as one JSON document")
    args = ap.parse_args()

    import importlib
    if args.smoke:
        for name, modname in BENCHES:
            importlib.import_module(modname)       # rot check: must import
        print(f"imported {len(BENCHES)} bench modules OK")
        mod = importlib.import_module("benchmarks.bench_multiclient")
        t0 = time.time()
        rows = mod.run_smoke()
        print(f"bench smoke complete in {time.time() - t0:.1f}s")
        if args.json:
            _write_serving_json(args.json, rows)
        return

    failures = []
    serving_rows = []
    for name, modname in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ({modname}) ===")
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run(quick=args.quick)
            if name == "fig11_12_multiclient" and rows:
                serving_rows = rows
            print(f"=== {name}: done in {time.time() - t0:.1f}s ===")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if args.json and serving_rows:
        _write_serving_json(args.json, serving_rows)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
