"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--smoke]

Emits CSV lines (bench,key=value,...) and writes experiments/bench/*.json.

``--smoke`` is the CI guard against benchmark rot: it imports EVERY bench
module (so stale imports/APIs fail loudly) and runs a few real ticks of
bench_multiclient on tiny configs — the serving comparison plus the
paged-admission-at-fixed-HBM section.
"""
from __future__ import annotations

import argparse
import time
import traceback

BENCHES = [
    ("table2_adapter_configs", "benchmarks.bench_adapter_configs"),
    ("fig9_10_memory", "benchmarks.bench_memory"),
    ("fig11_12_multiclient", "benchmarks.bench_multiclient"),
    ("fig15_17_sharded", "benchmarks.bench_sharded"),
    ("fig18_19_heterogeneous", "benchmarks.bench_heterogeneous"),
    ("fig21_privacy", "benchmarks.bench_privacy"),
    ("fig22_23_mixed", "benchmarks.bench_mixed"),
    ("table4_5_batching", "benchmarks.bench_batching"),
    ("kernels", "benchmarks.bench_kernels"),
    ("ablations", "benchmarks.bench_ablations"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller models / fewer points")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: import every bench, run bench_multiclient "
                         "serving + paged-admission sections on tiny configs")
    args = ap.parse_args()

    import importlib
    if args.smoke:
        for name, modname in BENCHES:
            importlib.import_module(modname)       # rot check: must import
        print(f"imported {len(BENCHES)} bench modules OK")
        mod = importlib.import_module("benchmarks.bench_multiclient")
        t0 = time.time()
        mod.run_smoke()
        print(f"bench smoke complete in {time.time() - t0:.1f}s")
        return

    failures = []
    for name, modname in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ({modname}) ===")
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            mod.run(quick=args.quick)
            print(f"=== {name}: done in {time.time() - t0:.1f}s ===")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
