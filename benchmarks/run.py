"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--smoke]
                                          [--json PATH]

Emits CSV lines (bench,key=value,...) and writes experiments/bench/*.json.

``--smoke`` is the CI guard against benchmark rot: it imports EVERY bench
module (so stale imports/APIs fail loudly) and runs a few real ticks of
bench_multiclient on tiny configs — the serving comparison, the
paged-admission-at-fixed-HBM section, and the compacted-decode occupancy
sweep.

``--json PATH`` persists the serving-side sections (continuous-batching
tok/s, the telemetry-backed ``serving_latency`` tail-latency section,
paged admission counts, compacted-decode speedups) as one combined
JSON document, so the bench trajectory is machine-readable across PRs —
the CI bench-smoke job writes ``BENCH_serving.json`` from the same run,
plus the raw telemetry behind the latency section as ``BENCH_obs.jsonl``
and ``BENCH_obs.prom`` (validated by ``python -m repro.obs --check``).
The TRAINING sections (fine-tuning-as-a-service: shared-base vs dedicated
replicas HBM/step-s, heterogeneous bank mix) are persisted alongside it as
``BENCH_training.json`` in the same directory.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time
import traceback

BENCHES = [
    ("table2_adapter_configs", "benchmarks.bench_adapter_configs"),
    ("fig9_10_memory", "benchmarks.bench_memory"),
    ("fig11_12_multiclient", "benchmarks.bench_multiclient"),
    ("sec5_finetune_service", "benchmarks.bench_finetune_service"),
    ("fig15_17_sharded", "benchmarks.bench_sharded"),
    ("fig18_19_heterogeneous", "benchmarks.bench_heterogeneous"),
    ("fig21_privacy", "benchmarks.bench_privacy"),
    ("fig22_23_mixed", "benchmarks.bench_mixed"),
    ("table4_5_batching", "benchmarks.bench_batching"),
    ("kernels", "benchmarks.bench_kernels"),
    ("ablations", "benchmarks.bench_ablations"),
]

# sections whose rows carry the serving trajectory (tok/s, admission and
# compaction counts) persisted by --json
SERVING_SECTIONS = (
    "sec37_serving_continuous_batching",
    "serving_latency",
    "paged_admission_fixed_hbm",
    "compact_decode_sparse_occupancy",
    "mixed_method_serving",
    "sharded_serving",
)

# training trajectory sections (--json writes them to BENCH_training.json)
TRAINING_SECTIONS = (
    "finetune_service_shared_base",
    "finetune_service_bank_mix",
)

# row-schema key -> section name, across both documents
_SCHEMA_OF = {
    "engine": "sec37_serving_continuous_batching",
    "latency": "serving_latency",
    "layout": "paged_admission_fixed_hbm",
    "occupancy": "compact_decode_sparse_occupancy",
    "mix": "mixed_method_serving",
    "workload": "finetune_service_shared_base",
    "bankmix": "finetune_service_bank_mix",
    "sharded": "sharded_serving",
}


def _write_sections_json(path: str, rows: list, section_names, label: str):
    """Split a flat row list back into its sections by schema and persist."""
    import jax

    sections = {name: [] for name in section_names}
    for row in rows:
        for key, name in _SCHEMA_OF.items():
            if key in row and name in sections:
                sections[name].append(row)
                break
    doc = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "unix_time": int(time.time()),
        "sections": sections,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    print(f"{label} bench trajectory written to {path}")


def _write_serving_json(path: str, rows: list):
    _write_sections_json(path, rows, SERVING_SECTIONS, "serving")


def _training_json_path(serving_path: str) -> str:
    return os.path.join(os.path.dirname(serving_path) or ".",
                        "BENCH_training.json")


def _write_training_json(serving_path: str, rows: list):
    _write_sections_json(_training_json_path(serving_path), rows,
                         TRAINING_SECTIONS, "training")


def _write_obs_exports(serving_path: str):
    """Persist the latency section's raw telemetry next to the --json doc:
    BENCH_obs.jsonl (full metric+event dump) and BENCH_obs.prom (Prometheus
    text exposition) — the artifacts the CI bench-smoke job uploads and
    validates with ``python -m repro.obs --check``."""
    import benchmarks.bench_multiclient as bmc
    obs = bmc.LAST_LATENCY_OBS
    if obs is None:
        return
    from repro.obs import export
    out_dir = os.path.dirname(serving_path) or "."
    jl = os.path.join(out_dir, "BENCH_obs.jsonl")
    pm = os.path.join(out_dir, "BENCH_obs.prom")
    export.write_jsonl(jl, obs)
    export.write_prometheus(pm, obs)
    print(f"telemetry exports written to {jl} and {pm}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller models / fewer points")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: import every bench, run bench_multiclient "
                         "serving + paged-admission + compaction sections on "
                         "tiny configs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist the serving/paged/compaction sections' rows "
                         "(tok/s, admission counts) as one JSON document")
    args = ap.parse_args()

    import importlib
    if args.smoke:
        for name, modname in BENCHES:
            importlib.import_module(modname)       # rot check: must import
        print(f"imported {len(BENCHES)} bench modules OK")
        t0 = time.time()
        rows = importlib.import_module("benchmarks.bench_multiclient").run_smoke()
        train_rows = importlib.import_module(
            "benchmarks.bench_finetune_service").run_smoke()
        # Bucket-coverage smoke (docs/invariants.md pass 3): a short REAL
        # engine workload — serving + live bank admission + finetune churn —
        # under the trace-count guard, so a hot-path recompile outside the
        # declared jit bucket sets fails the smoke job. Deliberately NOT
        # wrapped around the timed sections above: the guard's per-dispatch
        # cache probing is measurable at tiny-config tick times and would
        # distort the tok/s ratios the floors assert on.
        from repro.analysis.runner import run_buckets
        res = run_buckets()
        print(f"trace guard: {res.checked}")
        if not res.ok:
            raise SystemExit("bench smoke hit hot-path trace violations:\n"
                             + "\n".join(str(v) for v in res.violations))
        print(f"bench smoke complete in {time.time() - t0:.1f}s")
        if args.json:
            # sharded_serving rows come from BOTH benches (serving identity
            # from bench_multiclient, finetune identity from
            # bench_finetune_service) — route the combined list so all of
            # them land in the serving document's section
            _write_serving_json(args.json, rows + train_rows)
            _write_training_json(args.json, train_rows)
            _write_obs_exports(args.json)
        return

    failures = []
    serving_rows = []
    training_rows = []
    for name, modname in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ({modname}) ===")
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run(quick=args.quick)
            if name == "fig11_12_multiclient" and rows:
                serving_rows = rows
            if name == "sec5_finetune_service" and rows:
                training_rows = rows
            print(f"=== {name}: done in {time.time() - t0:.1f}s ===")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if args.json and serving_rows:
        _write_serving_json(args.json, serving_rows + training_rows)
        _write_obs_exports(args.json)
    if args.json and training_rows:
        _write_training_json(args.json, training_rows)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
