"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax


def timeit(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall-time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def tree_bytes(tree) -> int:
    import numpy as np
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def residual_bytes(f, *primals) -> int:
    """Bytes captured by the VJP residuals of f — the activation-memory
    proxy used for the Fig 9/10 reproduction (no GPU allocator here)."""
    _, vjp = jax.vjp(f, *primals)
    seen = set()
    total = 0
    for leaf in jax.tree.leaves(vjp):
        if hasattr(leaf, "shape") and id(leaf) not in seen:
            seen.add(id(leaf))
            total += leaf.size * leaf.dtype.itemsize
    return total


def emit(bench: str, rows: list, out_dir: str = "experiments/bench"):
    """Print CSV rows + persist JSON."""
    os.makedirs(out_dir, exist_ok=True)
    for r in rows:
        print(f"{bench}," + ",".join(str(v) for v in r.values()))
    with open(os.path.join(out_dir, f"{bench}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return rows
