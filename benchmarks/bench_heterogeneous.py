"""Fig 18/19/20: heterogeneous placement.

Fig 19 (single request, growing context): all-GPU vs GPU+offloaded-cache vs
Symbiosis hetero (client on CPU). Analytic v5e/PCIe/host model
(serving.kvcache.decode_token_cost) — reproduces the paper's >=32K
crossover and the all-GPU OOM wall.
Fig 18 (hetero fine-tuning): client-side vs base-side compute split measured
on this host, showing the client share is small enough to park on a weak
device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import AdapterConfig, TrainConfig
from repro.configs import get_config
from repro.serving.kvcache import decode_token_cost, cache_bytes
from benchmarks.common import emit, timeit

CONTEXTS = [2_048, 8_192, 16_384, 32_768, 65_536, 131_072, 262_144, 524_288]


def run(quick: bool = False):
    cfg = get_config("symbiosis-llama2-13b")   # paper uses Llama2-7B/13B
    rows = []
    crossover = None
    for ctx in (CONTEXTS[:5] if quick else CONTEXTS):
        costs = {p: decode_token_cost(cfg, ctx, placement=p)
                 for p in ("gpu", "gpu_offload", "hetero")}
        row = {"fig": "19", "context": ctx,
               "kv_cache_GB": round(cache_bytes(cfg, ctx) / 1e9, 1)}
        for p, c in costs.items():
            row[f"{p}_s_per_tok"] = (round(c.total, 4)
                                     if c.total != float("inf") else "OOM")
        if (crossover is None
                and costs["hetero"].total < costs["gpu_offload"].total):
            crossover = ctx
        rows.append(row)
    rows.append({"fig": "19", "context": "crossover_at",
                 "kv_cache_GB": crossover,
                 "gpu_s_per_tok": "-", "gpu_offload_s_per_tok": "-",
                 "hetero_s_per_tok": "paper: >=32K"})

    # Fig 18 proxy: measure client-side vs base-side compute split
    rcfg = cfg.reduced(n_layers=2, d_model=256 if quick else 512)
    acfg = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))
    from repro.core import symbiosis
    base, bank, opt = symbiosis.init_system(rcfg, acfg, 2, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 2, 128), jnp.int32),
             "labels": jnp.ones((2, 2, 128), jnp.int32)}
    full = jax.jit(symbiosis.make_multi_client_train_step(
        rcfg, acfg, TrainConfig(n_clients=2, remat=False)))
    t_full = timeit(lambda: full(base, bank, opt, batch, 0), reps=3)
    # base-only: forward through frozen matmuls alone (adapterless, no grad)
    from repro.models import get_model
    model = get_model(rcfg)
    fwd = jax.jit(lambda b: model.forward(base, b, remat=False)[0])
    t_base = timeit(lambda: fwd({"tokens": batch["tokens"][0]}), reps=3)
    rows.append({"fig": "18", "context": "base_vs_client_split",
                 "kv_cache_GB": "-",
                 "gpu_s_per_tok": round(t_base, 4),
                 "gpu_offload_s_per_tok": round(t_full, 4),
                 "hetero_s_per_tok":
                     f"client share ~{100 * max(0.0, 1 - 2 * t_base / t_full):.0f}%"})
    return emit("fig18_19_heterogeneous", rows)


if __name__ == "__main__":
    run()
