"""Mixed-PEFT serving banks (ISSUE 5 tentpole).

One ServingEngine holds several banks keyed by AdapterConfig — LoRA, IA3
and prefix clients served CONCURRENTLY over one frozen base — and a single
compacted decode tick carries per-row methods. The contract: every
client's output in a mixed batch is BYTE-identical to serving that client
alone through a single-method engine (its "solo single-method run"),
across tick policies, occupancies and mid-stream churn.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, ServeConfig, DENSE, MOE, VLM, HYBRID, ENCDEC
from repro.core import adapters as ad_lib
from repro.models import get_model
from repro.serving.engine import ServingEngine, Request
from repro.serving.router import PlacementRouter, Slot
from repro.serving import kvcache
from conftest import tiny

METHOD_CFGS = [
    AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v")),
    AdapterConfig(method="ia3", targets=("k", "v", "down")),
    AdapterConfig(method="prefix", targets=("q", "v"), n_prefix=4),
]


def _system(arch=DENSE, clients_per_bank=1, seed=0, page_block=8, max_seq=48):
    cfg = tiny(arch)
    scfg = ServeConfig(n_clients=3 * clients_per_bank, max_seq=max_seq,
                      page_block=page_block)
    base = get_model(cfg).init_params(jax.random.PRNGKey(seed))
    banks = [ad_lib.init_client_bank(cfg, a, clients_per_bank,
                                     jax.random.PRNGKey(seed + 5 + i))
             for i, a in enumerate(METHOD_CFGS)]
    return cfg, scfg, base, banks


def _solo_reference(cfg, scfg, base, banks, req, max_b):
    """Serve one request alone through a fresh SINGLE-method engine holding
    only that client's adapter — the byte-identity oracle."""
    cpb = jax.tree.leaves(banks[0])[0].shape[0]    # clients per bank
    m, local = req.client_id // cpb, req.client_id % cpb
    one_bank = jax.tree.map(lambda x: x[local:local + 1], banks[m])
    scfg_solo = dataclasses.replace(scfg, n_clients=1)
    eng = ServingEngine(cfg, METHOD_CFGS[m], scfg_solo, base, one_bank,
                        max_batch_per_client=max_b)
    solo = Request(client_id=0, prompt=req.prompt.copy(),
                   max_new_tokens=req.max_new_tokens, sampling=req.sampling)
    eng.submit(solo)
    (done,) = eng.run()
    return done.generated


class TestMixedMethodEngine:
    """Engine-level byte-identity of mixed batches to solo runs."""

    # one client per bank (3 clients, global ids 0=lora 1=ia3 2=prefix);
    # occupancies over a 3-client x 2-slot bank
    OCCUPANCIES = {
        "one_slot": [(1, 1, 5, 6, 0)],                       # a lone IA3 row
        "bucket_boundary": [(0, 2, 5, 6, 0), (1, 2, 6, 6, 0)],   # 4 rows
        "full_bank": [(c, 2, 4 + c, 6, 0) for c in range(3)],    # 6 rows
        "churn": [(0, 1, 4, 3, 0), (1, 2, 5, 8, 1), (2, 1, 5, 4, 2),
                  (0, 1, 6, 2, 3), (2, 2, 4, 5, 6)],
    }

    def _reqs(self, cfg, rng, spec):
        return [Request(client_id=c,
                        prompt=rng.integers(0, cfg.vocab, (rows, S)).astype(np.int32),
                        max_new_tokens=new, arrive_tick=at)
                for (c, rows, S, new, at) in spec]

    def _serve_mixed(self, cfg, scfg, base, banks, reqs, *, policy, max_b=2):
        eng = ServingEngine(cfg, METHOD_CFGS, scfg, base, banks,
                            max_batch_per_client=max_b, policy=policy)
        for r in reqs:
            eng.submit(r)
        return eng, eng.run()

    @pytest.mark.parametrize("occupancy", list(OCCUPANCIES))
    def test_mixed_matches_solo(self, occupancy):
        self._case(occupancy, "opportunistic")

    @pytest.mark.tier2
    @pytest.mark.parametrize("policy", ["lockstep", "nolockstep"])
    @pytest.mark.parametrize("occupancy", list(OCCUPANCIES))
    def test_mixed_matches_solo_policies(self, occupancy, policy):
        self._case(occupancy, policy)

    def _case(self, occupancy, policy, arch=DENSE):
        cfg, scfg, base, banks = _system(arch)
        rng = np.random.default_rng(11)
        reqs = self._reqs(cfg, rng, self.OCCUPANCIES[occupancy])
        eng, done = self._serve_mixed(cfg, scfg, base, banks, reqs,
                                      policy=policy)
        assert len(done) == len(reqs)
        # one tick carried several methods whenever >1 bank was active
        for r in done:
            ref = _solo_reference(cfg, scfg, base, banks, r, 2)
            np.testing.assert_array_equal(
                r.generated, ref,
                err_msg=f"{occupancy}/{policy}: client {r.client_id} "
                        f"(method {METHOD_CFGS[r.client_id].method}) "
                        f"diverged from its solo single-method run")
        # allocator + activity state drained clean
        assert not any(eng._active_slots)
        assert not eng._active_mask.any()

    def test_three_methods_share_one_tick(self):
        """All three banks decode in the SAME compacted tick (not routed to
        per-bank ticks): with one request per bank all due at tick 0, every
        decode tick gathers 3 rows of 3 different methods."""
        cfg, scfg, base, banks = _system()
        rng = np.random.default_rng(3)
        eng = ServingEngine(cfg, METHOD_CFGS, scfg, base, banks,
                            max_batch_per_client=2)
        for c in range(3):
            eng.submit(Request(client_id=c,
                               prompt=rng.integers(0, cfg.vocab, (1, 5)).astype(np.int32),
                               max_new_tokens=5))
        done = eng.run()
        assert len(done) == 3
        # 3 active rows per decode tick, 4 ticks (first token from prefill)
        assert eng.stats["compact_rows"] == 3 * 4
        assert eng.stats["ticks"] == 4

    def test_mixed_requires_paged_layout(self):
        cfg, scfg, base, banks = _system()
        dense_scfg = dataclasses.replace(scfg, page_block=0)
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(cfg, METHOD_CFGS, dense_scfg, base, banks)
        with pytest.raises(ValueError, match="compacted"):
            ServingEngine(cfg, METHOD_CFGS, scfg, base, banks,
                          compact_decode=False)

    def test_router_charges_each_bank(self):
        """An attached router is charged every bank's resident adapter
        bytes at construction and refunded by release_banks()."""
        cfg, scfg, base, banks = _system()
        bank_bytes = [ad_lib.adapter_bytes(cfg, a)[1] for a in METHOD_CFGS]
        budget = kvcache.cache_bytes(cfg, scfg.max_seq, 6) + sum(bank_bytes) * 2
        router = PlacementRouter(cfg, [Slot(0, free_hbm=budget)],
                                 host_free_bytes=0)
        eng = ServingEngine(cfg, METHOD_CFGS, scfg, base, banks,
                            max_batch_per_client=2, router=router)
        assert len(eng._bank_placements) == 3
        assert router.slots[0].free_hbm == pytest.approx(
            budget - sum(bank_bytes))
        eng.release_banks()
        assert router.slots[0].free_hbm == pytest.approx(budget)

    def test_failed_bank_charge_refunds_committed_banks(self):
        """If a later bank's route_bank charge doesn't fit, the charges
        already committed for earlier banks are refunded — a failed engine
        construction must not leak router capacity."""
        cfg, scfg, base, banks = _system()
        bank_bytes = [ad_lib.adapter_bytes(cfg, a)[1] for a in METHOD_CFGS]
        budget = sum(bank_bytes[:2]) + bank_bytes[2] * 0.5   # 3rd won't fit
        router = PlacementRouter(cfg, [Slot(0, free_hbm=budget)],
                                 host_free_bytes=0)
        with pytest.raises(RuntimeError, match="serving-bank"):
            ServingEngine(cfg, METHOD_CFGS, scfg, base, banks,
                          max_batch_per_client=2, router=router)
        assert router.slots[0].free_hbm == pytest.approx(budget)

    def test_mixed_rank_lora_banks(self):
        """Two LoRA banks with different ranks are separate banks in one
        engine (heterogeneity isn't only across methods)."""
        cfg = tiny(DENSE)
        scfg = ServeConfig(n_clients=2, max_seq=48, page_block=8)
        base = get_model(cfg).init_params(jax.random.PRNGKey(0))
        acfgs = [AdapterConfig(method="lora", rank=2, alpha=4.0, targets=("q", "v")),
                 AdapterConfig(method="lora", rank=8, alpha=16.0, targets=("q", "v"))]
        banks = [ad_lib.init_client_bank(cfg, a, 1, jax.random.PRNGKey(7 + i))
                 for i, a in enumerate(acfgs)]
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32)
                   for _ in range(2)]
        eng = ServingEngine(cfg, acfgs, scfg, base, banks,
                            max_batch_per_client=2)
        for c in range(2):
            eng.submit(Request(client_id=c, prompt=prompts[c].copy(),
                               max_new_tokens=5))
        done = {r.client_id: r for r in eng.run()}
        for c in range(2):
            solo = ServingEngine(cfg, acfgs[c],
                                 dataclasses.replace(scfg, n_clients=1),
                                 base, banks[c], max_batch_per_client=2)
            solo.submit(Request(client_id=0, prompt=prompts[c].copy(),
                                max_new_tokens=5))
            (ref,) = solo.run()
            np.testing.assert_array_equal(done[c].generated, ref.generated)


@pytest.mark.tier2
@pytest.mark.parametrize("arch", [DENSE, MOE, VLM, HYBRID, ENCDEC])
@pytest.mark.parametrize("policy", ["opportunistic", "lockstep"])
def test_mixed_method_family_sweep(arch, policy):
    """CI tier-2 sweep (ISSUE 5 satellite): methods x families x policies.
    Every family serves a lora+ia3+prefix mix in one engine; every client
    matches its solo single-method run byte-for-byte. (Enc-dec requests
    carry no frames through the engine Request type yet — the engine path
    uses zero frames for both mixed and solo, which keeps the comparison
    valid.)"""
    if arch == ENCDEC:
        pytest.skip("Request carries tokens only; enc-dec needs frame "
                    "extras threaded through the engine (ROADMAP item)")
    cfg, scfg, base, banks = _system(arch)
    rng = np.random.default_rng(13)
    reqs = [Request(client_id=c,
                    prompt=rng.integers(0, cfg.vocab, (1, 4 + c)).astype(np.int32),
                    max_new_tokens=4 + c % 2, arrive_tick=c)
            for c in range(3)]
    eng = ServingEngine(cfg, METHOD_CFGS, scfg, base, banks,
                        max_batch_per_client=2, policy=policy)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    for r in done:
        ref = _solo_reference(cfg, scfg, base, banks, r, 2)
        np.testing.assert_array_equal(
            r.generated, ref,
            err_msg=f"{arch}/{policy}: client {r.client_id} diverged")
