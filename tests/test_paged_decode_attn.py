"""Table-aware paged decode attention: byte-identity contracts (ISSUE 3).

Three layers of exactness, all BIT-exact (np.testing.assert_array_equal):

1. pallas kernel (interpret) == jnp stream twin — the same blocked math
   with and without the Pallas grid machinery;
2. in-place table reads == the gather reference (``via_gather=True``:
   gather_paged_kv materializes the dense view, then the identical blocked
   math runs over it with an identity table);
3. a client-vmapped call == the flat call on concatenated pools (the
   custom_vmap rule that makes masked and compacted decode the same
   computation).

Plus tolerance checks against the un-blocked full-softmax oracle
(decode_attn_ref), and the analogous contracts for the SGMV kernel. No
hypothesis dependency — these run everywhere tier-1 runs.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.decode_attn import (
    paged_decode_attn_pallas, paged_decode_attn_quant_pallas,
    paged_decode_attn_stream, paged_decode_attn_quant_stream)
from repro.kernels.decode_attn.ops import decode_attn, _dense_block_kv
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.kernels.sgmv.ops import sgmv
from repro.kernels.sgmv.sgmv import sgmv_pallas_safe, sgmv_stream


def _paged_case(B, K, G, hd, P, blk, nb, seed=0, quant=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, K, G, hd))
    if quant:
        pk = jax.random.randint(ks[1], (P, blk, K, hd), -127, 128).astype(jnp.int8)
        pv = jax.random.randint(ks[2], (P, blk, K, hd), -127, 128).astype(jnp.int8)
        kss = jax.random.uniform(ks[3], (P, blk, K, 1), minval=0.005, maxval=0.03)
        vss = jax.random.uniform(ks[4], (P, blk, K, 1), minval=0.005, maxval=0.03)
    else:
        pk = jax.random.normal(ks[1], (P, blk, K, hd))
        pv = jax.random.normal(ks[2], (P, blk, K, hd))
        kss = vss = None
    # scattered page assignment: rows' pages are arbitrary pool entries
    tbl = jax.random.permutation(ks[5], P)[:B * nb].reshape(B, nb).astype(jnp.int32)
    pos = jax.random.randint(jax.random.PRNGKey(seed + 7), (B,), 0, nb * blk)
    return q, pk, pv, kss, vss, tbl, pos


# (B, K, G, hd, P, blk, nb, window): standard / non-dividing page count /
# single-page rows / sliding window
CASES = [(3, 2, 2, 32, 16, 8, 4, 0),
         (2, 1, 4, 64, 11, 16, 3, 0),
         (1, 2, 2, 32, 4, 8, 1, 0),
         (3, 2, 2, 32, 16, 8, 4, 12)]


class TestPagedKernelContracts:
    @pytest.mark.parametrize("case", CASES)
    def test_pallas_interpret_equals_stream(self, case):
        B, K, G, hd, P, blk, nb, w = case
        q, pk, pv, _, _, tbl, pos = _paged_case(B, K, G, hd, P, blk, nb)
        a = jax.jit(functools.partial(paged_decode_attn_pallas, window=w,
                                      interpret=True))(q, pk, pv, tbl, pos)
        b = jax.jit(functools.partial(paged_decode_attn_stream, window=w))(
            q, pk, pv, tbl, pos)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("case", CASES)
    def test_quant_pallas_interpret_equals_stream(self, case):
        B, K, G, hd, P, blk, nb, w = case
        q, pk, pv, kss, vss, tbl, pos = _paged_case(B, K, G, hd, P, blk, nb,
                                                    quant=True)
        a = jax.jit(functools.partial(paged_decode_attn_quant_pallas, window=w,
                                      interpret=True))(q, pk, kss, pv, vss, tbl, pos)
        b = jax.jit(functools.partial(paged_decode_attn_quant_stream, window=w))(
            q, pk, kss, pv, vss, tbl, pos)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("quant", [False, True])
    def test_table_read_equals_gather_reference(self, case, quant):
        """In-place page reads == gather-then-same-math (the oracle that
        replaced the PR-2 in-step gather)."""
        B, K, G, hd, P, blk, nb, w = case
        q, pk, pv, kss, vss, tbl, pos = _paged_case(B, K, G, hd, P, blk, nb,
                                                    quant=quant)
        kw = {"k_scale": kss, "v_scale": vss} if quant else {}
        direct = decode_attn(q, pk, pv, pos, block_tbl=tbl, window=w, **kw)
        oracle = decode_attn(q, pk, pv, pos, block_tbl=tbl, window=w,
                             via_gather=True, **kw)
        np.testing.assert_array_equal(np.asarray(direct), np.asarray(oracle))

    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("quant", [False, True])
    def test_matches_full_softmax_oracle(self, case, quant):
        B, K, G, hd, P, blk, nb, w = case
        q, pk, pv, kss, vss, tbl, pos = _paged_case(B, K, G, hd, P, blk, nb,
                                                    quant=quant)
        kw = {"k_scale": kss, "v_scale": vss} if quant else {}
        y = decode_attn(q, pk, pv, pos, block_tbl=tbl, window=w, **kw)
        yr = decode_attn_ref(q, pk, pv, pos, window=w, block_tbl=tbl, **kw)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)

    def test_vmapped_clients_equal_flat_pool_concat(self):
        """The custom_vmap rule: a bank of clients IS one client with more
        pages — the masked-vs-compacted byte-identity foundation."""
        C, B, K, G, hd, P, blk, nb = 3, 2, 2, 2, 32, 8, 8, 4
        qs, pks, pvs, tbls, poss = [], [], [], [], []
        for c in range(C):
            q, pk, pv, _, _, tbl, pos = _paged_case(B, K, G, hd, P, blk, nb,
                                                    seed=c)
            qs.append(q), pks.append(pk), pvs.append(pv)
            tbls.append(tbl), poss.append(pos)
        qs, pks, pvs, tbls, poss = map(jnp.stack, (qs, pks, pvs, tbls, poss))
        vm = jax.jit(jax.vmap(
            lambda q, k, v, t, p: decode_attn(q, k, v, p, block_tbl=t)))(
            qs, pks, pvs, tbls, poss)
        flat = jax.jit(lambda q, k, v, t, p: decode_attn(q, k, v, p, block_tbl=t))(
            qs.reshape(C * B, K, G, hd), pks.reshape(C * P, blk, K, hd),
            pvs.reshape(C * P, blk, K, hd),
            (tbls + jnp.arange(C)[:, None, None] * P).reshape(C * B, nb),
            poss.reshape(C * B))
        np.testing.assert_array_equal(np.asarray(vm.reshape(C * B, K, G, hd)),
                                      np.asarray(flat))


class TestDenseBlockPick:
    def test_divisor_avoids_pads(self):
        """T=300 with block 128: pick 100 (largest divisor in (64, 128]) —
        pads never materialize for mildly non-dividing depths."""
        assert _dense_block_kv(300, 128) == (100, 0)
        assert _dense_block_kv(512, 128) == (128, 0)
        assert _dense_block_kv(48, 512) == (48, 0)
        bkv, pad = _dense_block_kv(127, 64)   # prime-ish: falls back to pads
        assert pad == (-127) % bkv and pad > 0

    def test_nondividing_depth_matches_ref(self):
        B, K, G, hd, T = 2, 2, 2, 32, 300
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, K, G, hd))
        k = jax.random.normal(ks[1], (B, T, K, hd))
        v = jax.random.normal(ks[2], (B, T, K, hd))
        pos = jnp.array([100, 299], jnp.int32)
        y = decode_attn(q, k, v, pos, block_kv=128)
        yr = decode_attn_ref(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)


class TestSgmvContracts:
    def test_pallas_interpret_equals_stream(self):
        T, din, r, dout, n = 256, 64, 8, 128, 3
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (T, din))
        A = jax.random.normal(ks[1], (n, din, r)) * 0.3
        B = jax.random.normal(ks[2], (n, r, dout)) * 0.3
        ids = jnp.array([0, -1], jnp.int32)
        a = jax.jit(lambda *t: sgmv_pallas_safe(*t, block_t=128, block_d=128,
                                                scale=0.5, interpret=True))(
            x, A, B, ids)
        b = jax.jit(lambda *t: sgmv_stream(*t, block_t=128, scale=0.5))(
            x, A, B, ids)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_per_row_equals_vmapped_lora(self):
        """block_t=1 SGMV == the per-client vmapped LoRA delta, bit for bit
        — the compacted decode's adapter exactness contract."""
        C, n, din, r, dout, scale = 3, 5, 64, 4, 96, 2.0
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        A = jax.random.normal(ks[0], (C, din, r)) * 0.3
        B = jax.random.normal(ks[1], (C, r, dout)) * 0.3
        xb = jax.random.normal(ks[2], (C, 2, 1, din))
        rc = jnp.array([0, 2, 1, 2, 0], jnp.int32)
        sid = jnp.array([0, 1, 0, 0, 1], jnp.int32)
        masked = jax.jit(jax.vmap(lambda x1, A1, B1: scale * jnp.einsum(
            "...r,ro->...o", jnp.einsum("...i,ir->...r", x1, A1), B1)))(xb, A, B)
        want = np.asarray(masked)[np.asarray(rc), np.asarray(sid)].reshape(n, dout)
        got = jax.jit(lambda x_, A_, B_, i_: sgmv(x_, A_, B_, i_, block_t=1,
                                                  scale=scale))(
            xb[rc, sid].reshape(n, din), A, B, rc)
        np.testing.assert_array_equal(np.asarray(got), want)
