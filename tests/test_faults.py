"""Fault containment (ISSUE 8, docs/robustness.md).

The contract under test: a faulty tenant NEVER takes the engine or its
neighbours down. Transient faults (stream hiccups, allocation failures)
retry from clean state and recover bitwise; fatal faults (non-finite
loss/grads/logits) quarantine the tenant — checkpoint, retire, release
every page and router charge — while every survivor's committed state
stays byte-identical to a run where the faulty tenant was never admitted
after its last clean tick. Engine-level kill -> restore resumes every
tenant bitwise; corrupt checkpoint files are rejected by CRC with
last-good fallback. The deterministic adversary lives in ``repro.faults``;
the larger seeded sweep is ``repro.faults.chaos`` (-m chaos)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, load_engine_state,
                              save_engine_state)
from repro.config import AdapterConfig, FinetuneConfig, ServeConfig
from repro.core import symbiosis
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.faults.audit import check_conservation
from repro.faults.health import (HealthPolicy, HealthRecord, HealthState,
                                 classify)
from repro.faults.plan import (AllocationFault, AllocHook, FaultyStream,
                               NonFiniteFault, StreamError,
                               corrupt_flip, corrupt_truncate)
from repro.serving.engine import Request, ServingEngine
from repro.training import FinetuneEngine, FinetuneJob, make_job_stream
from conftest import tiny

LORA = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))


def _serving(cfg, base, bank, **kw):
    scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8, pool_pages=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return ServingEngine(cfg, LORA, scfg, base, bank,
                             max_batch_per_client=2, debug=True, **kw)


def _prompts(cfg, per_client=2, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)
             for _ in range(per_client)] for _ in range(2)]


def _submit_all(eng, prompts, max_new=3):
    for c, ps in enumerate(prompts):
        for p in ps:
            eng.submit(Request(client_id=c, prompt=p.copy(),
                               max_new_tokens=max_new, arrive_tick=0))


def _job(cfg, i, schedule=None, steps=4):
    stream = make_job_stream(cfg, 2, 8, seed=i)
    if schedule is not None:
        stream = FaultyStream(stream, schedule)
    return FinetuneJob(acfg=LORA, data=stream, batch_size=2, seq_len=8,
                       steps=steps, seed=i, name=f"j{i}")


def _assert_same_result(a, b):
    for x, y in zip(jax.tree.leaves((a.result.adapter, a.result.opt)),
                    jax.tree.leaves((b.result.adapter, b.result.opt))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{a.name} state diverged")
    np.testing.assert_array_equal(a.losses, b.losses,
                                  err_msg=f"{a.name} losses diverged")


# ---------------------------------------------------------------------------
# health state machine (pure host state)
# ---------------------------------------------------------------------------

def test_health_state_machine_and_backoff():
    pol = HealthPolicy(max_retries=3, backoff_base=1, max_backoff=4)
    rec = HealthRecord()
    assert rec.eligible(0)
    assert rec.trip(0, "hiccup", pol) == "retry"
    assert rec.state is HealthState.SUSPECT
    assert not rec.eligible(0) and rec.eligible(1)      # 1-tick backoff
    assert rec.trip(1, "hiccup", pol) == "retry"
    assert rec.next_eligible_tick == 1 + 2              # doubled
    assert rec.trip(3, "hiccup", pol) == "retry"
    assert rec.next_eligible_tick == 3 + 4              # capped at max_backoff
    assert rec.trip(7, "hiccup", pol) == "quarantine"   # retries exhausted
    assert rec.state is HealthState.QUARANTINED and not rec.active
    assert rec.total_faults == 4
    assert pol.backoff(10) == 4                         # ceiling holds

    rec2 = HealthRecord()
    rec2.trip(0, "hiccup", pol)
    rec2.ok(1)
    assert rec2.state is HealthState.RESUMED and rec2.failures == 0
    rec2.ok(2)
    assert rec2.state is HealthState.HEALTHY


def test_fault_classification():
    assert classify(StreamError("x")) == "transient"
    assert classify(AllocationFault("x")) == "transient"
    assert classify(OSError("io hiccup")) == "transient"
    assert classify(NonFiniteFault("nan")) == "fatal"
    # programming errors must not retry-loop
    assert classify(ValueError("bug")) == "fatal"


# ---------------------------------------------------------------------------
# transactional admission (the leak-regression tests)
# ---------------------------------------------------------------------------

def test_serving_admission_fault_rolls_back_no_page_leak(key):
    """Regression: an allocation fault mid-admission must roll back pages,
    table rows, reservations and the router charge atomically, then retry
    the SAME admission from clean state — bitwise. Pre-transactional code
    leaked the already-popped pages (and had no injection hook at all)."""
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    prompts = _prompts(cfg)
    hook = AllocHook({0})
    eng = _serving(cfg, base, bank, fault_hook=hook)
    clean = _serving(cfg, base, bank)
    _submit_all(eng, prompts)
    _submit_all(clean, prompts)
    done, ref = eng.run(), clean.run()
    assert hook.fired == 1
    assert eng.stats["faults"] >= 1
    assert not check_conservation(eng)
    ref_of = {r.prompt.tobytes(): r.generated for r in ref}
    assert len(done) == len(ref)
    for r in done:
        assert r.status == "ok"
        np.testing.assert_array_equal(r.generated, ref_of[r.prompt.tobytes()])


def test_train_admission_fault_retries_bitwise(key):
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 2, key)
    hook = AllocHook({0})
    # spec form: BankSpec.capacity pre-reserves the stacked bank, so the
    # fault-delayed second admission doesn't grow it mid-run (growth would
    # re-trace the R=1 bucket at the new capacity)
    spec = EngineSpec(cfg=cfg, banks=(BankSpec("jobs", LORA, capacity=2),),
                      finetune=FinetuneConfig(max_jobs=2))
    eng = FinetuneEngine(spec, base, debug=True, fault_hook=hook)
    clean = FinetuneEngine(spec, base, debug=True)
    for i in range(2):
        eng.submit(_job(cfg, i))
        clean.submit(_job(cfg, i))
    done = {j.name: j for j in eng.run()}
    ref = {j.name: j for j in clean.run()}
    assert hook.fired == 1
    assert not check_conservation(eng)
    assert set(done) == set(ref)
    for name, j in done.items():
        assert j.status == "finished"
        _assert_same_result(ref[name], j)


# ---------------------------------------------------------------------------
# stream faults against the fine-tuning service
# ---------------------------------------------------------------------------

def test_stream_exhaustion_finished_early(key):
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 1, key)
    eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=1),
                         debug=True)
    j = _job(cfg, 0, schedule={2: "stream_end"}, steps=5)
    eng.submit(j)
    done = eng.run()
    assert done and done[0] is j
    assert j.status == "finished_early"
    assert len(j.losses) == 2                  # steps 0 and 1 committed
    assert j.result is not None and j.result.step == 2
    assert eng.stats["finished_early"] == 1
    assert not check_conservation(eng)


def test_stream_error_transient_recovery_bitwise(key):
    """A transient stream error backs the job off one tick; the retry draws
    the SAME step's batch from the clean cursor, so the finished job is
    bit-identical to the never-faulted run."""
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 1, key)
    out = {}
    for tag, sched in (("clean", {}), ("faulted", {1: "stream_error"})):
        eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=1),
                             debug=True)
        j = _job(cfg, 0, schedule=sched, steps=3)
        eng.submit(j)
        eng.run()
        assert j.status == "finished"
        out[tag] = j
    assert out["faulted"].health.total_faults == 1
    assert any(s == HealthState.SUSPECT.value
               for _, s, _ in out["faulted"].health.history)
    _assert_same_result(out["clean"], out["faulted"])


def test_nan_batch_quarantines_victim_survivor_bitwise(key):
    """Non-finite loss/grads (caught by the in-step probe) are fatal: the
    poisoned commit is dropped, the victim quarantines, and the survivor's
    full trajectory stays bitwise equal to the clean two-job run."""
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 2, key)
    runs = {}
    for tag, sched0 in (("clean", {}), ("faulted", {1: "nan_batch"})):
        eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                             debug=True)
        jobs = [_job(cfg, 0, schedule=sched0), _job(cfg, 1, schedule={})]
        for j in jobs:
            eng.submit(j)
        eng.run()
        assert not check_conservation(eng)
        runs[tag] = jobs
    victim, survivor = runs["faulted"]
    clean_victim, clean_survivor = runs["clean"]
    assert victim.status == "quarantined"
    assert victim.health.state is HealthState.QUARANTINED
    # only the pre-fault prefix ever committed, and it committed bitwise
    np.testing.assert_array_equal(victim.losses, clean_victim.losses[:1])
    assert survivor.status == "finished"
    _assert_same_result(clean_survivor, survivor)


# ---------------------------------------------------------------------------
# serving quarantine
# ---------------------------------------------------------------------------

def test_nan_adapter_quarantine_and_client_ban(key):
    """A poisoned adapter produces non-finite logits: each of its requests
    is quarantined (slots/pages/charges freed), the client is refused
    admission after repeated faults, and the OTHER client's token streams
    stay bitwise equal to the clean run."""
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    bad = jax.tree.map(lambda p: p.at[0].set(jnp.nan), bank)
    prompts = _prompts(cfg, per_client=3)
    clean_eng = _serving(cfg, base, bank)
    eng = _serving(cfg, base, bad)
    _submit_all(clean_eng, prompts)
    _submit_all(eng, prompts)
    ref = {r.prompt.tobytes(): r.generated for r in clean_eng.run()
           if r.client_id == 1}
    done = eng.run()
    mine = [r for r in done if r.client_id == 0]
    other = [r for r in done if r.client_id == 1]
    assert len(mine) == 3 and len(other) == 3
    assert all(r.status in ("quarantined", "rejected") for r in mine)
    assert any(r.status == "rejected" for r in mine)    # banned mid-run
    assert 0 in eng._quarantined_clients
    assert eng.stats["quarantined_clients"] == 1
    for r in other:
        assert r.status == "ok"
        np.testing.assert_array_equal(r.generated, ref[r.prompt.tobytes()])
    assert not check_conservation(eng)


def test_quarantine_visible_through_client_event_feed(key):
    """ISSUE 9 acceptance: the fault episode from the quarantine test above
    is observable by the CLIENT through ``drain_events`` — the banned tenant
    sees its quarantine/reject events, the healthy tenant sees only a clean
    admit/retire stream, and finished records carry ``fault_history``."""
    from repro.obs import Obs
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    bad = jax.tree.map(lambda p: p.at[0].set(jnp.nan), bank)
    eng = _serving(cfg, base, bad, obs=Obs())
    _submit_all(eng, _prompts(cfg, per_client=3))
    done = eng.run()
    mine = eng.drain_events(client=0)
    kinds = [e.kind for e in mine]
    assert "quarantine" in kinds
    assert "reject" in kinds                     # banned mid-run
    assert all(e.tenant == 0 for e in mine)
    q = next(e for e in mine if e.kind == "quarantine")
    assert q.engine == "serving" and q.seq >= 0
    healthy = eng.drain_events(client=1)
    assert {e.kind for e in healthy} <= {"admit", "retire", "backoff",
                                         "retry"}
    assert "quarantine" not in {e.kind for e in healthy}
    for r in done:
        if r.client_id == 0 and r.status in ("quarantined", "rejected"):
            assert r.fault_history         # surfaced on the record itself
        if r.client_id == 1:
            assert r.fault_history == []
    # the feed is destructive: a second drain is empty
    assert eng.drain_events(client=0) == []


def test_conservation_audit_detects_page_leak(key):
    """The audit is not vacuous: a deliberately leaked page is reported."""
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    eng = _serving(cfg, base, bank)
    eng._free_pages[0].pop()
    errs = check_conservation(eng)
    assert errs and "not conserved" in errs[0]


# ---------------------------------------------------------------------------
# engine-level crash recovery
# ---------------------------------------------------------------------------

def test_engine_checkpoint_crc_last_good_fallback(tmp_path):
    d = str(tmp_path)
    p0 = save_engine_state(d, {"v": 0})
    p1 = save_engine_state(d, {"v": 1})
    assert load_engine_state(d) == (1, {"v": 1})
    corrupt_flip(p1, seed=3)
    assert load_engine_state(d) == (0, {"v": 0})        # CRC rejects, falls back
    p2 = save_engine_state(d, {"v": 2})
    corrupt_truncate(p2)
    assert load_engine_state(d) == (0, {"v": 0})        # truncation rejected too
    corrupt_truncate(p0, keep=4)
    with pytest.raises(CheckpointCorruptError):
        load_engine_state(d)                            # nothing valid left


def test_ckpt_write_fault_last_good_blob_wins(tmp_path):
    """ckpt_write kind (PR 8 residual): an injected IO error or torn write
    during ``save_engine_state`` never disturbs the last good blob."""
    import os
    from repro.checkpoint import set_write_fault_hook
    from repro.faults.plan import CkptWriteFault, CkptWriteHook, FaultPlan

    d = str(tmp_path)
    save_engine_state(d, {"v": 0})                       # seq 0, good
    # ENOSPC/EIO shape: the write raises before any byte lands
    set_write_fault_hook(CkptWriteHook(at={0}))
    try:
        with pytest.raises(CkptWriteFault):
            save_engine_state(d, {"v": 1})
    finally:
        set_write_fault_hook(None)
    assert load_engine_state(d) == (0, {"v": 0})
    # torn-write shape: a truncated frame lands AT the final path...
    hook = CkptWriteHook(at={0}, mode="torn")
    set_write_fault_hook(hook)
    try:
        with pytest.raises(CkptWriteFault):
            save_engine_state(d, {"v": 2})
    finally:
        set_write_fault_hook(None)
    assert hook.fired == 1
    assert os.path.exists(os.path.join(d, "engine_00000001.ckpt"))
    # ...and restore rejects it, falling back to the last good blob
    assert load_engine_state(d) == (0, {"v": 0})
    # a later clean write becomes the newest valid snapshot again
    save_engine_state(d, {"v": 3})
    assert load_engine_state(d)[1] == {"v": 3}
    # the kind is plannable like every other
    plan = FaultPlan(0, n_tenants=2, n_faults=7)
    assert plan.counts().get("ckpt_write") == 1
    assert plan.ckpt_write_schedule()


def test_quarantine_ckpt_write_fault_does_not_block_retirement(key, tmp_path):
    """A failing quarantine checkpoint is best-effort by contract: the
    victim still retires (pages + charges released), the failure is
    recorded on its health history, and survivors are untouched."""
    from repro.checkpoint import set_write_fault_hook
    from repro.faults.plan import CkptWriteHook

    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 2, key)
    eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                         quarantine_dir=str(tmp_path), debug=True)
    eng.submit(_job(cfg, 0, schedule={1: "nan_batch"}))   # victim
    eng.submit(_job(cfg, 1, schedule={}))                 # survivor
    hook = CkptWriteHook(at=set(range(64)))               # every write fails
    set_write_fault_hook(hook)
    try:
        done = {j.name: j for j in eng.run()}
    finally:
        set_write_fault_hook(None)
    assert done["j0"].status == "quarantined"
    assert done["j1"].status == "finished"
    assert hook.fired >= 1
    assert any("quarantine checkpoint failed" in reason
               for _, _, reason in done["j0"].health.history)
    assert not check_conservation(eng)


def test_finetune_kill_restore_bitwise(key):
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 2, key)

    ref_eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                             debug=True)
    for i in range(2):
        ref_eng.submit(_job(cfg, i))
    ref = {j.name: j for j in ref_eng.run()}

    eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                         debug=True)
    for i in range(2):
        eng.submit(_job(cfg, i))
    eng.train_tick()
    eng.train_tick()
    state = eng.engine_state()                          # ... kill ...
    eng2 = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                          debug=True)
    eng2.load_engine_state(state)
    done = {j.name: j for j in eng2.run()}
    assert set(done) == set(ref)
    for name in ref:
        assert done[name].status == "finished"
        _assert_same_result(ref[name], done[name])
    assert not check_conservation(eng2)


def test_serving_kill_restore_bitwise(key):
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    prompts = _prompts(cfg)

    ref_eng = _serving(cfg, base, bank)
    _submit_all(ref_eng, prompts, max_new=4)
    ref = {r.prompt.tobytes(): r.generated for r in ref_eng.run()}

    eng = _serving(cfg, base, bank)
    _submit_all(eng, prompts, max_new=4)
    eng.service_tick()
    eng.service_tick()
    state = eng.engine_state()                          # ... kill ...
    eng2 = _serving(cfg, base, bank)
    eng2.load_engine_state(state)
    done = eng2.run()
    assert len(done) == len(ref)
    for r in done:
        assert r.status == "ok"
        np.testing.assert_array_equal(r.generated, ref[r.prompt.tobytes()])
    assert not check_conservation(eng2)
