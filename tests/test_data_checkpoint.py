"""Data pipeline determinism + checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLMDataset, make_client_batches
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.config import ENCDEC
from conftest import tiny


class TestData:
    def test_deterministic_per_step(self):
        ds = SyntheticLMDataset(vocab=64, seq_len=16, n_clients=2,
                                batch_per_client=3, seed=7)
        a, b = ds.batch(5), ds.batch(5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = ds.batch(6)
        assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLMDataset(vocab=64, seq_len=16, n_clients=1,
                                batch_per_client=1, seed=0)
        b = ds.batch(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][0, 0, 1:]),
                                      np.asarray(b["labels"][0, 0, :-1]))

    def test_markov_structure_is_learnable(self):
        """With structure=0.9, the preferred successor appears ~90%."""
        ds = SyntheticLMDataset(vocab=32, seq_len=256, n_clients=1,
                                batch_per_client=4, seed=0, structure=0.9)
        b = ds.batch(0)
        toks = np.asarray(b["tokens"][0]).reshape(-1)
        nxt = np.asarray(b["labels"][0]).reshape(-1)
        hit = (ds.succ[0][toks] == nxt).mean()
        assert hit > 0.8

    def test_clients_have_distinct_tasks(self):
        ds = SyntheticLMDataset(vocab=32, seq_len=8, n_clients=2,
                                batch_per_client=1, seed=0)
        assert not np.array_equal(ds.succ[0], ds.succ[1])

    def test_frontend_stub_shapes(self):
        cfg = tiny(ENCDEC)
        stream = make_client_batches(cfg, 2, 3, 16)
        b = stream.batch(0)
        assert b["frames"].shape == (2, 3, cfg.n_frontend_tokens, cfg.d_model)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, key):
        tree = {"a": jax.random.normal(key, (4, 4)),
                "b": {"c": jnp.arange(7), "d": [jnp.ones(3), jnp.zeros(2)]}}
        save_checkpoint(str(tmp_path), 3, tree)
        out = restore_checkpoint(str(tmp_path), 3, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert latest_step(str(tmp_path)) == 3

    def test_structure_mismatch_raises(self, tmp_path, key):
        tree = {"a": jnp.ones((2, 2))}
        save_checkpoint(str(tmp_path), 1, tree)
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, {"zz": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, {"a": jnp.ones((3, 3))})

    def test_separate_client_and_base_checkpoints(self, tmp_path, key):
        """The as-a-service persistence split: base saved once, per-client
        adapters independently restorable."""
        base = {"w": jax.random.normal(key, (8, 8))}
        save_checkpoint(str(tmp_path), 0, base, name="base")
        for c in range(3):
            save_checkpoint(str(tmp_path), 0, {"A": jnp.full((4,), c)},
                            name=f"client_{c}")
        got = restore_checkpoint(str(tmp_path), 0, {"A": jnp.zeros((4,))},
                                 name="client_1")
        np.testing.assert_array_equal(np.asarray(got["A"]), np.ones(4))
