"""Paged KV cache: pool/block-table layout exactness + cost model.

Correctness bars:

* ISSUE 3 (table-aware kernel): paged decode reads pages in place through
  the block table — BYTE-identical to the gather reference (the same
  blocked math run over a ``gather_paged_kv``-materialized dense view, via
  ``blocks.paged_gather_oracle``), at model level for every attention
  family.
* ISSUE 2 (layout exactness), amended by ISSUE 3: the paged layout tracks
  the dense layout within float tolerance (the kernel's blocked online
  softmax re-associates the reductions the dense path does in one shot) and
  the greedy token stream stays identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DENSE, MOE, HYBRID, VLM, ENCDEC, ServeConfig
from repro.core import symbiosis
from repro.kernels.decode_attn.ref import paged_view
from repro.models import blocks, get_model
from repro.serving import kvcache
from conftest import tiny

ATTN_FAMS = [DENSE, MOE, HYBRID, VLM]


def _roundtrip(arch, n_new=4, **cache_kw):
    """prefill + n_new greedy decode steps; returns per-step logits list."""
    cfg = tiny(arch)
    model = get_model(cfg)
    base = model.init_params(jax.random.PRNGKey(0))
    B, S, max_seq = 2, 8, 32
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    extra = {}
    if arch == VLM:
        extra["img_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if arch == ENCDEC:
        extra["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.1
    cache = model.init_cache(B, max_seq, **cache_kw)
    logits, cache = model.prefill(base, {"tokens": prompt, **extra}, cache)
    out = [np.asarray(logits)]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(n_new):
        logits, cache = model.decode_step(base, cache, tok)
        out.append(np.asarray(logits))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return out


def _steps_close(xs, ys, tol=1e-4):
    """Per-step logits within tolerance AND identical greedy tokens."""
    for a, b in zip(xs, ys):
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol)
        np.testing.assert_array_equal(np.argmax(a, -1), np.argmax(b, -1))


class TestPagedKernelByteIdentity:
    """ISSUE 3 acceptance: the table-aware kernel's in-place page reads are
    byte-identical to the gather reference at MODEL level — same decode
    steps rerun under ``blocks.paged_gather_oracle()`` (gather_paged_kv + the
    identical blocked math) must reproduce every step's logits exactly."""

    def _case(self, arch, **cache_kw):
        direct = _roundtrip(arch, **cache_kw)
        with blocks.paged_gather_oracle():
            oracle = _roundtrip(arch, **cache_kw)
        for a, b in zip(direct, oracle):
            np.testing.assert_array_equal(a, b)

    def test_dense_family(self):
        self._case(DENSE, page_block=8)

    def test_quant_pools(self):
        self._case(DENSE, page_block=8, quant=True)

    def test_single_page_and_nondividing(self):
        self._case(DENSE, page_block=32)   # one page per slot (max_seq 32)
        self._case(DENSE, page_block=12)   # 12 does not divide max_seq 32

    @pytest.mark.tier2
    @pytest.mark.parametrize("arch", ATTN_FAMS + [ENCDEC])
    @pytest.mark.parametrize("page_block", [4, 8, 16, 12])
    def test_all_families(self, arch, page_block):
        self._case(arch, page_block=page_block)


class TestPagedExactness:
    def test_dense_family_paged_matches_dense(self):
        """Fast tier-1 guard: paged tracks dense within float tolerance and
        the greedy stream is identical (bit-exactness holds paged-vs-paged
        across schedules — see test_compact_decode — not across layouts:
        the table-aware kernel's online softmax re-associates reductions)."""
        _steps_close(_roundtrip(DENSE), _roundtrip(DENSE, page_block=8))

    @pytest.mark.tier2
    @pytest.mark.parametrize("arch", ATTN_FAMS + [ENCDEC])
    @pytest.mark.parametrize("page_block", [4, 8, 16])
    def test_paged_matches_dense_all_families(self, arch, page_block):
        """Every attention-bearing family, several page sizes (including a
        block size that does not divide max_seq)."""
        _steps_close(_roundtrip(arch), _roundtrip(arch, page_block=page_block))

    @pytest.mark.tier2
    def test_paged_quant_compose_matches_dense_quant(self):
        """Paged + int8 tracks dense + int8 (same quantization points; the
        kernel dequantizes per streamed page)."""
        _steps_close(_roundtrip(DENSE, quant=True),
                     _roundtrip(DENSE, quant=True, page_block=8))


class TestPagedPrimitives:
    def test_prefill_write_bounded_by_lengths(self):
        """Positions >= a row's length never touch the pool — what protects
        other slots' live pages during a masked admission prefill."""
        pool = jnp.full((4, 4, 2, 8), -1.0)
        tbl = jnp.array([[0, 1], [2, 3]], jnp.int32)
        x = jnp.ones((2, 8, 2, 8))
        out = blocks.paged_prefill_write(pool, tbl, x, jnp.array([3, 0]))
        out = np.asarray(out)
        assert (out[0, :3] == 1.0).all()          # row 0: 3 tokens written
        assert (out[0, 3:] == -1.0).all()
        assert (out[1:] == -1.0).all()            # page 1 tail + row 1 pages

    def test_token_write_inactive_dropped(self):
        pool = jnp.zeros((2, 4, 1, 8))
        tbl = jnp.array([[0], [1]], jnp.int32)
        pos = jnp.array([1, 2], jnp.int32)
        x = jnp.ones((2, 1, 8))
        out = blocks.paged_token_write(pool, tbl, pos, x,
                                       active=jnp.array([True, False]))
        out = np.asarray(out)
        assert (out[0, 1] == 1.0).all()           # active row wrote its slot
        assert (out[1] == 0.0).all()              # inactive row dropped

    def test_paged_view_roundtrip(self):
        pool = jnp.arange(4 * 2 * 1 * 2, dtype=jnp.float32).reshape(4, 2, 1, 2)
        tbl = jnp.array([[3, 0], [1, 2]], jnp.int32)
        view = np.asarray(paged_view(pool, tbl))
        np.testing.assert_array_equal(view[0, :2], np.asarray(pool[3]))
        np.testing.assert_array_equal(view[0, 2:], np.asarray(pool[0]))
        np.testing.assert_array_equal(view[1, :2], np.asarray(pool[1]))

    def test_slot_axes_mark_pool_shared(self):
        """Structural slot-axis derivation: pools and block tables have no
        slot axis (None); per-slot leaves keep their axis."""
        cfg = tiny(DENSE)
        axes = symbiosis.cache_slot_axes(cfg, 32, page_block=8)
        assert axes["block_tbl"] is None
        assert axes["layers"]["k"] is None        # shared page pool
        assert axes["pos"] == 0
        dense_axes = symbiosis.cache_slot_axes(cfg, 32)
        assert dense_axes["layers"]["k"] == 1     # dense: slot axis under L


class TestPrefillPoolNoCopy:
    """Regression (PR 3 known issue): prefill used to scan layer-stacked
    page pools as xs/ys, re-materializing the WHOLE pool once per
    ADMISSION. Pools must ride the prefill scan as fused CARRY (layer axis
    folded into the page axis, like decode): asserted structurally — no
    scan in the prefill jaxpr stacks a pool-sized output — and end-to-end —
    the engine's donated pool buffer is updated in place across an
    admission."""

    @pytest.mark.parametrize("arch", [DENSE, HYBRID, ENCDEC])
    def test_no_pool_sized_scan_output(self, arch):
        cfg = tiny(arch)
        model = get_model(cfg)
        max_seq, B, S = 32, 2, 8
        cache = jax.eval_shape(
            lambda: model.init_cache(B, max_seq, page_block=8))
        # pool leaves = leaves with a page axis (shape scales with the pool);
        # per-slot leaves (cross caches, mamba state) legitimately ride ys
        page_axes = symbiosis.cache_page_axes(cfg, max_seq, page_block=8)
        flat_cache, treedef = jax.tree.flatten(cache)
        flat_pax = treedef.flatten_up_to(page_axes)
        pool_shapes = {leaf.shape for leaf, pax in zip(flat_cache, flat_pax)
                       if pax is not None}
        base = model.init_params(jax.random.PRNGKey(0))
        real_cache = model.init_cache(B, max_seq, page_block=8)
        batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
        if arch == ENCDEC:
            batch["frames"] = jnp.zeros((B, cfg.n_frontend_tokens, cfg.d_model))
        jaxpr = jax.make_jaxpr(
            lambda c, b: model.prefill(base, b, c))(real_cache, batch)

        def scan_ys_shapes(jxp, out):
            for eqn in jxp.eqns:
                if eqn.primitive.name == "scan":
                    nc = eqn.params["num_carry"]
                    out.update(v.aval.shape for v in eqn.outvars[nc:])
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        scan_ys_shapes(v.jaxpr, out)
            return out

        ys_shapes = scan_ys_shapes(jaxpr.jaxpr, set())
        stacked = pool_shapes & ys_shapes
        assert not stacked, (
            f"{arch}: prefill scan stacks pool-shaped outputs {stacked} — "
            f"the page pool is being copied per admission")

    def test_admission_updates_pool_in_place(self):
        from repro.config import AdapterConfig
        from repro.serving.engine import ServingEngine, Request
        cfg = tiny(DENSE)
        scfg = ServeConfig(n_clients=2, max_seq=48, page_block=8)
        acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
        base, bank, _ = symbiosis.init_system(cfg, acfg, 2,
                                              jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, acfg, scfg, base, bank,
                            max_batch_per_client=2)
        rng = np.random.default_rng(0)
        ptr = eng.caches["layers"]["k"].unsafe_buffer_pointer()
        eng.submit(Request(client_id=0,
                           prompt=rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                           max_new_tokens=3))
        eng.service_tick()                       # admission + prefill + decode
        assert eng.caches["layers"]["k"].unsafe_buffer_pointer() == ptr, (
            "paged admission produced a fresh pool buffer (pool copied "
            "instead of donated in-place update)")


class TestPagedCostModel:
    def test_cache_bytes_rounds_to_pages(self):
        cfg = tiny(DENSE, dtype="bfloat16")
        per_tok = kvcache.make_cache_spec(cfg).bytes_per_token
        assert kvcache.cache_bytes(cfg, 17, page_block=16) == 32 * per_tok
        assert kvcache.cache_bytes(cfg, 16, page_block=16) == 16 * per_tok
        assert kvcache.cache_bytes(cfg, 17) == 17 * per_tok

    def test_quant_bytes_about_half(self):
        cfg = tiny(DENSE, dtype="bfloat16")
        full = kvcache.cache_bytes(cfg, 1024)
        quant = kvcache.cache_bytes(cfg, 1024, quant=True)
        assert 0.4 * full < quant < 0.65 * full

    def test_paged_quant_beats_dense_row(self):
        """The admission story: a short request charged per int8 page is a
        tiny fraction of a dense max_seq-deep bf16 slot row."""
        cfg = tiny(DENSE, dtype="bfloat16")
        dense_row = kvcache.cache_bytes(cfg, 2048)
        paged = kvcache.cache_bytes(cfg, 24, quant=True, page_block=16)
        assert paged * 10 < dense_row

    def test_serve_cache_kwargs_family_gating(self):
        scfg = ServeConfig(page_block=16, kv_quant=True)
        kw = symbiosis.serve_cache_kwargs(tiny(DENSE), scfg)
        assert kw == {"page_block": 16, "quant": True}
        kw = symbiosis.serve_cache_kwargs(tiny(HYBRID), scfg)
        assert kw == {"page_block": 16}           # no pure-KV cache to quantize
        from repro.config import RWKV
        kw = symbiosis.serve_cache_kwargs(tiny(RWKV), scfg)
        assert kw == {}                           # O(1) state: nothing to page
