"""Shared fixtures: tiny configs per architecture family.

NOTE: no XLA_FLAGS here — tests run on the single real CPU device; only the
dry-run (repro.launch.dryrun) forces 512 placeholder devices.
"""
import jax
import pytest

from repro.analysis import tracecount
from repro.config import (ModelConfig, AdapterConfig, DENSE, MOE, RWKV, HYBRID,
                          ENCDEC, VLM)

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _trace_guard(request):
    """Tier-1 bucket-coverage guard (see repro.analysis.tracecount): any
    engine driven during a test dispatches its jitted steps through
    ``tracecount.dispatch``, and every compile must land inside the
    engine's declared trace domain. Tests that deliberately break
    bucketing open their own inner ``tracecount.guard`` — nested guards
    shadow this one, so their intentional violations stay local."""
    with tracecount.guard(request.node.nodeid) as g:
        yield
    res = g.result()
    assert res.ok, ("hot-path trace-count violations:\n"
                    + "\n".join(v.message for v in res.violations))


def tiny(arch=DENSE, **kw):
    base = {"name": f"tiny-{arch}", "arch": arch, "n_layers": 2,
            "d_model": 64, "n_heads": 4, "n_kv_heads": 2, "d_ff": 128,
            "vocab": 128, "dtype": "float32", "param_dtype": "float32"}
    if arch == MOE:
        base.update(n_experts=4, top_k=2, n_shared_experts=1, d_expert=32,
                    first_dense_layers=1, n_layers=3)
    if arch == RWKV:
        base.update(n_heads=4, n_kv_heads=4, head_dim=16)
    if arch == HYBRID:
        base.update(n_layers=4, attn_every=2, n_experts=4, top_k=2,
                    moe_every=2, moe_offset=1, d_state=8, d_conv=4)
    if arch == ENCDEC:
        base.update(n_enc_layers=2, n_frontend_tokens=8, rope_theta=0.0,
                    n_kv_heads=4)
    if arch == VLM:
        base.update(n_frontend_tokens=8)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def lora_cfg():
    return AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
