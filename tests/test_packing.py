"""Token-budget ragged packing (paper §3.7) — property-based."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property sweeps are optional-dep gated
from hypothesis import given, settings, strategies as st

from repro.core import packing


@st.composite
def ragged_case(draw):
    C = draw(st.integers(1, 5))
    S_max = draw(st.integers(1, 12))
    lengths = [draw(st.integers(0, S_max)) for _ in range(C)]
    d = draw(st.integers(1, 8))
    slack = draw(st.integers(0, 8))
    budget = sum(lengths) + slack
    return C, S_max, lengths, d, max(budget, 1)


class TestPackUnpack:
    @given(ragged_case())
    @settings(max_examples=40, deadline=None)
    def test_roundtrip(self, case):
        C, S_max, lengths, d, budget = case
        rng = np.random.default_rng(0)
        x = rng.normal(size=(C, S_max, d)).astype(np.float32)
        lens = jnp.asarray(lengths, jnp.int32)
        p = packing.pack(jnp.asarray(x), lens, budget)
        out = packing.unpack(p, p.buf, S_max)
        out = np.asarray(out)
        for c, L in enumerate(lengths):
            np.testing.assert_allclose(out[c, :L], x[c, :L], rtol=1e-6)
            np.testing.assert_allclose(out[c, L:], 0.0)

    @given(ragged_case())
    @settings(max_examples=40, deadline=None)
    def test_segment_ids_and_positions(self, case):
        C, S_max, lengths, d, budget = case
        x = np.ones((C, S_max, d), np.float32)
        p = packing.pack(jnp.asarray(x), jnp.asarray(lengths, jnp.int32), budget)
        seg = np.asarray(p.seg_ids)
        total = sum(lengths)
        assert (seg >= 0).sum() == min(total, budget)
        off = 0
        for c, L in enumerate(lengths):
            assert (seg[off:off + L] == c).all()
            np.testing.assert_array_equal(np.asarray(p.slot_pos)[off:off + L],
                                          np.arange(L))
            off += L

    def test_linear_commutes_with_packing(self):
        """The §3.7 insight: token position doesn't matter to nn.Linear, so
        linear(pack(x)) == pack(linear(x)) — batching without padding is
        exact."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(3, 6, 8)).astype(np.float32)
        w = rng.normal(size=(8, 5)).astype(np.float32)
        lens = jnp.asarray([6, 2, 4], jnp.int32)
        p = packing.pack(jnp.asarray(x), lens, budget=16)
        y_packed = packing.unpack(p, p.buf @ w, 6)
        y_direct = jnp.asarray(x) @ w
        mask = (np.arange(6)[None, :] < np.asarray(lens)[:, None])
        np.testing.assert_allclose(np.asarray(y_packed)[mask],
                                   np.asarray(y_direct)[mask], rtol=1e-5)

    def test_overflow_drops_tokens(self):
        x = np.ones((2, 4, 3), np.float32)
        p = packing.pack(jnp.asarray(x), jnp.asarray([4, 4], jnp.int32), budget=6)
        assert int((np.asarray(p.seg_ids) >= 0).sum()) == 6
