"""Golden-HLO fixtures for launch.hlo_analysis (ISSUE 6 satellite).

Hand-written HLO text exercising the parser paths that real modules hit:
tuple-result collectives, async -start/-done pairs (counted once, charged
the destination element only), while-loop trip multiplication, fusion
walk-through, and the unknown-dtype warning.
"""
import warnings

import pytest

from repro.analysis.aliasing import parse_aliased_params, parse_entry_params
from repro.launch import hlo_analysis

WHILE_HLO = """\
HloModule golden_while, is_scheduled=true

%body.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[64,128]) %p), index=0
  %x = f32[64,128] get-tuple-element((s32[], f32[64,128]) %p), index=1
  %ag = f32[64,128]{1,0} all-gather(f32[64,32]{1,0} %x), dimensions={1}
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %i, s32[] %one)
  ROOT %out = (s32[], f32[64,128]) tuple(s32[] %next, f32[64,128] %ag)
}

%cond.1 (p.2: (s32[], f32[64,128])) -> pred[] {
  %p.2 = (s32[], f32[64,128]) parameter(0)
  %i.2 = s32[] get-tuple-element((s32[], f32[64,128]) %p.2), index=0
  %t = s32[] constant(3)
  ROOT %lt = pred[] compare(s32[] %i.2, s32[] %t), direction=LT
}

ENTRY %main (arg: f32[64,128]) -> f32[64,128] {
  %arg = f32[64,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,128]) tuple(s32[] %zero, f32[64,128] %arg)
  %w = (s32[], f32[64,128]) while((s32[], f32[64,128]) %init), condition=%cond.1, body=%body.1
  ROOT %res = f32[64,128] get-tuple-element((s32[], f32[64,128]) %w), index=1
}
"""

ASYNC_HLO = """\
HloModule golden_async, is_scheduled=true

ENTRY %main (arg: f32[8,16]) -> f32[32,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %ag-start = (f32[8,16]{1,0}, f32[32,16]{1,0}) all-gather-start(f32[8,16]{1,0} %arg), dimensions={0}
  %ag-done = f32[32,16]{1,0} all-gather-done((f32[8,16]{1,0}, f32[32,16]{1,0}) %ag-start)
  %ar = f32[32,16]{1,0} all-reduce(f32[32,16]{1,0} %ag-done), to_apply=%add.1
  ROOT %out = f32[32,16]{1,0} copy(f32[32,16]{1,0} %ar)
}
"""

TUPLE_HLO = """\
HloModule golden_tuple, is_scheduled=true

ENTRY %main (a: f32[4,4], b: s32[8]) -> (f32[4,4], s32[8]) {
  %a = f32[4,4]{1,0} parameter(0)
  %b = s32[8]{0} parameter(1)
  %ar = (f32[4,4]{1,0}, s32[8]{0}) all-reduce(f32[4,4]{1,0} %a, s32[8]{0} %b), to_apply=%add.2
  %g0 = f32[4,4]{1,0} get-tuple-element((f32[4,4]{1,0}, s32[8]{0}) %ar), index=0
  %g1 = s32[8]{0} get-tuple-element((f32[4,4]{1,0}, s32[8]{0}) %ar), index=1
  ROOT %t = (f32[4,4]{1,0}, s32[8]{0}) tuple(f32[4,4]{1,0} %g0, s32[8]{0} %g1)
}
"""


def test_while_trip_multiplication():
    coll = hlo_analysis.collective_bytes(WHILE_HLO)
    # one all-gather of f32[64,128] = 32768 B, x3 loop trips
    assert coll["all-gather"] == 3 * 64 * 128 * 4
    assert coll["n_ops"] == 3
    ops = hlo_analysis.find_collectives(WHILE_HLO)
    assert len(ops) == 1 and ops[0].mult == 3
    assert ops[0].kind == "all-gather"
    assert ("f32", (64, 128)) in ops[0].shapes


def test_async_pair_counted_once_destination_only():
    coll = hlo_analysis.collective_bytes(ASYNC_HLO)
    # -start charged max(tuple elements) = the f32[32,16] destination;
    # -done charged nothing; the sync all-reduce charged its full result.
    dest = 32 * 16 * 4
    assert coll["all-gather"] == dest
    assert coll["all-reduce"] == dest
    assert coll["n_ops"] == 2
    ops = hlo_analysis.find_collectives(ASYNC_HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce"]
    start = next(o for o in ops if o.kind == "all-gather")
    # both tuple elements are listed (shape audit sees operand + dest)...
    assert ("f32", (8, 16)) in start.shapes
    assert ("f32", (32, 16)) in start.shapes
    # ...but only the destination is charged
    assert start.bytes == dest


def test_variadic_tuple_result_sums_all_elements():
    coll = hlo_analysis.collective_bytes(TUPLE_HLO)
    assert coll["all-reduce"] == 4 * 4 * 4 + 8 * 4
    ops = hlo_analysis.find_collectives(TUPLE_HLO)
    assert len(ops) == 1
    assert set(ops[0].shapes) == {("f32", (4, 4)), ("s32", (8,))}


def test_unknown_dtype_warns_once_and_assumes_4_bytes():
    hlo_analysis._warned_dtypes.discard("f6e9")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        b1 = hlo_analysis._shape_bytes("f6e9", "2,3")
        b2 = hlo_analysis._shape_bytes("f6e9", "5")
    assert b1 == 2 * 3 * 4 and b2 == 5 * 4      # 4 B/elem fallback
    assert len([x for x in w if "unknown HLO element type" in str(x.message)]) == 1


def test_analyze_module_loop_aware_collectives():
    walker = hlo_analysis.analyze_module(WHILE_HLO)
    assert walker["all-gather"] == 3 * 64 * 128 * 4
    assert walker["coll_bytes"] == walker["all-gather"]


def test_alias_header_parsing_nested_braces():
    header = (
        "HloModule jit_step, is_scheduled=true, input_output_alias={ "
        "{0}: (2, {}, may-alias), {1, 0}: (3, {}, must-alias) }, "
        "entry_computation_layout={(f32[4,4]{1,0}, s32[8]{0}, "
        "f32[2,16,8]{2,1,0}, pred[3]{0})->(f32[4,4]{1,0})}\n"
    )
    assert parse_aliased_params(header) == [2, 3]
    params = parse_entry_params(header)
    assert params == [("f32", (4, 4)), ("s32", (8,)),
                      ("f32", (2, 16, 8)), ("pred", (3,))]
