"""Cross-client compacted prefill + refcounted shared-prefix pages (ISSUE 10).

Two contracts under test:

1. **Byte identity.** Shared-prefix page reuse is an allocator trick, not a
   numerics change: every request's greedy output with sharing on is
   byte-identical to the same workload with ``prefix_cache=False`` and to
   solo serving — across hit / miss / partial-prefix / CoW-divergence /
   retire-and-reuse lifecycles, adapter methods, and tick policies.
2. **Refcount hygiene.** The content index's references always equal the
   slots' shared-page memberships (no leak, no double free, no
   use-after-free) — audited after every tick via ``debug=True`` and
   asserted directly on the ``PrefixIndex`` unit surface.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, ServeConfig, DENSE
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.faults.audit import check_conservation
from repro.models import get_model
from repro.obs import Obs
from repro.serving.engine import Request, ServingEngine
from repro.serving.prefix_cache import (PrefixIndex, chain_digests,
                                        sharable_tokens)
from conftest import tiny

BLK = 8


# ---------------------------------------------------------------------------
# PrefixIndex unit surface
# ---------------------------------------------------------------------------

class TestPrefixIndex:
    def test_sharable_tokens_last_token_never_shared(self):
        # the consumer must prefill at least its final token
        assert sharable_tokens(0, BLK) == (0, 0)
        assert sharable_tokens(1, BLK) == (0, 0)
        assert sharable_tokens(8, BLK) == (0, 7)    # exact block: 7-token tail
        assert sharable_tokens(9, BLK) == (1, 0)
        assert sharable_tokens(17, BLK) == (2, 0)
        assert sharable_tokens(14, BLK) == (1, 5)

    def test_chain_digests_prefix_property_and_scope(self):
        rng = np.random.default_rng(0)
        t = rng.integers(1, 100, 25).astype(np.int32)
        long = chain_digests(b"0:0", t, BLK)             # f=3, r=0
        short = chain_digests(b"0:0", t[:17], BLK)       # f=2, r=0
        assert long[:2] == short
        # a different scope (bank, local adapter) shifts every digest
        other = chain_digests(b"0:1", t, BLK)
        assert all(a != b for a, b in zip(long, other))
        # a divergent token in block 0 changes block 1's digest too (chained)
        t2 = t.copy()
        t2[3] += 1
        assert chain_digests(b"0:0", t2, BLK)[1] != long[1]

    def test_publish_lookup_full_and_partial(self):
        rng = np.random.default_rng(1)
        t = rng.integers(1, 100, 17).astype(np.int32)    # f=2, r=0
        idx = PrefixIndex()
        took = idx.publish(b"0:0", t, BLK, [10, 11, 12], (0, 0))
        assert took == [10, 11]                          # page 12 unshared
        hit = idx.lookup(b"0:0", t, BLK)
        assert hit.full_pages == [10, 11] and hit.start == 16
        assert hit.tail_page is None
        # partial prefix: only block 0 matches
        t2 = t.copy()
        t2[9] += 1
        hit2 = idx.lookup(b"0:0", t2, BLK)
        assert hit2.full_pages == [10] and hit2.start == 8
        # different scope: no match at all
        assert idx.lookup(b"1:0", t, BLK).matched_blocks == 0

    def test_tail_entry_cow_semantics(self):
        rng = np.random.default_rng(2)
        t = rng.integers(1, 100, 14).astype(np.int32)    # f=1, r=5
        idx = PrefixIndex()
        took = idx.publish(b"0:0", t, BLK, [3, 4], (0, 0))
        assert took == [3]                               # tail page 4: refs=0
        hit = idx.lookup(b"0:0", t, BLK)
        assert hit.full_pages == [3]
        assert hit.tail_page == 4 and hit.tail_tokens == 5 and hit.start == 13
        # a prompt agreeing on fewer tail tokens does NOT hit the tail
        t2 = t.copy()
        t2[11] += 1
        hit2 = idx.lookup(b"0:0", t2, BLK)
        assert hit2.full_pages == [3] and hit2.tail_page is None
        # tails are never ref-held
        tail_digest = [d for d, ref in zip(
            chain_digests(b"0:0", t, BLK), [False, True]) if ref]
        with pytest.raises(ValueError):
            idx.ref(tail_digest[0])
        # the publisher retires: tail invalidated, full block survives
        idx.drop_tail((0, 0))
        assert idx.lookup(b"0:0", t, BLK).tail_page is None
        assert idx.lookup(b"0:0", t, BLK).full_pages == [3]

    def test_refcount_protocol_and_double_free(self):
        rng = np.random.default_rng(3)
        t = rng.integers(1, 100, 9).astype(np.int32)     # f=1, r=0
        idx = PrefixIndex()
        (d,) = chain_digests(b"0:0", t, BLK)
        idx.publish(b"0:0", t, BLK, [7, 8], (0, 0))      # refs=1 (publisher)
        assert idx.ref(d) == 7                           # refs=2 (consumer)
        assert idx.page_refs() == {7: 2}
        assert idx.deref(7) is False                     # publisher lets go
        assert idx.deref(7) is True                      # last ref: recycle
        with pytest.raises(KeyError):
            idx.deref(7)                                 # no longer published
        # a zero-ref entry surviving in the index (the bug a double free
        # regression would produce) must raise, never go negative
        from repro.serving.prefix_cache import _Entry
        idx._entries[d] = _Entry(page=7, refs=0, tail=0, owner=(0, 0))
        idx._by_page[7] = d
        with pytest.raises(RuntimeError, match="double free"):
            idx.deref(7)

    def test_duplicate_publish_keeps_first(self):
        rng = np.random.default_rng(4)
        t = rng.integers(1, 100, 9).astype(np.int32)
        idx = PrefixIndex()
        assert idx.publish(b"0:0", t, BLK, [1, 2], (0, 0)) == [1]
        # a second slot prefilled the same content before looking up: the
        # first entry wins, the second slot keeps its page exclusive
        assert idx.publish(b"0:0", t, BLK, [5, 6], (0, 1)) == []
        assert idx.page_refs() == {1: 1}

    def test_state_round_trip(self):
        rng = np.random.default_rng(5)
        t = rng.integers(1, 100, 14).astype(np.int32)
        idx = PrefixIndex()
        idx.publish(b"0:0", t, BLK, [3, 4], (0, 0))
        (d, _tail) = chain_digests(b"0:0", t, BLK)
        idx.ref(d)
        clone = PrefixIndex.from_state(idx.state())
        assert clone.page_refs() == idx.page_refs() == {3: 2}
        assert clone.lookup(b"0:0", t, BLK).tail_page == 4
        assert len(clone) == len(idx)


# ---------------------------------------------------------------------------
# engine-level byte identity
# ---------------------------------------------------------------------------

@pytest.fixture
def paged_system(key, lora_cfg):
    cfg = tiny(DENSE)
    scfg = ServeConfig(n_clients=2, max_seq=48, page_block=BLK)
    base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 2, key)
    return cfg, scfg, base, bank


def _engine(cfg, scfg, base, bank, lora_cfg, **kw):
    kw.setdefault("max_batch_per_client", 2)
    kw.setdefault("debug", True)           # conservation audit every tick
    return ServingEngine(cfg, lora_cfg, scfg, base, bank, **kw)


def _template_reqs(cfg, rng, *, n=4, tpl_len=16, arrive_every=2, max_new=3,
                   first_max_new=None):
    """n single-row requests from client 0 sharing one tpl_len-token
    template, each with a distinct suffix token, arriving staggered so
    later ones hit what earlier ones published. The index recycles pages
    when the LAST holder retires (strict refcounting), so the first
    request defaults to decoding long enough to still be live when the
    final arrival is admitted."""
    if first_max_new is None:
        first_max_new = max_new + arrive_every * n
    tpl = rng.integers(1, cfg.vocab, tpl_len).astype(np.int32)
    reqs = []
    for i in range(n):
        prompt = np.concatenate([tpl, [np.int32(1 + i)]])[None, :]
        reqs.append(Request(client_id=0, prompt=prompt,
                            max_new_tokens=first_max_new if i == 0 else max_new,
                            arrive_tick=i * arrive_every))
    return reqs


def _run(eng, reqs):
    for r in reqs:
        eng.submit(Request(client_id=r.client_id, prompt=r.prompt.copy(),
                           max_new_tokens=r.max_new_tokens,
                           sampling=r.sampling, arrive_tick=r.arrive_tick))
    done = eng.run()
    assert all(r.status == "ok" for r in done)
    return {r.prompt.tobytes(): r.generated for r in done}


class TestSharedPrefixByteIdentity:
    @pytest.mark.parametrize("policy",
                             ["lockstep", "nolockstep", "opportunistic"])
    def test_hit_matches_nocache_every_policy(self, paged_system, lora_cfg,
                                              policy):
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(7)
        reqs = _template_reqs(cfg, rng)
        on = _engine(cfg, scfg, base, bank, lora_cfg, policy=policy)
        off = _engine(cfg, scfg, base, bank, lora_cfg, policy=policy,
                      prefix_cache=False)
        got = _run(on, reqs)
        ref = _run(off, reqs)
        assert on._share_prefix and not off._share_prefix
        assert set(got) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        assert off.stats["prefix_hits"] == 0
        assert on.stats["prefill_tokens"] == off.stats["prefill_tokens"]
        if policy != "lockstep":
            # lockstep retires whole batches before admitting the next, so
            # strict refcounting leaves nothing to hit — identity still holds
            assert on.stats["prefix_hits"] >= 1
            assert on.stats["pages_shared"] >= 2      # two template blocks
            # suffix-only prefill actually saved compute
            assert (on.stats["prefill_tokens_computed"]
                    < off.stats["prefill_tokens_computed"])

    def test_cow_divergence_matches(self, paged_system, lora_cfg):
        """Prompts agreeing on a full block + 5 tail tokens: the hit copies
        the publisher's tail page and overwrites from the divergence."""
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(11)
        tpl = rng.integers(1, cfg.vocab, 13).astype(np.int32)   # f=1, r=5
        reqs = []
        for i in range(3):
            prompt = np.concatenate([tpl, [np.int32(1 + i)]])[None, :]
            reqs.append(Request(client_id=0, prompt=prompt,
                                max_new_tokens=10 if i == 0 else 3,
                                arrive_tick=2 * i))
        on = _engine(cfg, scfg, base, bank, lora_cfg)
        off = _engine(cfg, scfg, base, bank, lora_cfg, prefix_cache=False)
        got, ref = _run(on, reqs), _run(off, reqs)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        assert on.stats["cow_copies"] >= 1
        assert on.stats["prefix_hits"] >= 1

    def test_miss_is_invisible(self, paged_system, lora_cfg):
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(13)
        reqs = [Request(client_id=c, max_new_tokens=3, arrive_tick=2 * i,
                        prompt=rng.integers(1, cfg.vocab,
                                            (1, 10 + i)).astype(np.int32))
                for i, c in enumerate([0, 1, 0, 1])]
        on = _engine(cfg, scfg, base, bank, lora_cfg)
        off = _engine(cfg, scfg, base, bank, lora_cfg, prefix_cache=False)
        got, ref = _run(on, reqs), _run(off, reqs)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        assert on.stats["prefix_hits"] == 0
        assert on.stats["prefill_tokens_computed"] == \
            off.stats["prefill_tokens_computed"]

    def test_refcounted_retire_and_reuse(self, paged_system, lora_cfg):
        """Publisher retires while a consumer still decodes (refs keep the
        pages); after the last holder retires the pages recycle and a
        fresh template run misses cleanly — all byte-identical and
        conservation-audited every tick (debug=True)."""
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(17)
        tpl = rng.integers(1, cfg.vocab, 16).astype(np.int32)

        def req(i, max_new, at):
            return Request(client_id=0, max_new_tokens=max_new,
                           arrive_tick=at,
                           prompt=np.concatenate([tpl,
                                                  [np.int32(1 + i)]])[None, :])
        # A publishes and retires first; B hits and outlives A (its refs
        # keep the template pages); C hits via B's refs after A is gone
        reqs = [req(0, 4, 0), req(1, 12, 1), req(2, 3, 7)]
        on = _engine(cfg, scfg, base, bank, lora_cfg)
        off = _engine(cfg, scfg, base, bank, lora_cfg, prefix_cache=False)
        got, ref = _run(on, reqs), _run(off, reqs)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        assert on.stats["prefix_hits"] >= 2       # B, and C after A retired
        # everything retired: no refs survive, every page back in the pool
        assert on._prefix_index.page_refs() == {}
        assert not on._slot_shared
        assert not check_conservation(on)
        # the template's pages were recycled — a new run starts cold
        late = [Request(client_id=0, prompt=reqs[0].prompt.copy(),
                        max_new_tokens=reqs[0].max_new_tokens)]
        hits_before = on.stats["prefix_hits"]
        got2 = _run(on, late)
        np.testing.assert_array_equal(got2[reqs[0].prompt.tobytes()],
                                      ref[reqs[0].prompt.tobytes()])
        assert on.stats["prefix_hits"] == hits_before

    def test_matches_solo_serving(self, paged_system, lora_cfg):
        """The strongest oracle: each templated request equals serving it
        ALONE through a fresh engine (nothing published, nothing shared)."""
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(19)
        reqs = _template_reqs(cfg, rng, n=3)
        on = _engine(cfg, scfg, base, bank, lora_cfg)
        got = _run(on, reqs)
        assert on.stats["prefix_hits"] >= 1
        for r in reqs:
            solo = _engine(cfg, scfg, base, bank, lora_cfg)
            solo.submit(Request(client_id=0, prompt=r.prompt.copy(),
                                max_new_tokens=r.max_new_tokens))
            (done,) = solo.run()
            np.testing.assert_array_equal(got[r.prompt.tobytes()],
                                          done.generated)

    def test_engine_state_round_trip_with_live_shared_pages(
            self, paged_system, lora_cfg):
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(23)
        reqs = _template_reqs(cfg, rng, n=3, max_new=6, arrive_every=2)
        ref_eng = _engine(cfg, scfg, base, bank, lora_cfg)
        ref = _run(ref_eng, reqs)

        eng = _engine(cfg, scfg, base, bank, lora_cfg)
        for r in reqs:
            eng.submit(Request(client_id=0, prompt=r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens,
                               arrive_tick=r.arrive_tick))
        for _ in range(5):                      # mid-flight: live shared pages
            eng.service_tick()
        assert eng._prefix_index.page_refs()    # snapshot carries real refs
        state = eng.engine_state()              # ... kill ...
        eng2 = _engine(cfg, scfg, base, bank, lora_cfg)
        eng2.load_engine_state(state)
        done = eng2.run()
        assert len(done) == len(ref)
        for r in done:
            np.testing.assert_array_equal(r.generated, ref[r.prompt.tobytes()])
        assert not check_conservation(eng2)

    def test_obs_bitwise_invisible_and_instruments(self, paged_system,
                                                   lora_cfg):
        cfg, scfg, base, bank = paged_system
        rng = np.random.default_rng(29)
        reqs = _template_reqs(cfg, rng)
        obs = Obs()
        on = _engine(cfg, scfg, base, bank, lora_cfg, obs=obs)
        off = _engine(cfg, scfg, base, bank, lora_cfg)
        got, ref = _run(on, reqs), _run(off, reqs)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        m = obs.metrics
        assert (m.counter("prefix_cache_hits_total", client=0).value
                == on.stats["prefix_hits"] > 0)
        assert (m.counter("pages_shared", client=0).value
                == on.stats["pages_shared"] > 0)
        assert m.merged_histogram("admission_prefill_tokens").n \
            == len(reqs)
        # the compacted gather shows up as a span phase
        spans = [r for r in m.samples()
                 if r["metric"] == "span_seconds"
                 and r["labels"].get("phase") == "prefill_compact_gather"]
        assert spans

    def test_prefix_cache_requires_paged_ragged(self, key, lora_cfg):
        cfg = tiny(DENSE)
        base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 2, key)
        dense_scfg = ServeConfig(n_clients=2, max_seq=48)     # no page pool
        with pytest.raises(ValueError, match="prefix_cache"):
            _engine(cfg, dense_scfg, base, bank, lora_cfg, prefix_cache=True)
        quant_scfg = ServeConfig(n_clients=2, max_seq=48, page_block=BLK,
                                 kv_quant=True)
        with pytest.raises(ValueError, match="prefix_cache"):
            _engine(cfg, quant_scfg, base, bank, lora_cfg, prefix_cache=True)
        # quant engines silently fall back to compacted prefill, no sharing
        eng = _engine(cfg, quant_scfg, base, bank, lora_cfg)
        assert eng._compact_prefill and not eng._share_prefix


class TestSharedPrefixMixedMethods:
    METHODS = [
        AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v")),
        AdapterConfig(method="ia3", targets=("k", "v", "down")),
        AdapterConfig(method="prefix", targets=("q", "v"), n_prefix=4),
    ]

    def test_mixed_bank_hits_are_byte_identical(self, key):
        """Each method's client reuses its own template (the digest scope
        pins (bank, local adapter) — sharing never crosses adapters)."""
        cfg = tiny(DENSE)
        scfg = ServeConfig(n_clients=3, max_seq=48, page_block=BLK)
        base = get_model(cfg).init_params(jax.random.PRNGKey(0))
        banks = [ad_lib.init_client_bank(cfg, a, 1, jax.random.PRNGKey(5 + i))
                 for i, a in enumerate(self.METHODS)]
        rng = np.random.default_rng(31)
        tpls = {c: rng.integers(1, cfg.vocab, 16).astype(np.int32)
                for c in range(3)}
        reqs = []
        for i in range(2):
            for c in range(3):
                prompt = np.concatenate([tpls[c], [np.int32(1 + i)]])[None, :]
                reqs.append(Request(client_id=c, prompt=prompt,
                                    max_new_tokens=10 if i == 0 else 3,
                                    arrive_tick=3 * i))

        def run(**kw):
            eng = ServingEngine(cfg, self.METHODS, scfg, base, banks,
                                max_batch_per_client=2, debug=True, **kw)
            for r in reqs:
                eng.submit(Request(client_id=r.client_id,
                                   prompt=r.prompt.copy(),
                                   max_new_tokens=r.max_new_tokens,
                                   arrive_tick=r.arrive_tick))
            done = eng.run()
            assert all(r.status == "ok" for r in done)
            return eng, {(r.client_id, r.prompt.tobytes()): r.generated
                         for r in done}

        on_eng, got = run()
        off_eng, ref = run(prefix_cache=False)
        assert set(got) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        # every method's second templated request hit its own scope
        assert on_eng.stats["prefix_hits"] >= 3
        assert not check_conservation(on_eng)


@pytest.mark.tier2
class TestSharedPrefixSweep:
    """Tier-2 sweep: many users, few templates, every policy — the CI
    shared-prefix job (ci.yml)."""

    @pytest.mark.parametrize("policy",
                             ["lockstep", "nolockstep", "opportunistic"])
    def test_template_mix_byte_identical(self, key, lora_cfg, policy):
        cfg = tiny(DENSE)
        scfg = ServeConfig(n_clients=2, max_seq=48, page_block=BLK,
                           pool_pages=24)
        base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 2, key)
        rng = np.random.default_rng(37)
        tpls = [rng.integers(1, cfg.vocab, 16).astype(np.int32)
                for _ in range(2)]
        reqs = []
        for i in range(10):
            c = i % 2
            tpl = tpls[c]
            suffix = rng.integers(1, cfg.vocab, 1 + i % 3).astype(np.int32)
            reqs.append(Request(
                client_id=c,
                prompt=np.concatenate([tpl, suffix])[None, :],
                max_new_tokens=5 + i % 4, arrive_tick=i))
        on = _engine(cfg, scfg, base, bank, lora_cfg, policy=policy)
        off = _engine(cfg, scfg, base, bank, lora_cfg, policy=policy,
                      prefix_cache=False)
        got, ref = _run(on, reqs), _run(off, reqs)
        assert set(got) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])
        if policy != "lockstep":
            assert on.stats["prefix_hits"] >= 4
        assert not check_conservation(on)
