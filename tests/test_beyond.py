"""Beyond-paper optimizations (EXPERIMENTS.md §Beyond): exactness proofs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, DENSE
from repro.core import symbiosis
from repro.models import blocks
from repro.models.blocks import DEFAULT_LIN
from conftest import tiny


class TestHeadPadding:
    """§Perf it5: zero-weight q-head padding is mathematically inert when the
    pads are interleaved per KV group (padded wo rows are zero)."""

    def _pair(self):
        cfg0 = tiny(DENSE, n_heads=4, n_kv_heads=2, head_dim=16)
        cfgp = dataclasses.replace(cfg0, head_pad=2)
        p0 = blocks.attn_init(jax.random.PRNGKey(0), cfg0, jnp.float32)
        hd, K, G, pg, d = 16, 2, 2, 1, cfg0.d_model
        wq = p0["wq"].reshape(d, K, G, hd)
        wq = jnp.concatenate([wq, jnp.zeros((d, K, pg, hd))], 2).reshape(d, -1)
        wo = p0["wo"].reshape(K, G, hd, d)
        wo = jnp.concatenate([wo, jnp.zeros((K, pg, hd, d))], 1).reshape(-1, d)
        pp = dict(p0, wq=wq, wo=wo)
        return cfg0, cfgp, p0, pp

    def test_forward_exact(self):
        cfg0, cfgp, p0, pp = self._pair()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg0.d_model))
        pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
        y0 = blocks.mha_forward(p0, cfg0, x, pos, DEFAULT_LIN)
        yp = blocks.mha_forward(pp, cfgp, x, pos, DEFAULT_LIN)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(yp), atol=1e-5)

    def test_decode_exact(self):
        cfg0, cfgp, p0, pp = self._pair()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg0.d_model))
        ck = jnp.zeros((2, 16, 2, 16))
        cv = jnp.zeros((2, 16, 2, 16))
        pos = jnp.zeros((2,), jnp.int32)
        o0, *_ = blocks.mha_decode(p0, cfg0, x, ck, cv, pos, DEFAULT_LIN)
        op, *_ = blocks.mha_decode(pp, cfgp, x, ck, cv, pos, DEFAULT_LIN)
        np.testing.assert_allclose(np.asarray(o0), np.asarray(op), atol=1e-5)

    def test_arctic_config_divisible(self):
        from repro.configs import get_config
        cfg = get_config("arctic-480b")
        assert cfg.n_heads == 56          # architecture-faithful
        assert cfg.hp % 16 == 0           # shards on the production mesh


class TestInt8KVCache:
    def test_decode_drift_bounded(self, key, lora_cfg):
        """§Perf it13: int8 cache tracks full-precision decode closely."""
        cfg = tiny(DENSE)
        base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 2, key)
        c_full = symbiosis.init_client_caches(cfg, 2, 2, 48)
        c_q = symbiosis.init_client_caches(cfg, 2, 2, 48, quant=True)
        dec = jax.jit(symbiosis.make_multi_client_decode_step(
            cfg, lora_cfg, ServeConfig()))
        tok = jnp.ones((2, 2), jnp.int32)
        for _ in range(12):
            lf, c_full = dec(base, bank, c_full, tok)
            lq, c_q = dec(base, bank, c_q, tok)
            drift = float(jnp.abs(jax.nn.softmax(lf) - jax.nn.softmax(lq)).max())
            assert drift < 0.02, f"prob drift {drift}"
            tok = jnp.argmax(lf, -1).astype(jnp.int32)

    def test_quant_cache_is_int8(self):
        cfg = tiny(DENSE)
        c = symbiosis.init_client_caches(cfg, 1, 1, 16, quant=True)
        assert c["layers"]["k"].dtype == jnp.int8
        assert c["layers"]["k_s"].dtype == jnp.float32
        # bytes: int8 cache + 1/hd scales ~= 0.53x of bf16
        bf16 = symbiosis.init_client_caches(
            tiny(DENSE, dtype="bfloat16"), 1, 1, 16)
        from repro.common.tree import tree_bytes
        assert tree_bytes(c) < 0.7 * tree_bytes(bf16) * 2


class TestFlashAttention:
    def test_flash_matches_bruteforce(self):
        """The T>8192 online-softmax path is exact (§Perf it1-3)."""
        import math
        cfg = tiny(DENSE, n_heads=4, n_kv_heads=2, head_dim=16)
        p = blocks.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        S = 16384   # triggers flash (T > 8192)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
        y = blocks.mha_forward(p, cfg, x, pos, DEFAULT_LIN)
        # brute force on a slice of queries against the full prefix
        q = (x @ p["wq"]).reshape(1, S, 4, 16)
        k = jnp.repeat((x @ p["wk"]).reshape(1, S, 2, 16), 2, 2)
        v = jnp.repeat((x @ p["wv"]).reshape(1, S, 2, 16), 2, 2)
        q = blocks.apply_rope(q, pos, cfg.rope_theta)
        k = blocks.apply_rope(k, pos, cfg.rope_theta)
        rows = jnp.array([0, 1, S // 2, S - 1])
        s = jnp.einsum("bshd,bthd->bhst", q[:, rows], k) / math.sqrt(16)
        mask = pos[:, None, rows, None] >= pos[:, None, None, :]
        s = jnp.where(mask, s, -1e30)
        ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(s, -1), v)
        ref = ref.reshape(1, 4, 64) @ p["wo"]
        np.testing.assert_allclose(np.asarray(y[:, rows]), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_flash_gradients_finite(self):
        cfg = tiny(DENSE, n_heads=2, n_kv_heads=2, head_dim=16)
        cfg = dataclasses.replace(cfg, d_model=32)
        p = blocks.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        S = 16384
        x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 32)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
        g = jax.grad(lambda x_: blocks.mha_forward(p, cfg, x_, pos,
                                                   DEFAULT_LIN).sum())(x)
        assert np.isfinite(np.asarray(g)).all()
