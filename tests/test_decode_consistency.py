"""Prefill/decode vs full forward consistency — the cache math is exact.

For each family: forward(prompt + generated) logits at the last position
must match prefill(prompt) -> decode(token)* stepwise logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DENSE, MOE, RWKV, HYBRID, VLM
from repro.models import get_model
from conftest import tiny

FAMS = [DENSE, RWKV, HYBRID, VLM]
# MoE excluded from exactness: capacity-based dispatch depends on the token
# count in flight (prefill batch vs single token), so logits match only when
# no token is dropped — covered separately below.


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_then_decode_matches_forward(arch, cache_kw=None):
    cfg = tiny(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    base = model.init_params(key)
    B, S, n_new = 2, 8, 3
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    extra = {}
    if arch == VLM:
        extra["img_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02

    max_seq = S + n_new + 1 + (cfg.n_frontend_tokens if arch == VLM else 0)
    cache = model.init_cache(B, max_seq, **(cache_kw or {}))
    logits_p, cache = model.prefill(base, {"tokens": prompt, **extra}, cache)

    toks = [jnp.argmax(logits_p, -1).astype(jnp.int32)]
    dec_logits = [logits_p]
    for _ in range(n_new):
        lg, cache = model.decode_step(base, cache, toks[-1])
        dec_logits.append(lg)
        toks.append(jnp.argmax(lg, -1).astype(jnp.int32))

    seq = jnp.concatenate([prompt] + [t[:, None] for t in toks[:-1]], axis=1)
    logits_f, _ = model.forward(base, {"tokens": seq, **extra}, remat=False)
    prefix = cfg.n_frontend_tokens if arch == VLM else 0  # image tokens lead
    for i in range(n_new + 1):
        pos = prefix + S - 1 + i
        np.testing.assert_allclose(
            np.asarray(dec_logits[i]), np.asarray(logits_f[:, pos]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {i} diverges from forward")


@pytest.mark.tier2
@pytest.mark.parametrize("arch", [a for a in FAMS if a != RWKV])
def test_prefill_then_decode_matches_forward_paged(arch):
    """Same consistency bar through the paged KV layout (attention-bearing
    families; RWKV has no KV cache to page)."""
    test_prefill_then_decode_matches_forward(arch, cache_kw={"page_block": 4})


def test_moe_decode_runs_finite():
    cfg = tiny(MOE)
    model = get_model(cfg)
    base = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 16)
    logits, cache = model.prefill(base, {"tokens": jnp.ones((2, 8), jnp.int32)}, cache)
    logits2, _ = model.decode_step(base, cache, jnp.argmax(logits, -1).astype(jnp.int32))
    assert np.isfinite(np.asarray(logits2)).all()


def test_ring_cache_matches_full_cache():
    """Sliding-window ring decode == full-depth decode (beyond-paper)."""
    cfg = tiny(DENSE, sliding_window=8)
    model = get_model(cfg)
    base = model.init_params(jax.random.PRNGKey(0))
    B = 2
    full = model.init_cache(B, 64)
    ring = model.init_cache(B, 64, window=16)
    tok = jnp.ones((B,), jnp.int32)
    for i in range(40):
        lf, full = model.decode_step(base, full, tok)
        lr, ring = model.decode_step(base, ring, tok, ring=True)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                                   rtol=1e-4, atol=1e-4)
        tok = jnp.argmax(lf, -1).astype(jnp.int32)
