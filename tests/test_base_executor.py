"""Host-level base executor: packed ragged execution matches direct matmul."""
import jax.numpy as jnp
import numpy as np

from repro.core.base_executor import BaseExecutor, calibrate_layer_cost


class TestBaseExecutor:
    def test_ragged_batch_exact(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
        ex = BaseExecutor({(0, "q"): (w, b), (1, "q"): (w, None)})
        segs = [rng.normal(size=(n, 16)).astype(np.float32) for n in (5, 1, 9)]
        outs = ex.run_layer(0, "q", segs)
        for s, o in zip(segs, outs):
            np.testing.assert_allclose(o, s @ np.asarray(w) + np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
        outs2 = ex.run_layer(1, "q", segs[:1])
        np.testing.assert_allclose(outs2[0], segs[0] @ np.asarray(w),
                                   rtol=1e-4, atol=1e-5)

    def test_stats_track_batching(self):
        w = jnp.ones((4, 4))
        ex = BaseExecutor({(0, "q"): (w, None)})
        ex.run_layer(0, "q", [np.ones((2, 4), np.float32)] * 3)
        ex.run_layer(0, "q", [np.ones((1, 4), np.float32)])
        assert ex.stats["calls"] == 2
        assert ex.stats["batched_requests"] == 4
        assert ex.stats["avg_batch"] == 2.0

    def test_calibration_positive(self):
        overhead, per_token = calibrate_layer_cost(din=64, dout=64, reps=2)
        assert overhead > 0 and per_token > 0
