"""Opportunistic batching policies (paper §3.7, Tables 4/5)."""
import pytest
pytest.importorskip("hypothesis")  # property sweeps are optional-dep gated
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import ClientSpec, simulate

N_LAYERS = 8
EXEC_OVERHEAD = 1e-4
PER_TOKEN = 1e-6


def uniform_clients(n, tokens=64, cs_time=5e-5, iters=3):
    return [ClientSpec(client_id=i, n_tokens=tokens, client_side_time=cs_time,
                       n_iterations=iters) for i in range(n)]


def hetero_clients():
    """The Table 5 setting: batch sizes 2..256, different adapters => very
    different client-side times."""
    specs = []
    for i, (tok, cs) in enumerate([(2, 2e-5), (16, 6e-5), (64, 2e-4), (256, 8e-4)]):
        specs.append(ClientSpec(client_id=i, n_tokens=tok, client_side_time=cs,
                                n_iterations=4, latency_sensitive=(tok <= 2)))
    return specs


class TestPolicies:
    def test_lockstep_batches_everyone(self):
        r = simulate(uniform_clients(4), N_LAYERS, "lockstep",
                     EXEC_OVERHEAD, PER_TOKEN)
        assert r.avg_batch_size == pytest.approx(4.0, abs=0.5)

    def test_nolockstep_batch_of_one(self):
        r = simulate(uniform_clients(4), N_LAYERS, "nolockstep",
                     EXEC_OVERHEAD, PER_TOKEN)
        assert r.avg_batch_size == 1.0

    def test_opportunistic_between(self):
        r = simulate(uniform_clients(6), N_LAYERS, "opportunistic",
                     EXEC_OVERHEAD, PER_TOKEN, wait_fraction=0.2)
        assert 1.0 < r.avg_batch_size <= 6.0

    def test_table5_ordering(self):
        """Paper Table 5: opportunistic beats lockstep on latency AND beats
        nolockstep on throughput for heterogeneous clients."""
        lock = simulate(hetero_clients(), N_LAYERS, "lockstep",
                        EXEC_OVERHEAD, PER_TOKEN)
        nolock = simulate(hetero_clients(), N_LAYERS, "nolockstep",
                          EXEC_OVERHEAD, PER_TOKEN)
        opp = simulate(hetero_clients(), N_LAYERS, "opportunistic",
                       EXEC_OVERHEAD, PER_TOKEN, wait_fraction=0.1)
        mean_lat = lambda r: sum(r.per_client_latency.values()) / 4
        assert mean_lat(opp) < mean_lat(lock), "opportunistic should cut wait"
        assert opp.throughput >= nolock.throughput * 0.9

    def test_lockstep_small_waits_for_large(self):
        """Table 4's pathology: a small request's latency is inflated by the
        large request it is locked to."""
        small = ClientSpec(0, n_tokens=1, client_side_time=1e-5, n_iterations=2)
        large = ClientSpec(1, n_tokens=512, client_side_time=2e-3, n_iterations=2)
        lock = simulate([small, large], N_LAYERS, "lockstep",
                        EXEC_OVERHEAD, PER_TOKEN)
        free = simulate([small, large], N_LAYERS, "opportunistic",
                        EXEC_OVERHEAD, PER_TOKEN, wait_fraction=0.1)
        assert free.per_client_latency[0] < lock.per_client_latency[0] * 0.7

    @given(n=st.integers(1, 8), iters=st.integers(1, 4),
           policy=st.sampled_from(["lockstep", "nolockstep", "opportunistic"]))
    @settings(max_examples=30, deadline=None)
    def test_conservation(self, n, iters, policy):
        """Every client finishes every iteration under every policy."""
        r = simulate(uniform_clients(n, iters=iters), N_LAYERS, policy,
                     EXEC_OVERHEAD, PER_TOKEN)
        assert r.total_tokens == n * 64 * iters
        assert all(v > 0 for v in r.per_client_latency.values())
        assert r.makespan > 0

    def test_backward_doubles_layers(self):
        fwd = simulate(uniform_clients(2, iters=1), N_LAYERS, "nolockstep",
                       EXEC_OVERHEAD, PER_TOKEN)
        fb = simulate(uniform_clients(2, iters=1), N_LAYERS, "nolockstep",
                      EXEC_OVERHEAD, PER_TOKEN, backward=True)
        assert fb.n_executions == 2 * fwd.n_executions
