"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates a REDUCED same-family variant (2 layers, d_model<=512,
<=4 experts) and runs one forward/train step + one prefill/decode step on
CPU, asserting output shapes and no NaNs. The FULL configs are exercised by
the dry-run only (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AdapterConfig, TrainConfig, ServeConfig, SHAPES, VLM
from repro.configs import ASSIGNED, get_config
from repro.core import symbiosis
from repro.data import frontend_stub
from repro.launch.specs import is_applicable

ACFG = AdapterConfig(method="lora", rank=4, targets=("q", "k", "v", "o"))


def _reduced(arch_id):
    cfg = get_config(arch_id).reduced(n_layers=2, d_model=256, n_experts=4,
                                      vocab=512)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512 and cfg.n_experts <= 4
    return cfg


@pytest.mark.parametrize("arch_id", ASSIGNED)
class TestArchSmoke:
    def test_train_step(self, arch_id):
        cfg = _reduced(arch_id)
        C, B, S = 2, 2, 32
        key = jax.random.PRNGKey(0)
        base, bank, opt = symbiosis.init_system(cfg, ACFG, C, key)
        tcfg = TrainConfig(n_clients=C, remat=True)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, ACFG, tcfg))
        batch = {"tokens": jax.random.randint(key, (C, B, S), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (C, B, S), 0, cfg.vocab)}
        batch.update(frontend_stub(cfg, C, B))
        bank2, opt2, m = step(base, bank, opt, batch, 0)
        loss = np.asarray(m["loss"])
        assert loss.shape == (C,)
        assert np.isfinite(loss).all(), f"{arch_id}: NaN loss"
        for a, b in zip(jax.tree.leaves(bank), jax.tree.leaves(bank2)):
            assert a.shape == b.shape
            assert np.isfinite(np.asarray(b)).all()

    def test_prefill_decode(self, arch_id):
        cfg = _reduced(arch_id)
        C, B, S = 2, 2, 16
        key = jax.random.PRNGKey(0)
        base, bank, _ = symbiosis.init_system(cfg, ACFG, C, key)
        # VLM prefill writes image-prefix + text positions into the cache
        max_seq = S + 8 + (cfg.n_frontend_tokens if cfg.arch == VLM else 0)
        scfg = ServeConfig(n_clients=C, max_seq=max_seq)
        caches = symbiosis.init_client_caches(cfg, C, B, max_seq)
        prefill = jax.jit(symbiosis.make_multi_client_prefill(cfg, ACFG, scfg))
        decode = jax.jit(symbiosis.make_multi_client_decode_step(cfg, ACFG, scfg))
        batch = {"tokens": jax.random.randint(key, (C, B, S), 0, cfg.vocab)}
        batch.update(frontend_stub(cfg, C, B))
        logits, caches = prefill(base, bank, caches, batch)
        assert logits.shape == (C, B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch_id}: NaN prefill"
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, caches = decode(base, bank, caches, tok)
        assert logits2.shape == (C, B, cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all(), f"{arch_id}: NaN decode"
        expect_pos = S + 1 + (cfg.n_frontend_tokens if cfg.arch == VLM else 0)
        assert int(np.asarray(caches["pos"]).max()) == expect_pos

    def test_shape_assignments_covered(self, arch_id):
        """Every assigned (arch × shape) is either applicable or has a
        documented skip (DESIGN.md §6)."""
        for shape in SHAPES:
            ok, note = is_applicable(arch_id, shape)
            if not ok:
                assert shape == "long_500k", f"unexpected skip {arch_id}×{shape}"
                assert note
