"""Activation-noise privacy (paper §3.8): exactness + end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DENSE
from repro.core import privacy
from repro.core.virtlayer import make_client_ctx, attach_privacy
from repro.core.frozen_linear import frozen_dense
from repro.models import get_model
from conftest import tiny


class TestNoiseProtocol:
    def test_exact_cancellation_linear(self, key):
        """y = ((x+n)W + b) - nW == xW + b, exactly up to fp re-association."""
        x = jax.random.normal(key, (4, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        b = jax.random.normal(jax.random.PRNGKey(2), (8,))
        n = jax.random.normal(jax.random.PRNGKey(3), (16,)) * 10.0
        n_eff = n @ w                       # bias-free executor flow
        y = privacy.private_dense(frozen_dense, x, w, b, "q", n, n_eff)
        np.testing.assert_allclose(y, x @ w + b, rtol=1e-4, atol=1e-4)

    def test_variant_rotation(self, key):
        dims = {"q": (16, 8), "v": (16, 8)}
        noise = privacy.make_noise(key, dims, n_variants=3)
        assert noise["q"].shape == (3, 16)
        w = {p: jax.random.normal(jax.random.fold_in(key, i), d)
             for i, (p, d) in enumerate(dims.items())}
        eff = privacy.noise_effect(noise, w)
        for v in range(3):
            nv = privacy.select_variant(noise, "q", v)
            np.testing.assert_allclose(eff["q"][v], nv @ w["q"], rtol=1e-5)

    def test_noisy_activations_differ(self, key):
        """What the executor sees (x+n) must not reveal x."""
        x = jax.random.normal(key, (4, 16))
        n = jax.random.normal(jax.random.PRNGKey(3), (16,)) * 5.0
        assert float(jnp.abs((x + n) - x).min()) > 0.1


class TestEndToEndPrivacy:
    def test_model_output_unchanged(self, key, lora_cfg):
        """Paper: 'the model produces the exact output which it otherwise
        would have' — full model forward with privacy == without."""
        cfg = tiny(DENSE)
        model = get_model(cfg)
        base = model.init_params(key)
        from repro.core import adapters as ad_lib
        adapter = ad_lib.init_adapter(cfg, lora_cfg, jax.random.PRNGKey(7))

        dims = {p: d for p, d in ad_lib.resolve_targets(cfg, lora_cfg)}
        dims = {"q": dims["q"], "v": dims["v"]}
        noise = privacy.make_noise(jax.random.PRNGKey(9), dims, n_variants=2,
                                   scale=3.0)
        adapter_p = attach_privacy(adapter, cfg, base, noise)

        ctx_plain = make_client_ctx(cfg, lora_cfg)
        ctx_priv = make_client_ctx(cfg, lora_cfg, privacy_noise=noise,
                                   privacy_variant=1)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
        y0, _ = model.forward(base, batch, ctx_plain, adapter)
        y1, _ = model.forward(base, batch, ctx_priv, adapter_p)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-3, atol=2e-3)

    def test_privacy_trains(self, key, lora_cfg):
        """Fine-tuning through the privacy protocol still converges to the
        same gradients (linearity means cancellation holds in the vjp)."""
        cfg = tiny(DENSE)
        model = get_model(cfg)
        base = model.init_params(key)
        from repro.core import adapters as ad_lib
        adapter = ad_lib.init_adapter(cfg, lora_cfg, jax.random.PRNGKey(7))
        dims = {p: d for p, d in ad_lib.resolve_targets(cfg, lora_cfg)}
        noise = privacy.make_noise(jax.random.PRNGKey(9), dims, scale=2.0)
        adapter_p = attach_privacy(adapter, cfg, base, noise)
        ctx_priv = make_client_ctx(cfg, lora_cfg, privacy_noise=noise)
        ctx_plain = make_client_ctx(cfg, lora_cfg)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}

        def loss(ad, ctx, full_ad):
            merged = {**full_ad, "layers": {**full_ad["layers"], **ad}}
            logits, _ = model.forward(base, batch, ctx, merged)
            return (logits ** 2).mean()

        g_p = jax.grad(loss)(
            {k: adapter_p["layers"][k] for k in ("q", "v")}, ctx_priv, adapter_p)
        g_0 = jax.grad(loss)(
            {k: adapter["layers"][k] for k in ("q", "v")}, ctx_plain, adapter)
        for a, b in zip(jax.tree.leaves(g_p), jax.tree.leaves(g_0)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-2, atol=1e-4)
