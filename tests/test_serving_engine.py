"""Multi-tenant serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AdapterConfig, ServeConfig, DENSE
from repro.core import symbiosis
from repro.serving.engine import ServingEngine, Request
from repro.serving import kvcache
from conftest import tiny


@pytest.fixture
def system(key, lora_cfg):
    cfg = tiny(DENSE)
    scfg = ServeConfig(n_clients=3, max_seq=48)
    base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 3, key)
    return cfg, scfg, base, bank


class TestEngine:
    def test_generation_matches_direct_decode(self, system, lora_cfg):
        """Engine outputs == a hand-rolled prefill+decode loop for the same
        client (batching across clients must not change results — the
        paper's exactness claim at the serving layer)."""
        cfg, scfg, base, bank = system
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank, max_batch_per_client=2)
        rng = np.random.default_rng(0)
        prompts = {c: rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
                   for c in range(3)}
        for c in range(3):
            eng.submit(Request(client_id=c, prompt=prompts[c], max_new_tokens=5))
        done = {r.client_id: r for r in eng.run()}

        # direct single-client reference
        from repro.models import get_model
        from repro.core.virtlayer import make_client_ctx
        model = get_model(cfg)
        ctx = make_client_ctx(cfg, lora_cfg)
        for c in range(3):
            adapter = jax.tree.map(lambda x: x[c], bank)
            cache = model.init_cache(2, scfg.max_seq)
            logits, cache = model.prefill(base, {"tokens": jnp.asarray(prompts[c])},
                                          cache, ctx, adapter)
            toks = [np.asarray(jnp.argmax(logits, -1), np.int32)]
            for _ in range(4):
                lg, cache = model.decode_step(base, cache,
                                              jnp.asarray(toks[-1]), ctx, adapter)
                toks.append(np.asarray(jnp.argmax(lg, -1), np.int32))
            ref = np.stack(toks, axis=1)
            np.testing.assert_array_equal(done[c].generated, ref,
                                          err_msg=f"client {c} diverged")

    def test_clients_at_different_rates(self, system, lora_cfg):
        """Client independence: different max_new_tokens finish independently."""
        cfg, scfg, base, bank = system
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank, max_batch_per_client=1)
        rng = np.random.default_rng(1)
        eng.submit(Request(0, rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32),
                           max_new_tokens=2))
        eng.submit(Request(1, rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32),
                           max_new_tokens=9))
        done = eng.run()
        assert {r.generated.shape[1] for r in done} == {2, 9}


class TestCacheSpec:
    def test_kv_bytes_formula(self):
        cfg = tiny(DENSE, dtype="bfloat16")
        spec = kvcache.make_cache_spec(cfg)
        expect = cfg.n_layers * cfg.n_kv_heads * cfg.hd * 2 * 2
        assert spec.bytes_per_token == expect
        assert spec.total_bytes(100, 2) == expect * 200

    def test_rwkv_constant_in_seq(self):
        from repro.config import RWKV
        cfg = tiny(RWKV)
        spec = kvcache.make_cache_spec(cfg)
        assert spec.bytes_per_token == 0
        assert spec.total_bytes(1_000_000, 1) == spec.total_bytes(10, 1)

    def test_placement_crossover(self):
        """Fig 19's shape: hetero beats gpu_offload beyond some context."""
        from repro.configs import get_config
        cfg = get_config("symbiosis-llama2-13b")
        short = kvcache.decode_token_cost(cfg, 2_000, placement="gpu")
        short_h = kvcache.decode_token_cost(cfg, 2_000, placement="hetero")
        long = kvcache.decode_token_cost(cfg, 131_072, placement="gpu_offload")
        long_g = kvcache.decode_token_cost(cfg, 131_072, placement="gpu")
        long_h = kvcache.decode_token_cost(cfg, 131_072, placement="hetero")
        assert short.total < short_h.total, "all-GPU wins short contexts"
        assert long_g.total == float("inf"), "all-GPU OOMs at 131k (Fig 19)"
        assert long_h.total < long.total, "hetero must win long contexts"
