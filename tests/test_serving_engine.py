"""Multi-tenant serving engine behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, DENSE
from repro.core import symbiosis
from repro.serving.engine import ServingEngine, Request, SamplingParams
from repro.serving import kvcache
from repro.serving.router import PlacementRouter, Slot
from conftest import tiny


def _solo_reference(cfg, scfg, base, bank, lora_cfg, req, max_b):
    """Serve one request alone through a fresh engine — the baseline the
    paper's exactness claim compares against."""
    eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                        max_batch_per_client=max_b)
    solo = Request(client_id=req.client_id, prompt=req.prompt.copy(),
                   max_new_tokens=req.max_new_tokens,
                   sampling=req.sampling)
    eng.submit(solo)
    (done,) = eng.run()
    return done.generated


@pytest.fixture
def system(key, lora_cfg):
    cfg = tiny(DENSE)
    scfg = ServeConfig(n_clients=3, max_seq=48)
    base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 3, key)
    return cfg, scfg, base, bank


class TestEngine:
    def test_generation_matches_direct_decode(self, system, lora_cfg):
        """Engine outputs == a hand-rolled prefill+decode loop for the same
        client (batching across clients must not change results — the
        paper's exactness claim at the serving layer)."""
        cfg, scfg, base, bank = system
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank, max_batch_per_client=2)
        rng = np.random.default_rng(0)
        prompts = {c: rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
                   for c in range(3)}
        for c in range(3):
            eng.submit(Request(client_id=c, prompt=prompts[c], max_new_tokens=5))
        done = {r.client_id: r for r in eng.run()}

        # direct single-client reference
        from repro.models import get_model
        from repro.core.virtlayer import make_client_ctx
        model = get_model(cfg)
        ctx = make_client_ctx(cfg, lora_cfg)
        for c in range(3):
            adapter = jax.tree.map(lambda x, c=c: x[c], bank)
            cache = model.init_cache(2, scfg.max_seq)
            logits, cache = model.prefill(base, {"tokens": jnp.asarray(prompts[c])},
                                          cache, ctx, adapter)
            toks = [np.asarray(jnp.argmax(logits, -1), np.int32)]
            for _ in range(4):
                lg, cache = model.decode_step(base, cache,
                                              jnp.asarray(toks[-1]), ctx, adapter)
                toks.append(np.asarray(jnp.argmax(lg, -1), np.int32))
            ref = np.stack(toks, axis=1)
            np.testing.assert_array_equal(done[c].generated, ref,
                                          err_msg=f"client {c} diverged")

    def test_clients_at_different_rates(self, system, lora_cfg):
        """Client independence: different max_new_tokens finish independently."""
        cfg, scfg, base, bank = system
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank, max_batch_per_client=1)
        rng = np.random.default_rng(1)
        eng.submit(Request(0, rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32),
                           max_new_tokens=2))
        eng.submit(Request(1, rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32),
                           max_new_tokens=9))
        done = eng.run()
        assert {r.generated.shape[1] for r in done} == {2, 9}


class TestContinuousBatching:
    def _workload(self, cfg, rng, *, n=6, rows=1, max_new=(3, 9)):
        reqs = []
        for i in range(n):
            reqs.append(Request(
                client_id=i % 3,
                prompt=rng.integers(0, cfg.vocab, (rows, 4 + 2 * (i % 3))).astype(np.int32),
                max_new_tokens=max_new[i % len(max_new)],
                arrive_tick=2 * i,          # staggered: joins mid-stream
            ))
        return reqs

    @pytest.mark.parametrize("policy", ["lockstep", "nolockstep", "opportunistic"])
    def test_staggered_arrivals_policy_invariant(self, system, lora_cfg, policy):
        """Continuous batching with staggered arrivals produces byte-identical
        greedy outputs to serving each request alone, under every policy —
        the paper's exact-output property at the serving layer."""
        cfg, scfg, base, bank = system
        rng = np.random.default_rng(7)
        reqs = self._workload(cfg, rng)
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=2, policy=policy)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == len(reqs)
        for r in done:
            ref = _solo_reference(cfg, scfg, base, bank, lora_cfg, r, 2)
            np.testing.assert_array_equal(
                r.generated, ref,
                err_msg=f"policy={policy} client {r.client_id} diverged from solo")

    def test_slot_reuse_midstream(self, system, lora_cfg):
        """More requests than slots: a finishing sequence's slot is re-admitted
        from the queue while other sequences keep decoding, and every
        occupant's output still matches solo serving."""
        cfg, scfg, base, bank = system
        rng = np.random.default_rng(3)
        # 5 requests for ONE client with 2 slots -> forced slot turnover,
        # plus a long-running request on another client that spans it all.
        reqs = [Request(client_id=0,
                        prompt=rng.integers(0, cfg.vocab, (1, 4 + i)).astype(np.int32),
                        max_new_tokens=2 + i)
                for i in range(5)]
        reqs.append(Request(client_id=1,
                            prompt=rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32),
                            max_new_tokens=16))
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=2)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 6
        # with 2 slots and 5 queued client-0 requests there must be overlap
        assert eng.stats["batched_clients"] > eng.stats["ticks"]
        for r in done:
            ref = _solo_reference(cfg, scfg, base, bank, lora_cfg, r, 2)
            np.testing.assert_array_equal(r.generated, ref)

    def test_sampling_schedule_invariant(self, system, lora_cfg):
        """Seeded temperature/top-k sampling draws depend only on the
        request's own stream -> identical under different policies."""
        cfg, scfg, base, bank = system
        rng = np.random.default_rng(11)
        outs = {}
        for policy in ("opportunistic", "nolockstep"):
            reqs = [Request(client_id=c,
                            prompt=rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                            max_new_tokens=6,
                            sampling=SamplingParams(method=m, temperature=0.8,
                                                    top_k=8, seed=17 + c))
                    for c, m in [(0, "temperature"), (1, "top_k"), (2, "greedy")]]
            rng = np.random.default_rng(11)    # same prompts per policy
            eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                                max_batch_per_client=1, policy=policy)
            for r in reqs:
                eng.submit(r)
            outs[policy] = {r.client_id: r.generated for r in eng.run()}
        for c in range(3):
            np.testing.assert_array_equal(outs["opportunistic"][c],
                                          outs["nolockstep"][c])

    def test_stats_count_tokens_not_clients(self, system, lora_cfg):
        """Regression: decode_tokens must count generated tokens (slots
        advanced), not ready clients."""
        cfg, scfg, base, bank = system
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=2)
        rng = np.random.default_rng(0)
        n_new = 5
        eng.submit(Request(0, rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32),
                           max_new_tokens=n_new))
        done = eng.run()
        # 2 rows x (n_new - 1) decode steps (first token comes from prefill)
        assert eng.stats["decode_tokens"] == 2 * (n_new - 1)
        assert eng.stats["prefill_tokens"] == 2 * 8
        assert done[0].generated.shape == (2, n_new)

    def test_router_admission_backpressure(self, system, lora_cfg):
        """With a router whose fleet fits one session at a time, requests
        queue until capacity is released, then all complete. The dense
        engine charges a full max_seq-deep slot row (what the dense layout
        physically pins), not the request's context."""
        cfg, scfg, base, bank = system
        need = kvcache.cache_bytes(cfg, scfg.max_seq, 1)
        router = PlacementRouter(cfg, [Slot(0, free_hbm=need * 1.5)],
                                 host_free_bytes=0)
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=1, router=router)
        rng = np.random.default_rng(5)
        for c in range(3):
            eng.submit(Request(c, rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                               max_new_tokens=4))
        done = eng.run()
        assert len(done) == 3
        # serialized by capacity: never more than one client batched per tick
        assert eng.stats["batched_clients"] <= eng.stats["ticks"]
        assert router.slots[0].free_hbm == pytest.approx(need * 1.5)

    def test_recurrent_family_exact_through_slot_reuse(self, key, lora_cfg):
        """Hybrid (Mamba+attention): admission zeroes a slot's recurrent
        state before prefill, so a previous occupant never leaks into the
        next sequence — outputs stay byte-exact through slot turnover."""
        from repro.config import HYBRID
        cfg = tiny(HYBRID)
        scfg = ServeConfig(n_clients=2, max_seq=32)
        base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 2, key)
        rng = np.random.default_rng(0)
        reqs = [Request(0, rng.integers(0, cfg.vocab, (1, 5)).astype(np.int32),
                        max_new_tokens=4),
                Request(1, rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                        max_new_tokens=6, arrive_tick=1),
                Request(0, rng.integers(0, cfg.vocab, (1, 5)).astype(np.int32),
                        max_new_tokens=3, arrive_tick=2)]
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=1)   # forces slot reuse
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 3
        for r in done:
            ref = _solo_reference(cfg, scfg, base, bank, lora_cfg, r, 1)
            np.testing.assert_array_equal(r.generated, ref)

    def test_bankwide_prefill_ablation_matches(self, system, lora_cfg):
        """The seed-style bank-wide prefill path produces the same outputs
        (it only wastes compute) — used by the benchmark comparison."""
        cfg, scfg, base, bank = system
        rng = np.random.default_rng(9)
        prompts = {c: rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32)
                   for c in range(3)}
        outs = {}
        for mode in (False, True):
            eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                                max_batch_per_client=1, bank_prefill=mode)
            for c in range(3):
                eng.submit(Request(c, prompts[c].copy(), max_new_tokens=5))
            outs[mode] = {r.client_id: r.generated for r in eng.run()}
        for c in range(3):
            np.testing.assert_array_equal(outs[False][c], outs[True][c])


class TestPagedServing:
    """ISSUE 2 tentpole: paged + quantized KV slots in the engine."""

    def _run(self, cfg, scfg, base, bank, lora_cfg, reqs, *, max_b=2, **kw):
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=max_b, **kw)
        for r in reqs:
            eng.submit(r)
        return eng, eng.run()

    def _workload(self, cfg, rng, n=6):
        return [Request(client_id=i % 3,
                        prompt=rng.integers(0, cfg.vocab,
                                            (1, 4 + 2 * (i % 3))).astype(np.int32),
                        max_new_tokens=(3, 9)[i % 2], arrive_tick=2 * i)
                for i in range(n)]

    def test_paged_engine_matches_dense(self, system, lora_cfg):
        """Fast tier-1 guard: one policy, paged == dense byte-identically."""
        self._policy_case(system, lora_cfg, "opportunistic")

    @pytest.mark.tier2
    @pytest.mark.parametrize("policy", ["lockstep", "nolockstep"])
    def test_paged_engine_matches_dense_policies(self, system, lora_cfg, policy):
        """Paged outputs are byte-identical to the dense engine under every
        tick policy (the acceptance bar of ISSUE 2)."""
        self._policy_case(system, lora_cfg, policy)

    def _policy_case(self, system, lora_cfg, policy):
        cfg, scfg, base, bank = system
        scfg_paged = dataclasses.replace(scfg, page_block=16)
        outs = {}
        for name, sc in (("dense", scfg), ("paged", scfg_paged)):
            rng = np.random.default_rng(7)
            _, done = self._run(cfg, sc, base, bank, lora_cfg,
                                self._workload(cfg, rng), policy=policy)
            outs[name] = sorted((r.client_id, r.prompt.tobytes(),
                                 r.generated.tobytes()) for r in done)
        assert outs["dense"] == outs["paged"]

    def test_page_reuse_no_cross_request_leakage(self, system, lora_cfg):
        """A finishing sequence's pages return to the pool and are re-used
        by the next admit; every occupant still matches solo serving, and
        the allocator drains clean (all pages free, no reservations)."""
        cfg, scfg, base, bank = system
        # pool of 6 8-token pages per client: each request needs 2-3 pages,
        # so 5 sequential client-0 requests MUST recycle pages
        scfg_paged = dataclasses.replace(scfg, page_block=8, pool_pages=6)
        rng = np.random.default_rng(3)
        reqs = [Request(client_id=0,
                        prompt=rng.integers(0, cfg.vocab, (1, 4 + i)).astype(np.int32),
                        max_new_tokens=2 + i)
                for i in range(5)]
        reqs.append(Request(client_id=1,
                            prompt=rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32),
                            max_new_tokens=16))
        eng, done = self._run(cfg, scfg_paged, base, bank, lora_cfg, reqs)
        assert len(done) == 6
        assert all(len(f) == 6 for f in eng._free_pages)
        assert eng._reserved == [0, 0, 0]
        for r in done:
            ref = _solo_reference(cfg, scfg, base, bank, lora_cfg, r, 2)
            np.testing.assert_array_equal(r.generated, ref)

    def test_pool_exhaustion_backpressures_admission(self, system, lora_cfg):
        """Two concurrent client-0 requests need 4 pages; a 3-page pool
        serializes them (admission waits for pages, not only for slots)."""
        cfg, scfg, base, bank = system
        scfg_paged = dataclasses.replace(scfg, page_block=8, pool_pages=3)
        rng = np.random.default_rng(5)
        reqs = [Request(0, rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                        max_new_tokens=4) for _ in range(2)]
        eng, done = self._run(cfg, scfg_paged, base, bank, lora_cfg, reqs)
        assert len(done) == 2
        assert eng.stats["peak_inflight"] == 1     # never concurrent
        for r in done:
            ref = _solo_reference(cfg, scfg, base, bank, lora_cfg, r, 2)
            np.testing.assert_array_equal(r.generated, ref)

    def test_paged_router_charges_pages_not_max_seq(self, system, lora_cfg):
        """At a fixed HBM budget that fits ONE dense max_seq row, the paged
        engine admits several short requests concurrently — the ISSUE 2
        admission claim at test scale."""
        cfg, scfg, base, bank = system
        budget = kvcache.cache_bytes(cfg, scfg.max_seq, 1) * 1.5
        rng = np.random.default_rng(5)
        reqs = lambda: [Request(c, rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                                max_new_tokens=4) for c in range(3)]
        eng_d, done_d = self._run(
            cfg, scfg, base, bank, lora_cfg, reqs(), max_b=1,
            router=PlacementRouter(cfg, [Slot(0, free_hbm=budget)],
                                   host_free_bytes=0))
        scfg_paged = dataclasses.replace(scfg, page_block=16)
        eng_p, done_p = self._run(
            cfg, scfg_paged, base, bank, lora_cfg, reqs(), max_b=1,
            router=PlacementRouter(cfg, [Slot(0, free_hbm=budget)],
                                   host_free_bytes=0))
        assert len(done_d) == len(done_p) == 3
        assert eng_d.stats["peak_inflight"] == 1   # dense: serialized by HBM
        assert eng_p.stats["peak_inflight"] == 3   # paged: all fit at once

    def test_quant_prefill_bucketed_matches_dense_tolerance(self, system, lora_cfg):
        """Regression (ISSUE 2 satellite): the engine buckets prefill
        lengths (6 -> 8 here) and prefills into int8-quantized slots; the
        quantized stream must track the dense one within int8 tolerance.
        Compares the post-prefill decode distributions step by step."""
        cfg, scfg, base, bank = system
        scfg_q = dataclasses.replace(scfg, kv_quant=True)
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32)  # buckets to 8
        logits = {}
        for name, sc in (("dense", scfg), ("quant", scfg_q)):
            eng = ServingEngine(cfg, lora_cfg, sc, base, bank,
                                max_batch_per_client=2)
            assert eng._bucket(6) == 8             # the bucketed path is hit
            eng.submit(Request(0, prompt.copy(), max_new_tokens=1))
            (done,) = eng.run()
            # prefill logits are layout-independent -> compare the argmax
            # token, then step the masked decode once on the filled caches
            active = np.zeros((3, 2), bool)
            active[0, 0] = True
            lg, _ = eng._decode(eng.base, eng.bank, eng.caches,
                                jnp.asarray(eng._last_tok), jnp.asarray(active))
            logits[name] = (done.generated.copy(), np.asarray(lg)[0, 0])
        np.testing.assert_array_equal(logits["dense"][0], logits["quant"][0])
        p_d = jax.nn.softmax(logits["dense"][1])
        p_q = jax.nn.softmax(logits["quant"][1])
        assert float(jnp.abs(p_d - p_q).max()) < 0.02

    def test_paged_quant_engine_serves(self, system, lora_cfg):
        """Paged + int8 compose in the live engine (the bench_multiclient
        admission configuration) and the allocator drains clean."""
        cfg, scfg, base, bank = system
        scfg_pq = dataclasses.replace(scfg, page_block=16, kv_quant=True)
        rng = np.random.default_rng(2)
        eng, done = self._run(cfg, scfg_pq, base, bank, lora_cfg,
                              self._workload(cfg, rng))
        assert len(done) == 6
        assert all(r.generated.shape[1] in (3, 9) for r in done)
        assert eng._reserved == [0, 0, 0]


class TestCacheSpec:
    def test_kv_bytes_formula(self):
        cfg = tiny(DENSE, dtype="bfloat16")
        spec = kvcache.make_cache_spec(cfg)
        expect = cfg.n_layers * cfg.n_kv_heads * cfg.hd * 2 * 2
        assert spec.bytes_per_token == expect
        assert spec.total_bytes(100, 2) == expect * 200

    def test_rwkv_constant_in_seq(self):
        from repro.config import RWKV
        cfg = tiny(RWKV)
        spec = kvcache.make_cache_spec(cfg)
        assert spec.bytes_per_token == 0
        assert spec.total_bytes(1_000_000, 1) == spec.total_bytes(10, 1)

    def test_placement_crossover(self):
        """Fig 19's shape: hetero beats gpu_offload beyond some context."""
        from repro.configs import get_config
        cfg = get_config("symbiosis-llama2-13b")
        short = kvcache.decode_token_cost(cfg, 2_000, placement="gpu")
        short_h = kvcache.decode_token_cost(cfg, 2_000, placement="hetero")
        long = kvcache.decode_token_cost(cfg, 131_072, placement="gpu_offload")
        long_g = kvcache.decode_token_cost(cfg, 131_072, placement="gpu")
        long_h = kvcache.decode_token_cost(cfg, 131_072, placement="hetero")
        assert short.total < short_h.total, "all-GPU wins short contexts"
        assert long_g.total == float("inf"), "all-GPU OOMs at 131k (Fig 19)"
        assert long_h.total < long.total, "hetero must win long contexts"


class TestRaggedPrefill:
    """Ragged shared prefill (ISSUE 4 satellite): several same-client
    admissions in one tick share ONE masked prefill call with per-row
    lengths, byte-identical to sequential per-request admission."""

    def _workload(self, cfg, rng):
        # client 0: three different-length prompts due the same tick
        # (ragged rows); client 1: two equal-length prompts; a straggler
        # arrives later and prefills alone
        reqs = [Request(0, rng.integers(0, cfg.vocab, (1, L)).astype(np.int32),
                        max_new_tokens=6) for L in (5, 9, 3)]
        reqs += [Request(1, rng.integers(0, cfg.vocab, (1, 7)).astype(np.int32),
                         max_new_tokens=5) for _ in range(2)]
        reqs.append(Request(1, rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32),
                            max_new_tokens=4, arrive_tick=3))
        return reqs

    @pytest.mark.parametrize("page_block", [0, 16])
    def test_ragged_matches_sequential(self, system, lora_cfg, page_block):
        cfg, scfg, base, bank = system
        sc = dataclasses.replace(scfg, page_block=page_block)
        outs, engines = {}, {}
        for name, ragged in (("ragged", True), ("sequential", False)):
            rng = np.random.default_rng(3)
            eng = ServingEngine(cfg, lora_cfg, sc, base, bank,
                                max_batch_per_client=3, ragged_prefill=ragged)
            for r in self._workload(cfg, rng):
                eng.submit(r)
            done = eng.run()
            outs[name] = sorted((r.client_id, r.prompt.tobytes(),
                                 r.generated.tobytes()) for r in done)
            engines[name] = eng
        assert outs["ragged"] == outs["sequential"]
        if page_block:
            # paged engines route batching through the CROSS-CLIENT compacted
            # prefill (ISSUE 10): the 3+2 same-tick admissions collapse into
            # ONE dispatch (+1 for the straggler)
            assert engines["ragged"].stats["compact_prefill_batches"] == 2
            assert engines["ragged"].stats["prefill_calls"] == 2
        else:
            # dense layout keeps the same-client masked ragged batch:
            # 2 ragged calls (+1 solo for the straggler)
            assert engines["ragged"].stats["ragged_prefill_batches"] == 2
            assert engines["ragged"].stats["prefill_calls"] == 3
        assert engines["sequential"].stats["prefill_calls"] == 6
        assert (engines["ragged"].stats["prefill_tokens"]
                == engines["sequential"].stats["prefill_tokens"])

    def test_ragged_rows_match_solo_serving(self, system, lora_cfg):
        """Each request in a shared ragged prefill still matches serving it
        alone — per-row lengths keep rows independent."""
        cfg, scfg, base, bank = system
        rng = np.random.default_rng(9)
        reqs = [Request(0, rng.integers(0, cfg.vocab, (1, L)).astype(np.int32),
                        max_new_tokens=5) for L in (4, 8)]
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank,
                            max_batch_per_client=2)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert eng.stats["ragged_prefill_batches"] == 1
        for r in done:
            ref = _solo_reference(cfg, scfg, base, bank, lora_cfg, r, 2)
            np.testing.assert_array_equal(r.generated, ref)

    def test_recurrent_families_reject_ragged(self, key, lora_cfg):
        """Right-padding rows to a shared bucket would pollute recurrent
        state: hybrid/RWKV engines refuse the knob (and default it off)."""
        from repro.config import HYBRID
        cfg = tiny(HYBRID)
        scfg = ServeConfig(n_clients=2, max_seq=48)
        base, bank, _ = symbiosis.init_system(cfg, lora_cfg, 2, key)
        with pytest.raises(ValueError, match="attention families"):
            ServingEngine(cfg, lora_cfg, scfg, base, bank,
                          ragged_prefill=True)
        eng = ServingEngine(cfg, lora_cfg, scfg, base, bank)
        assert not eng._ragged
