"""PEFT adapter bank semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig, DENSE, RWKV
from repro.core import adapters as ad_lib
from repro.core.virtlayer import make_client_ctx
from repro.models import get_model
from conftest import tiny


class TestLoRA:
    def test_starts_as_identity(self, key):
        """B == 0 at init => adapter output == base output exactly."""
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4, targets=("q", "v"))
        model = get_model(cfg)
        base = model.init_params(key)
        adapter = ad_lib.init_adapter(cfg, acfg, jax.random.PRNGKey(1))
        ctx = make_client_ctx(cfg, acfg)
        batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab)}
        with_ad, _ = model.forward(base, batch, ctx, adapter)
        without, _ = model.forward(base, batch, make_client_ctx(cfg, None), None)
        np.testing.assert_allclose(np.asarray(with_ad), np.asarray(without),
                                   rtol=1e-6)

    def test_nonzero_b_changes_output(self, key):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4, targets=("q", "v"))
        model = get_model(cfg)
        base = model.init_params(key)
        adapter = ad_lib.init_adapter(cfg, acfg, jax.random.PRNGKey(1))
        adapter = jax.tree.map(lambda x: x + 0.05, adapter)
        ctx = make_client_ctx(cfg, acfg)
        batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab)}
        with_ad, _ = model.forward(base, batch, ctx, adapter)
        without, _ = model.forward(base, batch, make_client_ctx(cfg, None), None)
        assert float(jnp.abs(with_ad - without).max()) > 1e-4

    def test_rank_padding_zero_rows_noop(self, key):
        """Mixed-rank banks pad A/B with zeros — padded rows are exact no-ops
        in the LoRA delta (DESIGN.md §5)."""
        x = jax.random.normal(key, (5, 16))
        A = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
        B = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
        A_pad = jnp.concatenate([A, jnp.zeros((16, 4))], axis=1)
        B_pad = jnp.concatenate([B, jnp.zeros((4, 8))], axis=0)
        np.testing.assert_allclose(x @ A @ B, x @ A_pad @ B_pad, rtol=1e-5)

    def test_bank_stacking(self, key):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4, targets=("q",))
        bank = ad_lib.init_client_bank(cfg, acfg, 3, key)
        leaves = jax.tree.leaves(bank)
        assert all(l.shape[0] == 3 for l in leaves)
        # clients differ (independent init)
        a = np.asarray(leaves[0])
        assert not np.allclose(a[0], a[1])


class TestRWKVAliases:
    def test_q_maps_to_r(self):
        cfg = tiny(RWKV)
        acfg = AdapterConfig(method="lora", rank=4, targets=("q", "v"))
        targets = dict(ad_lib.resolve_targets(cfg, acfg))
        assert "r" in targets and "v" in targets
        assert targets["r"] == (cfg.d_model, cfg.d_model)


class TestIA3:
    def test_identity_at_ones(self, key):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="ia3", targets=("k", "v", "down"))
        model = get_model(cfg)
        base = model.init_params(key)
        adapter = ad_lib.init_adapter(cfg, acfg, jax.random.PRNGKey(1))
        ctx = make_client_ctx(cfg, acfg)
        batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab)}
        with_ad, _ = model.forward(base, batch, ctx, adapter)
        without, _ = model.forward(base, batch, make_client_ctx(cfg, None), None)
        np.testing.assert_allclose(np.asarray(with_ad), np.asarray(without),
                                   rtol=1e-6)


class TestPrefix:
    def test_prefix_shapes_and_effect(self, key):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="prefix", n_prefix=4)
        adapter = ad_lib.init_adapter(cfg, acfg, key)
        pk = adapter["layers"]["prefix_k"]
        assert pk.shape == (cfg.n_layers, 4, cfg.n_kv_heads, cfg.hd)
        model = get_model(cfg)
        base = model.init_params(jax.random.PRNGKey(1))
        ctx = make_client_ctx(cfg, acfg)
        batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab)}
        with_ad, _ = model.forward(base, batch, ctx, adapter)
        without, _ = model.forward(base, batch, make_client_ctx(cfg, None), None)
        assert float(jnp.abs(with_ad - without).max()) > 1e-6
