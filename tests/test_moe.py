"""MoE dispatch: scatter path vs einsum oracle, capacity, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_lib
from repro.models.blocks import DEFAULT_LIN
from conftest import tiny
from repro.config import MOE


def _setup(key, capacity_factor=8.0):
    cfg = tiny(MOE)
    p = moe_lib.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model)) * 0.5
    return cfg, p, x


class TestDispatchEquivalence:
    def test_scatter_equals_einsum(self, key):
        cfg, p, x = _setup(key)
        # generous capacity so no tokens drop: the two dispatches must agree
        y_s, aux_s = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0, dispatch="scatter")
        y_e, aux_e = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0, dispatch="einsum")
        np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_e),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-5)

    def test_gradients_match(self, key):
        cfg, p, x = _setup(key)

        def loss(x, dispatch):
            y, aux = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0, dispatch=dispatch)
            return (y ** 2).mean() + 0.01 * aux

        gs = jax.grad(lambda x_: loss(x_, "scatter"))(x)
        ge = jax.grad(lambda x_: loss(x_, "einsum"))(x)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ge),
                                   rtol=1e-3, atol=1e-5)


class TestCapacity:
    def test_tight_capacity_drops_tokens(self, key):
        cfg, p, x = _setup(key)
        y_tight, _ = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=0.25)
        y_loose, _ = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0)
        # dropping changes some outputs but keeps everything finite
        assert np.isfinite(np.asarray(y_tight)).all()
        assert float(jnp.abs(y_tight - y_loose).max()) > 0.0

    def test_aux_loss_near_one_for_uniform(self, key):
        """Switch aux loss == E * sum(me*ce) ~= 1 when routing is balanced."""
        cfg, p, x = _setup(key)
        _, aux = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN, capacity_factor=8.0)
        assert 0.5 < float(aux) < 2.5


class TestSharedExpert:
    def test_shared_always_active(self, key):
        cfg, p, x = _setup(key)
        assert "shared" in p
        p_zero_routed = dict(p)
        p_zero_routed["experts"] = jax.tree.map(jnp.zeros_like, p["experts"])
        y, _ = moe_lib.moe_forward(p_zero_routed, cfg, x, DEFAULT_LIN,
                                   capacity_factor=8.0)
        assert float(jnp.abs(y).max()) > 1e-6, "shared expert path is dead"
