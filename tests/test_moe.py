"""MoE dispatch: scatter path vs einsum oracle, capacity, aux loss, and the
bank-vs-solo bitwise contract (vmap drift regression)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.models import get_model, moe as moe_lib
from repro.models.blocks import DEFAULT_LIN
from repro.optim import adamw_init
from conftest import tiny
from repro.config import MOE, AdapterConfig, TrainConfig


def _setup(key, capacity_factor=8.0):
    cfg = tiny(MOE)
    p = moe_lib.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model)) * 0.5
    return cfg, p, x


class TestDispatchEquivalence:
    def test_scatter_equals_einsum(self, key):
        cfg, p, x = _setup(key)
        # generous capacity so no tokens drop: the two dispatches must agree
        y_s, aux_s = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0, dispatch="scatter")
        y_e, aux_e = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0, dispatch="einsum")
        np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_e),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-5)

    def test_gradients_match(self, key):
        cfg, p, x = _setup(key)

        def loss(x, dispatch):
            y, aux = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0, dispatch=dispatch)
            return (y ** 2).mean() + 0.01 * aux

        gs = jax.grad(lambda x_: loss(x_, "scatter"))(x)
        ge = jax.grad(lambda x_: loss(x_, "einsum"))(x)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ge),
                                   rtol=1e-3, atol=1e-5)


class TestCapacity:
    def test_tight_capacity_drops_tokens(self, key):
        cfg, p, x = _setup(key)
        y_tight, _ = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=0.25)
        y_loose, _ = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN,
                                         capacity_factor=8.0)
        # dropping changes some outputs but keeps everything finite
        assert np.isfinite(np.asarray(y_tight)).all()
        assert float(jnp.abs(y_tight - y_loose).max()) > 0.0

    def test_aux_loss_near_one_for_uniform(self, key):
        """Switch aux loss == E * sum(me*ce) ~= 1 when routing is balanced."""
        cfg, p, x = _setup(key)
        _, aux = moe_lib.moe_forward(p, cfg, x, DEFAULT_LIN, capacity_factor=8.0)
        assert 0.5 < float(aux) < 2.5


class TestVmapBitwise:
    """Regression: MoE bank rows must match their solo run BITWISE (not
    rtol) — the ROADMAP "Bitwise vmap-vs-solo beyond dense" item.

    Pre-fix, the vmapped bank backward drifted 1-2 ulp from the solo
    program at some token counts (B=4,S=12 and B=1,S=24 reproduced it
    reliably): XLA fused the two cotangent paths meeting at the router
    probs differently between the batched and unbatched programs, and a
    vmap-of-1 (the R=1 row bucket) still traced the batched variant. The
    fix is two-sided — ``moe_forward`` runs its route->dispatch->combine
    body inside a closure-converted ``jax.checkpoint`` so the MoE backward
    is one self-contained recomputed subprogram, and
    ``make_compact_train_step`` runs a one-row bucket through the unbatched
    program the baseline runs."""

    # shapes that reproduced the pre-fix drift, plus a clean control
    SHAPES = [(4, 12), (1, 24)]

    def _compact_vs_baseline(self, method, targets, R, B, S, n_prefix=4):
        cfg = tiny(MOE)
        acfg = AdapterConfig(method=method, rank=4, alpha=8.0,
                             targets=targets, n_prefix=n_prefix)
        tcfg = TrainConfig(lr=1e-2, warmup_steps=1, total_steps=100,
                           max_grad_norm=1.0, remat=False, microbatch=0)
        base = get_model(cfg).init_params(jax.random.PRNGKey(0))
        compact = jax.jit(symbiosis.make_compact_train_step(
            cfg, acfg, microbatch=0, remat=False, memory_optimized=True))
        baseline = jax.jit(symbiosis.make_baseline_train_step(
            cfg, acfg, tcfg, memory_optimized=True))
        rng = np.random.default_rng(R * 100 + S)
        adapters = [ad_lib.init_adapter(cfg, acfg, jax.random.PRNGKey(10 + j))
                    for j in range(R)]
        bank = jax.tree.map(lambda *xs: jnp.stack(xs), *adapters)
        opt = jax.vmap(adamw_init)(bank)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (R, B, S)).astype(np.int32))}
        batch["labels"] = batch["tokens"]
        hyper = {"step": jnp.zeros((R,), jnp.int32),
                 "lr": jnp.full((R,), tcfg.lr, jnp.float32),
                 "warmup": jnp.full((R,), float(tcfg.warmup_steps), jnp.float32),
                 "total": jnp.full((R,), float(tcfg.total_steps), jnp.float32),
                 "wd": jnp.zeros((R,), jnp.float32),
                 "gnorm": jnp.full((R,), tcfg.max_grad_norm, jnp.float32)}
        new_bank, new_opt, _ = compact(
            base, bank, opt, batch, jnp.arange(R, dtype=jnp.int32),
            jnp.ones((R,), bool), hyper)
        for j in range(R):
            ref_a, ref_o, _ = baseline(base, adapters[j],
                                       adamw_init(adapters[j]),
                                       jax.tree.map(lambda x, j=j: x[j], batch), 0)
            got = jax.tree.map(lambda x, j=j: x[j], (new_bank, new_opt))
            for a, b in zip(jax.tree.leaves((ref_a, ref_o)),
                            jax.tree.leaves(got)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"MoE bank row {j} (R={R}, B={B}, S={S}, "
                            f"{method}) drifted from its solo run")

    def test_one_row_bucket_bitwise(self):
        """R=1 (the smallest engine bucket) at a shape that drifted pre-fix."""
        self._compact_vs_baseline("lora", ("q", "v"), R=1, B=4, S=12)

    def test_vmapped_bucket_bitwise(self):
        """A genuinely vmapped bucket at the same pre-fix-drifting shape."""
        self._compact_vs_baseline("lora", ("q", "v"), R=2, B=4, S=12)

    # the two distinct code paths are R=1 (unbatched) and R>1 (vmapped);
    # lora sweeps both pre-fix-drifting shapes, ia3/prefix one each
    SWEEP = ([("lora", ("q", "v"), R, shape)
              for R in (1, 2, 4) for shape in [(4, 12), (1, 24)]]
             + [(m, t, R, (4, 12))
                for m, t in [("ia3", ("k", "v", "down")),
                             ("prefix", ("q", "v"))]
                for R in (1, 4)])

    @pytest.mark.tier2
    @pytest.mark.parametrize("method,targets,R,shape", SWEEP)
    def test_row_bucket_sweep_bitwise(self, method, targets, R, shape):
        """Row-bucket x shape x method sweep of the bitwise contract."""
        B, S = shape
        self._compact_vs_baseline(method, targets, R=R, B=B, S=S)


class TestSharedExpert:
    def test_shared_always_active(self, key):
        cfg, p, x = _setup(key)
        assert "shared" in p
        p_zero_routed = dict(p)
        p_zero_routed["experts"] = jax.tree.map(jnp.zeros_like, p["experts"])
        y, _ = moe_lib.moe_forward(p_zero_routed, cfg, x, DEFAULT_LIN,
                                   capacity_factor=8.0)
        assert float(jnp.abs(y).max()) > 1e-6, "shared expert path is dead"
