"""System-level invariants of Symbiosis split execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AdapterConfig, TrainConfig, ServeConfig, DENSE
from repro.core import symbiosis
from conftest import tiny


def _batch(cfg, key, C, B=2, S=16):
    ks = jax.random.split(key, 2)
    return {"tokens": jax.random.randint(ks[0], (C, B, S), 0, cfg.vocab),
            "labels": jax.random.randint(ks[1], (C, B, S), 0, cfg.vocab)}


class TestMultiClientEquivalence:
    def test_shared_base_equals_isolated_jobs(self, key, lora_cfg):
        """The paper's exactness claim: outputs with Symbiosis are identical
        to the baseline — C clients sharing one base step exactly as C
        isolated fine-tuning jobs."""
        cfg = tiny(DENSE)
        tcfg = TrainConfig(n_clients=3, remat=False, lr=1e-2)
        base, bank, opt = symbiosis.init_system(cfg, lora_cfg, 3, key)
        batch = _batch(cfg, key, 3)
        shared_step = jax.jit(symbiosis.make_multi_client_train_step(cfg, lora_cfg, tcfg))
        bank_s, opt_s, m = shared_step(base, bank, opt, batch, 0)

        for c in range(3):
            one_bank = jax.tree.map(lambda x, c=c: x[c:c + 1], bank)
            one_opt = jax.tree.map(lambda x, c=c: x[c:c + 1], opt)
            one_batch = jax.tree.map(lambda x, c=c: x[c:c + 1], batch)
            b1, o1, m1 = shared_step(base, one_bank, one_opt, one_batch, 0)
            np.testing.assert_allclose(np.asarray(m1["loss"][0]),
                                       np.asarray(m["loss"][c]), rtol=1e-5)
            for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(bank_s)):
                np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[c]),
                                           rtol=1e-4, atol=1e-5)

    def test_microbatch_accumulation_matches_full(self, key, lora_cfg):
        cfg = tiny(DENSE)
        base, bank, opt = symbiosis.init_system(cfg, lora_cfg, 2, key)
        batch = _batch(cfg, key, 2, B=4)
        full = symbiosis.make_multi_client_train_step(
            cfg, lora_cfg, TrainConfig(n_clients=2, remat=False))
        micro = symbiosis.make_multi_client_train_step(
            cfg, lora_cfg, TrainConfig(n_clients=2, remat=False, microbatch=2))
        b_f, _, m_f = jax.jit(full)(base, bank, opt, batch, 0)
        b_m, _, m_m = jax.jit(micro)(base, bank, opt, batch, 0)
        np.testing.assert_allclose(np.asarray(m_f["loss"]), np.asarray(m_m["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(b_f), jax.tree.leaves(b_m)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_memory_optimized_backward_same_grads(self, key, lora_cfg):
        """§3.6 changes memory, not math: adapter updates identical."""
        cfg = tiny(DENSE)
        base, bank, opt = symbiosis.init_system(cfg, lora_cfg, 2, key)
        batch = _batch(cfg, key, 2)
        on = symbiosis.make_multi_client_train_step(
            cfg, lora_cfg, TrainConfig(n_clients=2, memory_optimized_backward=True))
        off = symbiosis.make_multi_client_train_step(
            cfg, lora_cfg, TrainConfig(n_clients=2, memory_optimized_backward=False))
        b_on, _, _ = jax.jit(on)(base, bank, opt, batch, 0)
        b_off, _, _ = jax.jit(off)(base, bank, opt, batch, 0)
        for a, b in zip(jax.tree.leaves(b_on), jax.tree.leaves(b_off)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)


class TestMultiPEFT:
    @pytest.mark.parametrize("method", ["lora", "ia3", "prefix"])
    def test_each_method_trains(self, key, method):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method=method, rank=4,
                             targets=("q", "v", "down") if method == "ia3"
                             else ("q", "v"))
        tcfg = TrainConfig(n_clients=2, lr=1e-2, remat=False)
        base, bank, opt = symbiosis.init_system(cfg, acfg, 2, key)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, acfg, tcfg))
        batch = _batch(cfg, key, 2)
        # step 1, not 0: warmup makes the step-0 learning rate exactly zero
        bank2, opt2, m = step(base, bank, opt, batch, 1)
        assert np.isfinite(np.asarray(m["loss"])).all()
        changed = any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(bank), jax.tree.leaves(bank2)))
        assert changed, f"{method} adapter did not update"

    def test_mixed_methods_share_base(self, key):
        """Two banks with different PEFT methods against ONE base tree
        (paper goal 6): no interference, both step."""
        cfg = tiny(DENSE)
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        a_lora = AdapterConfig(method="lora", rank=4, targets=("q", "v"))
        a_ia3 = AdapterConfig(method="ia3", targets=("k", "v", "down"))
        base, bank_l, opt_l = symbiosis.init_system(cfg, a_lora, 2, k1)
        from repro.core import adapters as ad_lib
        bank_i = ad_lib.init_client_bank(cfg, a_ia3, 2, k2)
        from repro.optim import adamw_init
        opt_i = jax.vmap(adamw_init)(bank_i)
        tcfg = TrainConfig(n_clients=2, remat=False)
        step_l = jax.jit(symbiosis.make_multi_client_train_step(cfg, a_lora, tcfg))
        step_i = jax.jit(symbiosis.make_multi_client_train_step(cfg, a_ia3, tcfg))
        batch = _batch(cfg, jax.random.PRNGKey(5), 2)
        _, _, ml = step_l(base, bank_l, opt_l, batch, 0)
        _, _, mi = step_i(base, bank_i, opt_i, batch, 0)
        assert np.isfinite(np.asarray(ml["loss"])).all()
        assert np.isfinite(np.asarray(mi["loss"])).all()


class TestMixedInferenceFinetune:
    def test_mixed_step(self, key, lora_cfg):
        """Paper §4.4: fine-tune and decode against the same resident base."""
        cfg = tiny(DENSE)
        tcfg = TrainConfig(n_clients=2, remat=False)
        scfg = ServeConfig(n_clients=2, max_seq=32)
        base, ft_bank, ft_opt = symbiosis.init_system(cfg, lora_cfg, 2, key)
        _, inf_bank, _ = symbiosis.init_system(cfg, lora_cfg, 2,
                                               jax.random.PRNGKey(11))
        caches = symbiosis.init_client_caches(cfg, 2, 2, 32)
        mixed = jax.jit(symbiosis.make_mixed_step(cfg, lora_cfg, tcfg, scfg))
        batch = _batch(cfg, key, 2)
        toks = jnp.zeros((2, 2), jnp.int32)
        ft_bank2, ft_opt2, caches2, logits, metrics = mixed(
            base, ft_bank, ft_opt, batch, inf_bank, caches, toks, 0)
        assert logits.shape == (2, 2, cfg.vocab)
        assert np.isfinite(np.asarray(metrics["loss"])).all()
        assert int(np.asarray(caches2["pos"]).max()) == 1


class TestConvergence:
    def test_losses_decrease_on_learnable_task(self, key):
        """Each client's loss drops on its own Markov task (real pipeline).
        Full-target rank-8 LoRA: attention-only adapters can't learn much on
        a random base, so target the MLP too."""
        from repro.data import make_client_batches
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=8, alpha=16.0,
                             targets=("q", "k", "v", "o", "gate", "up", "down"))
        tcfg = TrainConfig(n_clients=2, lr=1e-2, remat=False, total_steps=60,
                           warmup_steps=5)
        base, bank, opt = symbiosis.init_system(cfg, acfg, 2, key)
        step = jax.jit(symbiosis.make_multi_client_train_step(cfg, acfg, tcfg))
        stream = make_client_batches(cfg, 2, 4, 32)
        first = last = None
        for i in range(60):
            bank, opt, m = step(base, bank, opt, stream.batch(i), i)
            if i == 0:
                first = np.asarray(m["loss"])
            last = np.asarray(m["loss"])
        assert (last < first - 0.5).all(), f"{first} -> {last}"
