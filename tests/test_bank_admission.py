"""Dynamic serving-bank admission (ISSUE 6 satellite).

``admit_bank`` must grow a live engine without perturbing anyone: a client
admitted later generates byte-identically to the same client present from
construction, existing clients are untouched, a NEW AdapterConfig converts
a single-method engine into the mixed registry, the router is charged at
admission and released at retirement, and retired clients are refused.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, ServeConfig, DENSE
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.serving.engine import Request, ServingEngine
from conftest import tiny

LORA = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
IA3 = AdapterConfig(method="ia3", targets=("k", "v", "down"))


def _prompts(cfg, n, rng):
    return [rng.integers(1, cfg.vocab, (1, 5 + i)).astype(np.int32)
            for i in range(n)]


def _serve_all(eng, prompts, clients, max_new=4):
    reqs = [Request(client_id=c, prompt=p, max_new_tokens=max_new)
            for c, p in zip(clients, prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.generated for r in reqs]


def test_admitted_client_matches_static_engine():
    cfg = tiny(DENSE)
    base = None
    key = jax.random.PRNGKey(0)
    base, bank3, _ = symbiosis.init_system(cfg, LORA, 3, key)
    rng = np.random.default_rng(1)
    prompts = _prompts(cfg, 3, rng)

    # engine A: all three clients from construction
    eng_a = ServingEngine(cfg, LORA, ServeConfig(n_clients=3, max_seq=32,
                                                 page_block=8),
                          base, bank3, max_batch_per_client=1)
    gen_a = _serve_all(eng_a, prompts, [0, 1, 2])

    # engine B: two clients, then client 2's adapter admitted live
    bank2 = jax.tree.map(lambda x: x[:2], bank3)
    eng_b = ServingEngine(cfg, LORA, ServeConfig(n_clients=2, max_seq=32,
                                                 page_block=8),
                          base, bank2, max_batch_per_client=1)
    gen_b01 = _serve_all(eng_b, prompts[:2], [0, 1])
    adm = eng_b.admit_bank(LORA, jax.tree.map(lambda x: x[2:3], bank3))
    assert adm.client_ids == [2]
    assert eng_b.n_clients == 3
    (gen_b2,) = _serve_all(eng_b, prompts[2:], adm.client_ids)

    for a, b in zip(gen_a[:2], gen_b01):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(gen_a[2], gen_b2)


def test_admit_new_method_converts_to_mixed():
    cfg = tiny(DENSE)
    base, bank_l, _ = symbiosis.init_system(cfg, LORA, 2, jax.random.PRNGKey(0))
    bank_i = ad_lib.init_client_bank(cfg, IA3, 1, jax.random.PRNGKey(7))
    rng = np.random.default_rng(2)
    prompts = _prompts(cfg, 3, rng)

    # grown engine: lora-only, then an IA3 bank admitted live
    eng = ServingEngine(cfg, LORA, ServeConfig(n_clients=2, max_seq=32,
                                               page_block=8),
                        base, bank_l, max_batch_per_client=1)
    adm = eng.admit_bank(IA3, bank_i)
    assert adm.client_ids == [2]
    gen = _serve_all(eng, prompts, [0, 1, 2])

    # reference: the mixed registry from construction
    eng_m = ServingEngine(cfg, (LORA, IA3),
                          ServeConfig(n_clients=3, max_seq=32, page_block=8),
                          base, (bank_l, bank_i), max_batch_per_client=1)
    gen_m = _serve_all(eng_m, prompts, [0, 1, 2])
    for a, b in zip(gen, gen_m):
        np.testing.assert_array_equal(a, b)


def test_grow_existing_bank_existing_clients_untouched():
    cfg = tiny(DENSE)
    base, bank3, _ = symbiosis.init_system(cfg, LORA, 3, jax.random.PRNGKey(3))
    bank2 = jax.tree.map(lambda x: x[:2], bank3)
    eng = ServingEngine(cfg, LORA, ServeConfig(n_clients=2, max_seq=32,
                                               page_block=8),
                        base, bank2, max_batch_per_client=1)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), eng.caches)
    eng.admit_bank(LORA, jax.tree.map(lambda x: x[2:3], bank3))
    page_axes = symbiosis.cache_page_axes(
        cfg, 32, **symbiosis.serve_cache_kwargs(
            cfg, ServeConfig(n_clients=2, max_seq=32, page_block=8)))

    def _old_region(new, old, pax):
        ax = 0 if pax is None else pax
        return np.take(np.asarray(new), np.arange(old.shape[ax]), axis=ax)

    # existing clients' cache state (per-slot leaves AND their page ranges)
    # is byte-identical after growth
    jax.tree.map(
        lambda old, new, pax: np.testing.assert_array_equal(
            _old_region(new, old, pax), old),
        before, eng.caches, page_axes)


def test_retired_clients_are_refused():
    cfg = tiny(DENSE)
    base, bank, _ = symbiosis.init_system(cfg, LORA, 1, jax.random.PRNGKey(4))
    eng = ServingEngine(cfg, LORA, ServeConfig(n_clients=1, max_seq=32,
                                               page_block=8),
                        base, bank, max_batch_per_client=1)
    extra = ad_lib.init_client_bank(cfg, LORA, 1, jax.random.PRNGKey(5))
    adm = eng.admit_bank(LORA, extra)
    prompt = np.ones((1, 5), np.int32)
    _serve_all(eng, [prompt], adm.client_ids)
    eng.retire_bank(adm)
    with pytest.raises(ValueError, match="retired"):
        eng.submit(Request(client_id=adm.client_ids[0], prompt=prompt))


def test_retire_refuses_busy_clients_and_router_roundtrip():
    from repro.serving.router import PlacementRouter, Slot

    cfg = tiny(DENSE)
    base, bank, _ = symbiosis.init_system(cfg, LORA, 1, jax.random.PRNGKey(6))
    router = PlacementRouter(cfg, [Slot(0, free_hbm=1e9)], host_free_bytes=0)
    eng = ServingEngine(cfg, LORA, ServeConfig(n_clients=1, max_seq=32,
                                               page_block=8),
                        base, bank, max_batch_per_client=1, router=router)
    free0 = router.slots[0].free_hbm
    extra = ad_lib.init_client_bank(cfg, LORA, 1, jax.random.PRNGKey(8))
    adm = eng.admit_bank(LORA, extra)
    assert router.slots[0].free_hbm < free0      # charged at admission
    eng.submit(Request(client_id=adm.client_ids[0],
                       prompt=np.ones((1, 5), np.int32), max_new_tokens=8))
    eng.service_tick()                           # request now in flight
    with pytest.raises(RuntimeError, match="in flight"):
        eng.retire_bank(adm)
    eng.run()
    eng.retire_bank(adm)
    assert router.slots[0].free_hbm == free0     # released at retirement


def test_admission_requires_paged_compact():
    cfg = tiny(DENSE)
    base, bank, _ = symbiosis.init_system(cfg, LORA, 1, jax.random.PRNGKey(9))
    eng = ServingEngine(cfg, LORA, ServeConfig(n_clients=1, max_seq=32),
                        base, bank, max_batch_per_client=1)
    extra = ad_lib.init_client_bank(cfg, LORA, 1, jax.random.PRNGKey(10))
    with pytest.raises(ValueError, match="paged"):
        eng.admit_bank(LORA, extra)
