"""Compute-proportional (compacted) decode — ISSUE 3 tentpole.

Byte-identity bars:

* core level: ``make_compact_decode_step`` over any active subset ==
  ``make_masked_decode_step`` over the whole bank, logits AND every cache
  leaf, for every attention family × adapter method (incl. int8 pools);
* engine level: a compacted engine's outputs == the masked engine's, across
  occupancies (single slot / exactly a jit bucket / full bank) and tick
  policies.

Compaction is paged-only (the page pools are what let the client axis fold
into extra pages); the dense layout keeps the masked step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (AdapterConfig, ServeConfig, DENSE, MOE, VLM, HYBRID,
                          ENCDEC)
from repro.core import symbiosis
from repro.models import get_model
from repro.core.virtlayer import make_client_ctx
from repro.serving.engine import ServingEngine, Request
from conftest import tiny

ATTN_FAMS = [DENSE, MOE, VLM, HYBRID, ENCDEC]


def _bank_caches_after_prefill(cfg, acfg, scfg, C, B, S, seed=0):
    """Per-client prefill on identity block tables, stacked into bank caches
    (bypasses the engine so enc-dec frames can be threaded)."""
    model = get_model(cfg)
    base, bank, _ = symbiosis.init_system(cfg, acfg, C, jax.random.PRNGKey(seed))
    ctx = make_client_ctx(cfg, acfg)
    rng = np.random.default_rng(seed)
    cache_kw = symbiosis.serve_cache_kwargs(cfg, scfg)
    per = []
    for c in range(C):
        cache = model.init_cache(B, scfg.max_seq, **cache_kw)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))}
        if cfg.arch == VLM:
            batch["img_embed"] = jnp.asarray(rng.normal(
                size=(B, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)) * 0.02
        if cfg.arch == ENCDEC:
            batch["frames"] = jnp.asarray(rng.normal(
                size=(B, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)) * 0.1
        adapter = jax.tree.map(lambda x, c=c: x[c], bank)
        _, cache = model.prefill(base, batch, cache, ctx, adapter)
        per.append(cache)
    caches = symbiosis.stack_client_caches(cfg, scfg.max_seq, per, **cache_kw)
    return base, bank, caches, rng


class TestCompactStepCore:
    @pytest.mark.parametrize("arch", [DENSE, HYBRID])
    def test_matches_masked_step(self, arch):
        self._case(arch, "lora")

    @pytest.mark.tier2
    @pytest.mark.parametrize("arch", ATTN_FAMS)
    @pytest.mark.parametrize("method", ["lora", "ia3", "prefix"])
    def test_matches_masked_step_all(self, arch, method):
        self._case(arch, method)

    @pytest.mark.tier2
    def test_matches_masked_step_quant(self):
        self._case(DENSE, "lora", kv_quant=True)

    def _case(self, arch, method, **scfg_kw):
        cfg = tiny(arch)
        acfg = AdapterConfig(method=method, rank=4, alpha=8.0,
                             targets=("q", "v"), n_prefix=4)
        C, B, S = 3, 2, 6
        scfg = ServeConfig(n_clients=C, max_seq=32, page_block=8, **scfg_kw)
        base, bank, caches, rng = _bank_caches_after_prefill(cfg, acfg, scfg,
                                                            C, B, S)
        masked = jax.jit(symbiosis.make_masked_decode_step(cfg, acfg, scfg))
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (C, B)).astype(np.int32))
        active = np.zeros((C, B), bool)
        active[0, 1] = active[2, 0] = True
        lg_m, new_m = masked(base, bank, caches, tokens, jnp.asarray(active))

        # 2 live + 2 padding rows (row count is a call-site shape)
        compact = jax.jit(symbiosis.make_compact_decode_step(cfg, acfg, scfg))
        clients = jnp.asarray(np.array([0, 2, 0, 0], np.int32))
        slots = jnp.asarray(np.array([1, 0, 0, 0], np.int32))
        row_mask = jnp.asarray(np.array([True, True, False, False]))
        lg_c, new_c = compact(base, bank, caches, tokens[clients, slots],
                              clients, slots, row_mask)

        np.testing.assert_array_equal(np.asarray(lg_m)[0, 1], np.asarray(lg_c)[0])
        np.testing.assert_array_equal(np.asarray(lg_m)[2, 0], np.asarray(lg_c)[1])
        for a, b in zip(jax.tree.leaves(new_m), jax.tree.leaves(new_c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_requires_paged_layout(self):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4)
        with pytest.raises(ValueError, match="paged"):
            symbiosis.make_compact_decode_step(cfg, acfg,
                                               ServeConfig(max_seq=32))


class TestCompactEngine:
    """Engine-level: compacted vs masked serving, byte-identical outputs."""

    def _serve(self, cfg, acfg, scfg, base, bank, reqs, *, compact, policy,
               max_b=2):
        eng = ServingEngine(cfg, acfg, scfg, base, bank,
                            max_batch_per_client=max_b, policy=policy,
                            compact_decode=compact)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        return eng, sorted((r.client_id, r.prompt.tobytes(),
                            r.generated.tobytes()) for r in done)

    def _reqs(self, cfg, rng, spec):
        """spec: list of (client, rows, prompt_len, max_new, arrive)."""
        return [Request(client_id=c,
                        prompt=rng.integers(0, cfg.vocab, (rows, S)).astype(np.int32),
                        max_new_tokens=new, arrive_tick=at)
                for (c, rows, S, new, at) in spec]

    # occupancy shapes over a 3-client x 2-slot bank (buckets: 4, 6):
    OCCUPANCIES = {
        "one_slot": [(0, 1, 5, 6, 0)],
        "bucket_boundary": [(0, 2, 5, 6, 0), (1, 2, 6, 6, 0)],   # 4 rows
        "bucket_padded": [(0, 2, 5, 6, 0), (1, 2, 6, 6, 0),
                          (2, 1, 4, 6, 0)],                      # 5 rows -> 6
        "full_bank": [(c, 2, 4 + c, 6, 0) for c in range(3)],    # 6 rows
        "staggered_turnover": [(0, 1, 4, 3, 0), (1, 2, 5, 8, 1),
                               (0, 1, 5, 4, 2), (2, 2, 6, 2, 3),
                               (0, 2, 4, 5, 6)],
    }

    @pytest.mark.parametrize("occupancy", list(OCCUPANCIES))
    def test_compact_matches_masked(self, key, occupancy):
        self._case(key, occupancy, "opportunistic")

    @pytest.mark.tier2
    @pytest.mark.parametrize("policy", ["lockstep", "nolockstep"])
    @pytest.mark.parametrize("occupancy", list(OCCUPANCIES))
    def test_compact_matches_masked_policies(self, key, occupancy, policy):
        self._case(key, occupancy, policy)

    def _case(self, key, occupancy, policy, page_block=8, arch=DENSE):
        cfg = tiny(arch)
        acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
        scfg = ServeConfig(n_clients=3, max_seq=32, page_block=page_block)
        base, bank, _ = symbiosis.init_system(cfg, acfg, 3, key)
        outs = {}
        for compact in (False, True):
            rng = np.random.default_rng(11)
            eng, outs[compact] = self._serve(
                cfg, acfg, scfg, base, bank,
                self._reqs(cfg, rng, self.OCCUPANCIES[occupancy]),
                compact=compact, policy=policy)
        assert outs[True] == outs[False], (
            f"compacted decode diverged from masked ({occupancy}, {policy})")
        # allocator drained + activity state empty (incremental bookkeeping)
        assert not any(eng._active_slots)
        assert not eng._active_mask.any()

    def test_hybrid_engine_compact(self, key):
        """Recurrent family: per-slot Mamba state gathers/scatters through
        the compacted step; slot turnover stays exact."""
        cfg = tiny(HYBRID)
        acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
        scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8)
        base, bank, _ = symbiosis.init_system(cfg, acfg, 2, key)
        spec = [(0, 1, 5, 4, 0), (1, 1, 6, 8, 1), (0, 1, 5, 3, 2)]
        outs = {}
        for compact in (False, True):
            rng = np.random.default_rng(3)
            _, outs[compact] = self._serve(cfg, acfg, scfg, base, bank,
                                           self._reqs(cfg, rng, spec),
                                           compact=compact,
                                           policy="opportunistic", max_b=1)
        assert outs[True] == outs[False]

    def test_compact_stats_track_active_rows(self, key):
        """The compacted step's row count scales with ACTIVE slots, not the
        bank: a single 1-row request over a 3x2 bank decodes 1 row/tick
        (padded to the smallest jit bucket)."""
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4)
        scfg = ServeConfig(n_clients=3, max_seq=32, page_block=8)
        base, bank, _ = symbiosis.init_system(cfg, acfg, 3, key)
        eng, _ = self._serve(cfg, acfg, scfg, base, bank,
                             self._reqs(cfg, np.random.default_rng(0),
                                        [(0, 1, 5, 6, 0)]),
                             compact=True, policy="opportunistic")
        assert eng.stats["compact_rows"] == 5          # 5 decode ticks x 1 row
        assert eng.stats["compact_rows"] + eng.stats["compact_padded"] \
            == 5 * eng._buckets[0]                     # bucketed to 4

    @pytest.mark.parametrize("compact", [False, True])
    def test_single_token_request_never_joins_a_tick(self, key, compact):
        """Regression (found in PR-3 review): a request admitted with
        max_new_tokens=1 is already complete (its token came from prefill).
        Its slot must never join a decode tick — the slot's next block-table
        entry is unassigned, and under the global pool a stray decode write
        through it would land in ANOTHER client's page. Setup: client 0's
        pool fully allocated, client 1 has an in-flight request (so client 1
        is in the serving set) plus the single-token admit with a
        page-aligned prompt; client 0's stream must match solo serving."""
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
        scfg = ServeConfig(n_clients=2, max_seq=64, page_block=8)
        base, bank, _ = symbiosis.init_system(cfg, acfg, 2, key)
        rng = np.random.default_rng(5)
        # victim prompts exhaust client 0's whole pool (2 rows x 8 pages),
        # so global page 0 holds LIVE prompt K/V read on every tick — where
        # a stray write through a zero/unassigned table entry would land
        victim = Request(client_id=0,
                         prompt=rng.integers(0, cfg.vocab, (2, 58)).astype(np.int32),
                         max_new_tokens=6)
        filler = Request(client_id=1,
                         prompt=rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                         max_new_tokens=12)
        one_tok = Request(client_id=1,                      # S % page_block == 0
                          prompt=rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32),
                          max_new_tokens=1, arrive_tick=2)
        eng = ServingEngine(cfg, acfg, scfg, base, bank,
                            max_batch_per_client=2, compact_decode=compact)
        for r in (victim, filler, one_tok):
            eng.submit(r)
        done = {id(r): r for r in eng.run()}
        solo = ServingEngine(cfg, acfg, scfg, base, bank,
                             max_batch_per_client=2, compact_decode=compact)
        solo.submit(Request(client_id=0, prompt=victim.prompt.copy(),
                            max_new_tokens=6))
        (ref,) = solo.run()
        np.testing.assert_array_equal(
            done[id(victim)].generated, ref.generated,
            err_msg="single-token admit corrupted another client's stream")

    def test_compact_requires_paged_engine(self, key):
        cfg = tiny(DENSE)
        acfg = AdapterConfig(method="lora", rank=4)
        base, bank, _ = symbiosis.init_system(cfg, acfg, 2, key)
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(cfg, acfg, ServeConfig(n_clients=2, max_seq=32),
                          base, bank, compact_decode=True)


@pytest.mark.tier2
@pytest.mark.parametrize("page_block", [8, 16])
@pytest.mark.parametrize("max_b", [1, 2])           # bucket structures differ
@pytest.mark.parametrize("occupancy", ["one_slot", "full_bank"])
def test_compact_sweep(key, page_block, max_b, occupancy):
    """CI tier-2 sweep: page size x jit-bucket structure x occupancy for the
    compacted paged path (ISSUE 3 satellite)."""
    cfg = tiny(DENSE)
    acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
    scfg = ServeConfig(n_clients=3, max_seq=32, page_block=page_block)
    base, bank, _ = symbiosis.init_system(cfg, acfg, 3, key)
    spec = ([(0, 1, 5, 6, 0)] if occupancy == "one_slot"
            else [(c, max_b, 4 + c, 6, 0) for c in range(3)])
    outs = {}
    for compact in (False, True):
        rng = np.random.default_rng(7)
        eng = ServingEngine(cfg, acfg, scfg, base, bank,
                            max_batch_per_client=max_b,
                            compact_decode=compact)
        for r in [Request(client_id=c,
                          prompt=rng.integers(0, cfg.vocab, (rows, S)).astype(np.int32),
                          max_new_tokens=new, arrive_tick=at)
                  for (c, rows, S, new, at) in spec]:
            eng.submit(r)
        outs[compact] = sorted((r.client_id, r.generated.tobytes())
                               for r in eng.run())
    assert outs[True] == outs[False]
