"""The paper's own Table 3 eval models (not in the assigned pool) run the
same multi-client pipeline — generality, as the paper demonstrates with
5 architectures."""
import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, TrainConfig
from repro.configs import get_config, ASSIGNED
from repro.core import symbiosis

PAPER_MODELS = ["symbiosis-llama2-13b", "gemma2-27b", "starcoder2-15b"]


def test_assigned_pool_unchanged():
    assert len(ASSIGNED) == 10
    assert not set(PAPER_MODELS) & set(ASSIGNED)


@pytest.mark.parametrize("arch_id", PAPER_MODELS)
def test_paper_model_trains(arch_id):
    cfg = get_config(arch_id).reduced(n_layers=2, d_model=256)
    acfg = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))
    base, bank, opt = symbiosis.init_system(cfg, acfg, 2, jax.random.PRNGKey(0))
    step = jax.jit(symbiosis.make_multi_client_train_step(
        cfg, acfg, TrainConfig(n_clients=2, remat=True)))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 2, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 2, 32), 0, cfg.vocab)}
    _, _, m = step(base, bank, opt, batch, 1)
    assert np.isfinite(np.asarray(m["loss"])).all()


@pytest.mark.parametrize("arch_id", PAPER_MODELS)
def test_paper_model_dry_specs_build(arch_id):
    """Full-size configs lower-ready on the host mesh (no allocation)."""
    from repro.launch import specs
    from repro.launch.mesh import make_host_mesh
    b = specs.input_specs(arch_id, "decode_32k", make_host_mesh())
    assert b.n_clients * b.batch_per_client == 128
