"""Tick-level telemetry (docs/observability.md).

The contracts under test, in order of importance:

1. **Additive**: running an engine with ``obs=Obs()`` changes NOTHING about
   its outputs — serving token streams and fine-tuning trajectories are
   bitwise identical obs-on vs obs-off, and the autouse trace guard
   (conftest) proves telemetry introduces no new jit compiles.
2. **Free when off**: ``obs=None`` must not import ``repro.obs`` at all,
   and the null span is one shared context manager (no per-phase
   allocation, bounded wall-time overhead).
3. The metric/event/export primitives themselves: log-2 histogram bucket
   math and percentiles, filtered destructive event drains, JSONL and
   Prometheus exports accepted by the ``--check`` validator (and rejected
   once truncated).
"""
import json
import subprocess
import sys
import time
import warnings

import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, FinetuneConfig, ServeConfig
from repro.core import symbiosis
from repro.faults.plan import FaultyRequestStream
from repro.obs import Obs
from repro.obs.events import EventLog
from repro.obs.metrics import Histogram, Metrics
from repro.obs import export
from repro.serving.engine import Request, ServingEngine
from repro.training import FinetuneEngine, FinetuneJob, make_job_stream
from conftest import tiny

LORA = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))


def _serving(cfg, base, bank, **kw):
    scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8, pool_pages=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return ServingEngine(cfg, LORA, scfg, base, bank,
                             max_batch_per_client=2, debug=True, **kw)


def _prompts(cfg, per_client=2, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)
             for _ in range(per_client)] for _ in range(2)]


def _submit_all(eng, prompts, max_new=3):
    for c, ps in enumerate(prompts):
        for p in ps:
            eng.submit(Request(client_id=c, prompt=p.copy(),
                               max_new_tokens=max_new, arrive_tick=0))


def _job(cfg, i, steps=3):
    return FinetuneJob(acfg=LORA, data=make_job_stream(cfg, 2, 8, seed=i),
                       batch_size=2, seq_len=8, steps=steps, seed=i,
                       name=f"j{i}")


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

def test_histogram_bucket_math_and_percentiles():
    h = Histogram()
    for _ in range(99):
        h.observe(1e-3)
    h.observe(0.1)
    # 1e-3 lands in bucket ceil(log2(1e-3/1e-6)) = 10, upper edge 1.024e-3
    assert h.counts[10] == 99
    assert h.percentile(50) == pytest.approx(1.024e-3)
    assert h.percentile(99) == pytest.approx(1.024e-3)
    # p100's bucket edge (0.131...) is clamped to the exact observed max
    assert h.percentile(100) == pytest.approx(0.1)
    assert h.n == 100 and h.vmin == 1e-3 and h.vmax == 0.1
    assert h.mean == pytest.approx((99 * 1e-3 + 0.1) / 100)
    # bucket 0 catches sub-resolution values
    h2 = Histogram()
    h2.observe(0.0)
    h2.observe(1e-7)
    assert h2.counts[0] == 2
    # merge is additive
    h.merge(h2)
    assert h.n == 102 and h.counts[0] == 2


def test_metrics_registry_labels_and_samples():
    m = Metrics()
    m.counter("tok", client=0).inc(5)
    m.counter("tok", client=1).inc(7)
    assert m.counter("tok", client=0).value == 5          # get-or-create
    m.gauge("free").set(3)
    m.histogram("lat", phase="a").observe(2e-3)
    merged = m.merged_histogram("lat")
    assert merged.n == 1
    rows = m.samples()
    names = [(r["metric"], r["type"]) for r in rows]
    assert names == sorted(names)                         # deterministic
    hist_row = next(r for r in rows if r["type"] == "histogram")
    assert hist_row["count"] == 1 and "p99" in hist_row


def test_event_log_filtered_drain_and_cap():
    log = EventLog(maxlen=4)
    for i in range(3):
        log.emit("admit", engine="serving", tick=i, tenant=i % 2)
    log.emit("retire", engine="serving", tick=9, tenant=0)
    seqs = [e.seq for e in log.peek()]
    assert len(set(seqs)) == 4 and seqs == sorted(seqs)
    mine = log.drain(tenant=0)
    assert {e.kind for e in mine} == {"admit", "retire"}
    assert all(e.tenant == 0 for e in mine)
    left = log.peek()                                      # others untouched
    assert all(e.tenant == 1 for e in left) and len(left) == 1
    # cap: overflow bumps the dropped counter instead of growing
    for i in range(10):
        log.emit("admit", engine="serving", tick=i)
    assert len(log.peek()) == 4 and log.dropped > 0


# ---------------------------------------------------------------------------
# contract 1: telemetry is bitwise-invisible (trace guard via conftest)
# ---------------------------------------------------------------------------

def test_obs_on_off_bitwise_serving(key):
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    prompts = _prompts(cfg)
    off = _serving(cfg, base, bank)
    on = _serving(cfg, base, bank, obs=Obs())
    _submit_all(off, prompts)
    _submit_all(on, prompts)
    ref = {r.prompt.tobytes(): r.generated for r in off.run()}
    done = on.run()
    assert len(done) == len(ref)
    for r in done:
        assert r.status == "ok"
        np.testing.assert_array_equal(r.generated, ref[r.prompt.tobytes()])
    # the compatibility view is untouched by the mirror
    assert on.stats["ticks"] == off.stats["ticks"]


def test_obs_on_off_bitwise_finetune(key):
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 2, key)
    results = {}
    for tag, obs in (("off", None), ("on", Obs())):
        eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                             debug=True, obs=obs)
        jobs = [_job(cfg, 0), _job(cfg, 1)]
        for j in jobs:
            eng.submit(j)
        eng.run()
        results[tag] = jobs
    for a, b in zip(results["off"], results["on"]):
        np.testing.assert_array_equal(a.losses, b.losses)
        for x, y in zip(jax.tree.leaves((a.result.adapter, a.result.opt)),
                        jax.tree.leaves((b.result.adapter, b.result.opt))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_serving_metrics_and_latency_fields(key):
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    obs = Obs()
    eng = _serving(cfg, base, bank, obs=obs)
    _submit_all(eng, _prompts(cfg))
    done = eng.run()
    # satellite: submit_t/finish_t are now surfaced as per-request latency
    for r in done:
        assert r.queue_wait is not None and r.queue_wait >= 0
        assert r.ttft is not None and r.ttft >= r.queue_wait
        assert r.e2e_latency is not None and r.e2e_latency >= r.ttft
    m = obs.metrics
    assert m.merged_histogram("serve_queue_wait_seconds").n == len(done)
    assert m.merged_histogram("serve_ttft_seconds").n == len(done)
    assert m.merged_histogram("serve_e2e_seconds").n == len(done)
    toks = sum(r.generated.size for r in done)
    decode = sum(m.counter("serve_decode_tokens_total", client=c).value
                 for c in (0, 1))
    prefill = sum(m.counter("serve_prefill_tokens_total", client=c).value
                  for c in (0, 1))
    assert decode + 0 == sum(max(r.generated.size - 1, 0) for r in done)
    assert prefill == sum(r.prompt.size for r in done)
    assert toks > 0
    # per-phase spans observed real time
    spans = m.merged_histogram("span_seconds")
    assert spans.n > 0
    assert m.merged_histogram("tick_seconds").n == eng.stats["ticks"]
    # the stats dict is mirrored as gauges at snapshot time
    snap = obs.snapshot()
    stat_rows = [r for r in snap["metrics"] if r["metric"] == "engine_stat"]
    assert {r["labels"]["key"] for r in stat_rows} >= set(eng.stats)


def test_latency_fields_without_obs(key):
    """The Request latency timeline works with telemetry detached — the
    timestamps are engine bookkeeping, not an obs feature."""
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    eng = _serving(cfg, base, bank)
    _submit_all(eng, _prompts(cfg, per_client=1))
    done = eng.run()
    assert all(r.e2e_latency is not None for r in done)
    assert eng.drain_events() == []


def test_finetune_metrics_and_events(key):
    cfg = tiny()
    base, _, _ = symbiosis.init_system(cfg, LORA, 2, key)
    obs = Obs()
    eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2),
                         debug=True, obs=obs)
    jobs = [_job(cfg, 0), _job(cfg, 1)]
    for j in jobs:
        eng.submit(j)
    eng.run()
    for j in jobs:
        assert obs.metrics.counter(
            "train_steps_total", job=j.name).value == j.steps
        assert j.fault_history == []
    ev = eng.drain_events()
    kinds = [e.kind for e in ev]
    assert kinds.count("admit") == 2 and kinds.count("retire") == 2
    admits = [e for e in ev if e.kind == "admit"]
    assert {e.tenant for e in admits} == {"j0", "j1"}
    # drained means drained
    assert eng.drain_events() == []


# ---------------------------------------------------------------------------
# contract 2: disabled mode is free
# ---------------------------------------------------------------------------

def test_engines_do_not_import_obs_when_disabled():
    """The hard constraint from docs/observability.md: with obs=None no
    timing machinery is even imported — the engines must be importable and
    runnable without repro.obs ever entering sys.modules."""
    code = (
        "import sys\n"
        "import repro.serving.engine, repro.training.engine\n"
        "import repro.training.service\n"
        "assert not any(m.startswith('repro.obs') for m in sys.modules), "
        "sorted(m for m in sys.modules if m.startswith('repro.obs'))\n"
        "print('clean')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src",
                                         "JAX_PLATFORMS": "cpu",
                                         "PATH": "/usr/bin:/bin"},
                         cwd=".")
    assert out.returncode == 0, out.stderr
    assert "clean" in out.stdout


def test_disabled_span_is_shared_and_cheap(key):
    from repro.serving.engine import _NULL_CTX, _null_span
    # one shared nullcontext: no allocation per phase per tick
    assert _null_span("admit") is _NULL_CTX
    assert _null_span("jit_dispatch") is _NULL_CTX
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    eng = _serving(cfg, base, bank)
    assert eng._span is _null_span and eng._obs is None
    # bounded wall-time: 100k disabled span cycles must be cheap relative
    # to a bare loop (generous 50x/0.5s bound — this is pure-python ctx
    # entry, far below one engine tick)
    N = 100_000
    t0 = time.perf_counter()
    for _ in range(N):
        pass
    bare = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(N):
        with _null_span("x"):
            pass
    spans = time.perf_counter() - t0
    assert spans < max(50 * bare, 0.5), (spans, bare)


# ---------------------------------------------------------------------------
# events under churn + stream faults through the client-visible feed
# ---------------------------------------------------------------------------

def test_drain_events_under_churn(key):
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    obs = Obs()
    eng = _serving(cfg, base, bank, obs=obs)
    _submit_all(eng, _prompts(cfg, per_client=3))
    eng.run()
    c0 = eng.drain_events(client=0)
    c1 = eng.drain_events(client=1)
    assert c0 and c1
    assert all(e.tenant == 0 for e in c0)
    assert all(e.tenant == 1 for e in c1)
    seqs = [e.seq for e in c0 + c1]
    assert len(seqs) == len(set(seqs))
    assert {e.kind for e in c0} >= {"admit", "retire"}
    # kind-filtered drain of what's left (tenant-less events like compile)
    rest = eng.drain_events()
    assert all(e.tenant is None for e in rest)
    assert eng.drain_events() == []


def test_serving_stream_fault_retry_bitwise_and_events(key):
    """A transient request-stream error backs the client off; the retried
    fetch draws the SAME prompt so the stream is bitwise identical — and
    the whole episode is visible as backoff/retry events plus the
    request's fault_history."""
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    prompts = _prompts(cfg, per_client=1)
    clean = _serving(cfg, base, bank)
    _submit_all(clean, prompts)
    ref = {r.prompt.tobytes(): r.generated for r in clean.run()}

    obs = Obs()
    eng = _serving(cfg, base, bank, obs=obs)
    stream = FaultyRequestStream(prompts[0][0], {0: "stream_error"})
    eng.submit(Request(client_id=0, prompt=None, prompt_stream=stream,
                       max_new_tokens=3, arrive_tick=0))
    eng.submit(Request(client_id=1, prompt=prompts[1][0].copy(),
                       max_new_tokens=3, arrive_tick=0))
    done = eng.run()
    assert stream.calls == 2                    # faulted + successful retry
    assert all(r.status == "ok" for r in done)
    for r in done:
        np.testing.assert_array_equal(r.generated, ref[r.prompt.tobytes()])
    victim = next(r for r in done if r.client_id == 0)
    assert [k for _, k, _ in victim.fault_history] == ["backoff"]
    ev = eng.drain_events(client=0)
    kinds = [e.kind for e in ev]
    assert "backoff" in kinds and "retry" in kinds and "admit" in kinds


def test_serving_stream_end_rejects_with_event(key):
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    prompts = _prompts(cfg, per_client=1)
    obs = Obs()
    eng = _serving(cfg, base, bank, obs=obs)
    stream = FaultyRequestStream(prompts[0][0], {0: "stream_end"})
    eng.submit(Request(client_id=0, prompt=None, prompt_stream=stream,
                       max_new_tokens=3, arrive_tick=0))
    eng.submit(Request(client_id=1, prompt=prompts[1][0].copy(),
                       max_new_tokens=3, arrive_tick=0))
    done = eng.run()
    by_client = {r.client_id: r for r in done}
    assert by_client[0].status == "rejected"
    assert by_client[0].generated is None or by_client[0].generated.size == 0
    assert [k for _, k, _ in by_client[0].fault_history] == ["rejected"]
    assert by_client[1].status == "ok"
    kinds = {e.kind for e in eng.drain_events(client=0)}
    assert "reject" in kinds and "admit" not in kinds


def test_symbiosis_shared_obs_merged_feed(key):
    from repro.core.engine_spec import BankSpec, EngineSpec
    from repro.training.service import SymbiosisEngine
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    scfg = ServeConfig(n_clients=2, max_seq=32)
    spec = EngineSpec(cfg=cfg, banks=(BankSpec("b", LORA, capacity=2),),
                      serve=scfg, finetune=FinetuneConfig(max_jobs=1),
                      max_batch_per_client=2)
    obs = Obs()
    sym = SymbiosisEngine.from_spec(spec, base, serving_banks=[bank],
                                    obs=obs)
    prompts = _prompts(cfg, per_client=1)
    sym.submit(Request(client_id=0, prompt=prompts[0][0].copy(),
                       max_new_tokens=3, arrive_tick=0))
    sym.submit(_job(cfg, 0, steps=2))
    sym.run()
    ev = sym.drain_events()
    engines = {e.engine for e in ev}
    assert "serving" in engines and "finetune" in engines
    seqs = [e.seq for e in ev]
    assert seqs == sorted(seqs)
    assert sym.drain_events() == []


# ---------------------------------------------------------------------------
# exports + validator
# ---------------------------------------------------------------------------

def _small_obs():
    obs = Obs()
    obs.metrics.counter("serve_decode_tokens_total", client=0).inc(12)
    obs.metrics.gauge("serve_pages_free", client=0).set(5)
    h = obs.metrics.histogram("serve_ttft_seconds", client=0)
    h.observe(1e-3)
    h.observe(2e-3)
    obs.event("admit", engine="serving", tick=0, tenant=0, rows=1)
    obs.event("retire", engine="serving", tick=3, tenant=0, status="ok")
    return obs


def test_jsonl_export_golden_and_check(tmp_path):
    obs = _small_obs()
    path = str(tmp_path / "t.jsonl")
    export.write_jsonl(path, obs)
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["record"] == "header" and lines[0]["schema"] == 1
    assert lines[-1]["record"] == "footer"
    assert lines[-1]["n"] == len(lines) - 2
    kinds = {l.get("record") for l in lines[1:-1]}
    assert kinds == {"metric", "event"}
    hist = next(l for l in lines if l.get("type") == "histogram")
    assert hist["count"] == 2 and hist["buckets"]
    assert export.check_file(path) == []
    # truncation (lost footer) must be rejected
    with open(path) as f:
        full = f.readlines()
    with open(path, "w") as f:
        f.writelines(full[:-1])
    assert export.check_file(path)


def test_prometheus_export_golden_and_check(tmp_path):
    obs = _small_obs()
    path = str(tmp_path / "t.prom")
    export.write_prometheus(path, obs)
    text = open(path).read()
    assert text.rstrip().endswith("# EOF")
    assert 'serve_decode_tokens_total{client="0"} 12' in text
    # cumulative histogram framing with +Inf and _count
    assert 'serve_ttft_seconds_bucket{client="0",le="+Inf"} 2' in text
    assert 'serve_ttft_seconds_count{client="0"} 2' in text
    assert export.check_file(path) == []
    with open(path, "w") as f:
        f.write(text.replace("# EOF", ""))
    assert export.check_file(path)


def test_check_cli_exit_codes(tmp_path):
    from repro.obs.__main__ import main
    obs = _small_obs()
    good = str(tmp_path / "ok.jsonl")
    export.write_jsonl(good, obs)
    assert main(["--check", good]) == 0
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write('{"record": "metric"}\n')       # no header/footer framing
    assert main(["--check", bad]) != 0
    assert main(["--check", good, bad]) != 0    # one bad file fails the set


# ---------------------------------------------------------------------------
# profiler capture window
# ---------------------------------------------------------------------------

def test_capture_window_smoke(key, tmp_path):
    cfg = tiny()
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, key)
    obs = Obs()
    eng = _serving(cfg, base, bank, obs=obs)
    obs.request_capture(str(tmp_path / "prof"), ticks=1)
    _submit_all(eng, _prompts(cfg, per_client=1))
    eng.run()
    kinds = [e.kind for e in obs.events.peek()]
    if "capture_failed" in kinds:               # profiler unavailable here
        assert "capture_start" not in kinds
    else:
        assert "capture_start" in kinds and "capture_stop" in kinds
