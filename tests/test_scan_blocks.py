"""Recurrent blocks: chunked scans match sequential references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property sweeps are optional-dep gated
from hypothesis import given, settings, strategies as st

from repro.models.rwkv import wkv6_scan
from repro.models.mamba import selective_scan, _causal_conv


class TestWKV6:
    @given(chunk=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 20))
    @settings(max_examples=12, deadline=None)
    def test_chunked_equals_stepwise(self, chunk, seed):
        B, S, H, dk, dv = 2, 8, 2, 4, 4
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        r = jax.random.normal(ks[0], (B, S, H, dk))
        k = jax.random.normal(ks[1], (B, S, H, dk))
        v = jax.random.normal(ks[2], (B, S, H, dv))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dk)))  # decay in (0,1)
        bonus = jax.random.normal(ks[4], (H, dk)) * 0.1
        s0 = jnp.zeros((B, H, dk, dv))
        out_c, st_c = wkv6_scan(r, k, v, w, bonus, s0, chunk=chunk)

        # sequential reference
        s = np.zeros((B, H, dk, dv), np.float32)
        outs = []
        rn, kn, vn, wn = (np.asarray(t, np.float32) for t in (r, k, v, w))
        bn = np.asarray(bonus, np.float32)
        for t in range(S):
            kv = np.einsum("bhk,bhv->bhkv", kn[:, t], vn[:, t])
            outs.append(np.einsum("bhk,bhkv->bhv", rn[:, t],
                                  s + bn[None, :, :, None] * kv))
            s = wn[:, t][..., None] * s + kv
        ref = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(out_c), ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st_c), s, rtol=2e-4, atol=2e-4)

    def test_state_carries_across_calls(self):
        """prefill+decode chunking: scanning halves == scanning whole."""
        B, S, H, dk = 1, 8, 2, 4
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r, k, w = (jax.random.normal(ks[i], (B, S, H, dk)) for i in range(3))
        v = jax.random.normal(ks[3], (B, S, H, dk))
        w = jax.nn.sigmoid(w)
        bonus = jnp.zeros((H, dk))
        s0 = jnp.zeros((B, H, dk, dk))
        full, st_full = wkv6_scan(r, k, v, w, bonus, s0, chunk=4)
        h1, st1 = wkv6_scan(r[:, :4], k[:, :4], v[:, :4], w[:, :4], bonus, s0, chunk=4)
        h2, st2 = wkv6_scan(r[:, 4:], k[:, 4:], v[:, 4:], w[:, 4:], bonus, st1, chunk=4)
        np.testing.assert_allclose(np.asarray(full),
                                   np.asarray(jnp.concatenate([h1, h2], 1)),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                                   rtol=1e-4, atol=1e-5)


class TestSelectiveScan:
    @given(chunk=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 20))
    @settings(max_examples=12, deadline=None)
    def test_chunked_equals_stepwise(self, chunk, seed):
        B, S, ED, N = 2, 8, 4, 3
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        x = jax.random.normal(ks[0], (B, S, ED))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, ED)))
        Bc = jax.random.normal(ks[2], (B, S, N)) * 0.5
        Cc = jax.random.normal(ks[3], (B, S, N)) * 0.5
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (ED, N)) * 0.3)
        D = jnp.ones((ED,))
        h0 = jnp.zeros((B, ED, N))
        y_c, h_c = selective_scan(x, dt, Bc, Cc, A, D, h0, chunk=chunk)

        xn, dtn, Bn, Cn, An, Dn = (np.asarray(t, np.float32)
                                   for t in (x, dt, Bc, Cc, A, D))
        h = np.zeros((B, ED, N), np.float32)
        ys = []
        for t in range(S):
            a = np.exp(dtn[:, t][..., None] * An)
            b = dtn[:, t][..., None] * Bn[:, t][:, None, :] * xn[:, t][..., None]
            h = a * h + b
            ys.append(np.einsum("bdn,bn->bd", h, Cn[:, t]))
        ref = np.stack(ys, 1) + xn * Dn
        np.testing.assert_allclose(np.asarray(y_c), ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_c), h, rtol=2e-4, atol=2e-4)


class TestCausalConv:
    def test_state_continuation(self):
        B, S, ED, K = 1, 8, 3, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (B, S, ED))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, ED))
        b = jnp.zeros((ED,))
        full, _ = _causal_conv(x, w, b, None)
        h1, st = _causal_conv(x[:, :5], w, b, None)
        h2, _ = _causal_conv(x[:, 5:], w, b, st)
        np.testing.assert_allclose(np.asarray(full),
                                   np.asarray(jnp.concatenate([h1, h2], 1)),
                                   rtol=1e-5, atol=1e-6)
