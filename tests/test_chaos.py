"""The seeded chaos sweep as a pytest entry point (-m chaos).

CI's tier2-chaos job runs this plus ``python -m repro.faults.chaos`` for
the uploaded JSON report; the assertions here are the acceptance floor —
the sweep itself asserts the containment/recovery contracts per scenario
(see repro.faults.chaos and docs/robustness.md)."""
import pytest

from repro.faults import chaos

pytestmark = pytest.mark.chaos


def test_chaos_sweep(tmp_path):
    report = chaos.run_sweep(seed=0, workdir=str(tmp_path))
    assert report["ok"], report["errors"]
    assert report["total_injected"] >= 30
    assert len(report["kinds"]) >= 4
