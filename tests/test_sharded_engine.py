"""Sharded-vs-unsharded engine identity (ISSUE 7).

The host mesh (``make_host_mesh()``: (data=1, model=1)) carries the
production axis names on a single device, so the sharded engines must tick
**byte-identically** to the unsharded ones under the full auto sharding
plan — any drift means a constraint changed the program, not just the
layout. The ``tier2_sharded`` cases re-run identity on a real 2x2 host
mesh (CI sets ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)
with ``replicate_base=True``: batch/client-axis sharding with replicated
weights keeps bitwise identity, while tensor-parallel contraction
sharding is allowed last-bit drift (that regime is covered by the
collective audit, not an identity test).

The autouse trace guard doubles as the recompile check: a mesh engine
whose placements flap between committed/uncommitted would recompile on
the hot path and fail the fixture. ``test_mesh_does_not_widen_trace_domain``
pins the declared bucket sets themselves.
"""
import jax
import numpy as np
import pytest

from repro.config import (AdapterConfig, FinetuneConfig, ServeConfig,
                          DENSE, MOE)
from repro.core import symbiosis
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.launch.mesh import _make_mesh, make_host_mesh
from repro.serving.engine import Request, ServingEngine
from repro.training.engine import FinetuneEngine
from repro.training.job import FinetuneJob, make_job_stream
from conftest import tiny

METHODS = {
    "lora": AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v")),
    "ia3": AdapterConfig(method="ia3", targets=("k", "v", "down")),
    "prefix": AdapterConfig(method="prefix", targets=("q", "v"), n_prefix=4),
}


def _serve_stream(cfg, acfg, base, bank, mesh, *, replicate_base=False,
                  keep=None):
    """Drive a 2-client workload through a fresh engine; return the
    generated token arrays keyed by client. ``keep`` (a list) holds the
    engine alive: the trace guard identifies engines by ``id()``, so
    letting one die before the next is built can alias their compile
    records and mis-report a fresh compile as a hot-path recompile."""
    scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8)
    spec = EngineSpec(cfg=cfg, banks=(BankSpec("tenants", acfg, capacity=2),),
                      serve=scfg, mesh=mesh, replicate_base=replicate_base,
                      max_batch_per_client=2)
    eng = ServingEngine(spec, base, [bank])
    if keep is not None:
        keep.append(eng)
    rng = np.random.default_rng(7)
    for c in range(2):
        eng.submit(Request(client_id=c,
                           prompt=rng.integers(0, cfg.vocab, (1, 6))
                           .astype(np.int32),
                           max_new_tokens=4))
    return {r.client_id: np.asarray(r.generated) for r in eng.run()}


def _train_result(cfg, acfg, base, mesh, *, replicate_base=False, n_jobs=1,
                  keep=None):
    """Run n_jobs identical-shape jobs to completion; return their results
    (adapter/opt/losses) ordered by seed. ``keep`` as in _serve_stream."""
    spec = EngineSpec(cfg=cfg, banks=(BankSpec("jobs", acfg, capacity=2),),
                      finetune=FinetuneConfig(max_jobs=4), mesh=mesh,
                      replicate_base=replicate_base)
    eng = FinetuneEngine(spec, base)
    if keep is not None:
        keep.append(eng)
    jobs = [FinetuneJob(acfg=acfg, data=make_job_stream(cfg, 2, 8, seed=3 + i),
                        batch_size=2, seq_len=8, steps=3, seed=3 + i,
                        lr=1e-2, warmup_steps=1, max_grad_norm=1.0,
                        name=f"j{i}")
            for i in range(n_jobs)]
    for j in jobs:
        eng.submit(j)
    eng.run()
    return [j.result for j in jobs]


def _assert_results_equal(got, want, label):
    for a, b in zip(jax.tree.leaves((want.adapter, want.opt)),
                    jax.tree.leaves((got.adapter, got.opt))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{label}: train state diverged")
    np.testing.assert_allclose(got.losses, want.losses, rtol=1e-6,
                               err_msg=f"{label}: losses diverged")


@pytest.mark.parametrize("arch", [DENSE, MOE])
@pytest.mark.parametrize("method", sorted(METHODS))
def test_serving_identity_host_mesh(arch, method):
    """Serving ticks on the (1,1) host mesh are byte-identical to the
    unsharded engine, across PEFT methods and dense/MoE bases."""
    cfg = tiny(arch)
    acfg = METHODS[method]
    base, bank, _ = symbiosis.init_system(cfg, acfg, 2, jax.random.PRNGKey(0))
    keep = []
    ref = _serve_stream(cfg, acfg, base, bank, None, keep=keep)
    got = _serve_stream(cfg, acfg, base, bank, make_host_mesh(), keep=keep)
    assert ref.keys() == got.keys()
    for c in ref:
        np.testing.assert_array_equal(
            got[c], ref[c], err_msg=f"{arch}/{method}: client {c} diverged")


@pytest.mark.parametrize("arch", [DENSE, MOE])
@pytest.mark.parametrize("method", sorted(METHODS))
def test_train_identity_host_mesh(arch, method):
    """Train steps on the (1,1) host mesh leave adapter + optimizer state
    bitwise equal to the unsharded engine."""
    cfg = tiny(arch)
    acfg = METHODS[method]
    base = symbiosis.init_system(cfg, acfg, 1, jax.random.PRNGKey(0))[0]
    keep = []
    (ref,) = _train_result(cfg, acfg, base, None, keep=keep)
    (got,) = _train_result(cfg, acfg, base, make_host_mesh(), keep=keep)
    _assert_results_equal(got, ref, f"{arch}/{method}")


def test_mesh_does_not_widen_trace_domain():
    """Entering a mesh must not add jit bucket keys: the declared trace
    domain is a function of configs only, and the guard (autouse fixture)
    separately proves no compile lands outside it under the mesh."""
    cfg = tiny(DENSE)
    acfg = METHODS["lora"]
    scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8)
    base, bank, _ = symbiosis.init_system(cfg, acfg, 2, jax.random.PRNGKey(0))

    def spec(mesh):
        return EngineSpec(cfg=cfg, banks=(BankSpec("b", acfg, capacity=2),),
                          serve=scfg, finetune=FinetuneConfig(max_jobs=4),
                          mesh=mesh, max_batch_per_client=2)

    plain = ServingEngine(spec(None), base, [bank])
    meshed = ServingEngine(spec(make_host_mesh()), base, [bank])
    assert plain.trace_domain().families() == meshed.trace_domain().families()

    ft_plain = FinetuneEngine(spec(None), base)
    ft_meshed = FinetuneEngine(spec(make_host_mesh()), base)
    assert (ft_plain.trace_domain().families()
            == ft_meshed.trace_domain().families())


# ---------------------------------------------------------------------------
# tier2_sharded: real 2x2 device mesh (CI forces 4 host devices)
# ---------------------------------------------------------------------------
_needs_four = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@pytest.mark.tier2_sharded
@_needs_four
@pytest.mark.parametrize("method", ["lora", "ia3"])
def test_serving_identity_2x2(method):
    """2x2 mesh, replicated base: client-axis sharding of the page pool
    and banks must not change a single generated token."""
    cfg = tiny(DENSE)
    acfg = METHODS[method]
    base, bank, _ = symbiosis.init_system(cfg, acfg, 2, jax.random.PRNGKey(0))
    mesh = _make_mesh((2, 2), ("data", "model"))
    keep = []
    ref = _serve_stream(cfg, acfg, base, bank, None, keep=keep)
    got = _serve_stream(cfg, acfg, base, bank, mesh, replicate_base=True,
                        keep=keep)
    for c in ref:
        np.testing.assert_array_equal(
            got[c], ref[c], err_msg=f"2x2/{method}: client {c} diverged")


@pytest.mark.tier2_sharded
@_needs_four
def test_train_identity_2x2():
    """2x2 mesh, replicated base, two concurrent jobs so the compacted
    row axis actually splits over data=2: bitwise train state."""
    cfg = tiny(DENSE)
    acfg = METHODS["lora"]
    base = symbiosis.init_system(cfg, acfg, 1, jax.random.PRNGKey(0))[0]
    mesh = _make_mesh((2, 2), ("data", "model"))
    keep = []
    ref = _train_result(cfg, acfg, base, None, n_jobs=2, keep=keep)
    got = _train_result(cfg, acfg, base, mesh, replicate_base=True, n_jobs=2,
                        keep=keep)
    for r, g in zip(ref, got):
        _assert_results_equal(g, r, "2x2/lora")
