"""Tier-2 mixed-workload sweep (ISSUE 4 satellite): fine-tuning as a
service across PEFT methods × architecture families × join/leave churn ×
decode-interleave on/off.

Every combination asserts the tentpole contract end to end: each job's
final adapter params and optimizer state match a dedicated
``make_baseline_train_step`` run of that job alone, regardless of which
bank-mates churned around it or whether inference decode ticks were
interleaved against the same base. Dense and MoE hold BITWISE (MoE since
the dispatch-body checkpoint + unbatched R=1 bucket — see
tests/test_moe.py::TestVmapBitwise); the recurrent scans (mamba/RWKV
state) are still fused shape- and compilation-context-dependently by XLA
between the vmapped bank and the solo program, so those families assert
to 1-2 ulp (the tier-1 suite carries the strict bitwise contract on
dense for every method × churn × interleave combination)."""
import functools

import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, ServeConfig, TrainConfig, DENSE, MOE, HYBRID, RWKV
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.models import get_model
from repro.optim import adamw_init
from repro.serving.engine import Request, ServingEngine
from repro.training import (FinetuneEngine, FinetuneJob, SymbiosisEngine,
                            make_job_stream)
from conftest import tiny

pytestmark = pytest.mark.tier2

ARCHS = [DENSE, MOE, HYBRID, RWKV]
# vmapped-bank vs solo bitwise equality is structurally robust for dense,
# and for MoE since the dispatch-body checkpoint boundary + unbatched R=1
# bucket; the recurrent scans (mamba/RWKV) still fuse shape- and
# compilation-context-dependently, leaving 1-2 ulp between the programs
BITWISE_ARCHS = {DENSE, MOE}
METHODS = ["lora", "ia3", "prefix"]
TARGETS = {"lora": ("q", "v"), "ia3": ("k", "v", "down"), "prefix": ("q", "v")}


# one oracle compile per (cfg, acfg, tcfg) across the whole sweep — the
# solo baseline is the dominant compile cost otherwise
@functools.lru_cache(maxsize=None)
def _oracle_step(cfg, acfg, tcfg):
    return jax.jit(symbiosis.make_baseline_train_step(cfg, acfg, tcfg))


def _job(cfg, method, seed, steps, **kw):
    acfg = AdapterConfig(method=method, rank=4, alpha=8.0,
                         targets=TARGETS[method])
    return FinetuneJob(acfg=acfg, data=make_job_stream(cfg, 2, 12, seed=seed),
                       batch_size=2, seq_len=12, steps=steps, seed=seed,
                       lr=1e-2, warmup_steps=1, name=f"{method}-{seed}", **kw)


def _assert_matches_oracle(cfg, base, job):
    tcfg = TrainConfig(lr=job.lr, weight_decay=job.weight_decay,
                       warmup_steps=job.warmup_steps,
                       total_steps=job.schedule_total,
                       max_grad_norm=job.max_grad_norm, remat=False,
                       microbatch=job.microbatch)
    step_fn = _oracle_step(cfg, job.acfg, tcfg)
    adapter = ad_lib.init_adapter(cfg, job.acfg, jax.random.PRNGKey(job.seed))
    opt = adamw_init(adapter)
    losses = []
    for t in range(job.steps):
        adapter, opt, m = step_fn(base, adapter, opt, job.data.batch(t), t)
        losses.append(float(np.asarray(m["loss"])))
    bitwise = cfg.arch in BITWISE_ARCHS
    for a, b in zip(jax.tree.leaves((adapter, opt)),
                    jax.tree.leaves((job.result.adapter, job.result.opt))):
        if bitwise:
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{job.name} diverged from solo on {cfg.arch}")
        else:
            # ulp-level fusion drift, amplified through Adam's moment
            # normalization over steps — the repo's standard same-math
            # tolerance (cf. tests/test_symbiosis.py)
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5,
                err_msg=f"{job.name} diverged from solo on {cfg.arch}")
    np.testing.assert_allclose(job.result.losses, losses, rtol=1e-6)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("churn", [False, True])
@pytest.mark.parametrize("interleave", [False, True])
def test_mixed_workload_byte_identity(arch, method, churn, interleave):
    cfg = tiny(arch)
    key = jax.random.PRNGKey(0)
    acfg_inf = AdapterConfig(method="lora", rank=4, alpha=8.0,
                             targets=("q", "v"))
    base, inf_bank, _ = symbiosis.init_system(cfg, acfg_inf, 2, key)

    ft = FinetuneEngine(cfg, base)
    jobs = [_job(cfg, method, seed=0, steps=4),
            _job(cfg, method, seed=1, steps=4)]
    if churn:
        jobs.append(_job(cfg, method, seed=2, steps=2))    # leaves early
    for j in jobs:
        ft.submit(j)

    if interleave:
        scfg = ServeConfig(n_clients=2, max_seq=32)
        serving = ServingEngine(cfg, acfg_inf, scfg, base, inf_bank,
                                max_batch_per_client=1)
        sym = SymbiosisEngine(serving=serving, finetune=ft)
        rng = np.random.default_rng(7)
        reqs = [Request(client_id=i % 2,
                        prompt=rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                        max_new_tokens=5, arrive_tick=i) for i in range(3)]
        for r in reqs:
            sym.submit(r)
        done_r, done_j = sym.run()
        assert len(done_r) == 3 and len(done_j) == len(jobs)
        # interleaved serving still matches solo serving
        solo = ServingEngine(cfg, acfg_inf, scfg, base, inf_bank,
                             max_batch_per_client=1)
        rng = np.random.default_rng(7)
        ref = [Request(client_id=i % 2,
                       prompt=rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                       max_new_tokens=5, arrive_tick=i) for i in range(3)]
        for r in ref:
            solo.submit(r)
        solo.run()
        for a, b in zip(reqs, ref):
            np.testing.assert_array_equal(a.generated, b.generated)
    else:
        if churn:
            # stagger the churn join so membership changes mid-run
            for _ in range(1):
                ft.train_tick()
            late = _job(cfg, method, seed=3, steps=2)
            jobs.append(late)
            ft.submit(late)
        ft.run()

    for j in jobs:
        _assert_matches_oracle(cfg, base, j)


def test_twenty_jobs_one_base():
    """The paper's headline shape (§5): 20 adapters fine-tuned
    simultaneously against ONE shared frozen base, mixed PEFT methods,
    every one bitwise-faithful to its dedicated run."""
    cfg = tiny(DENSE)
    base = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = FinetuneEngine(cfg, base)
    from repro.config import FinetuneConfig
    eng.fcfg = FinetuneConfig(max_jobs=20)
    jobs = [_job(cfg, METHODS[i % 3], seed=i, steps=2 + i % 3)
            for i in range(20)]
    for j in jobs:
        eng.submit(j)
    done = eng.run()
    assert len(done) == 20
    assert eng.stats["peak_jobs"] == 20
    for j in jobs:
        _assert_matches_oracle(cfg, base, j)
