"""Mutation self-tests for the repro.analysis invariant passes (ISSUE 6).

Every pass is demonstrated BOTH ways: clean on the real engine step and
firing on a deliberately broken variant — a dropped donation, an inserted
pool copy, a scan that stacks the pool, an un-checkpointed MoE body, a
step that "trains" the frozen base, and an un-bucketed prefill shape.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import aliasing, jaxpr_passes, taint, tracecount
from repro.analysis.targets import serving_targets, tiny_config, train_targets
from repro.config import DENSE, MOE, AdapterConfig, ServeConfig
from repro.core import symbiosis

LORA = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))


@pytest.fixture(scope="module")
def decode_target():
    return next(t for t in serving_targets(DENSE)
                if t.name == "compact_decode[dense-paged]")


@pytest.fixture(scope="module")
def train_target():
    return next(t for t in train_targets(DENSE)
                if t.name.startswith("compact_train"))


@pytest.fixture(scope="module")
def moe_train_target():
    return next(t for t in train_targets(MOE)
                if t.name.startswith("compact_train"))


# --------------------------------------------------------------- donation
def test_donation_clean_on_real_step(decode_target):
    t = decode_target
    hlo = aliasing.compile_text(t.fn, t.args, t.donate_argnums)
    res = aliasing.check_donation(hlo, t.donated, target=t.name,
                                  frozen_leaves=t.frozen)
    assert res.ok, [str(v) for v in res.violations]
    assert res.checked["aliased_params"] == len(t.donated)


def test_donation_mutation_dropped_donation_fires(decode_target):
    t = decode_target
    hlo = aliasing.compile_text(t.fn, t.args, ())   # mutation: no donation
    res = aliasing.check_donation(hlo, t.donated, target="mutated")
    assert not res.ok
    assert all("no input-output alias" in v.message for v in res.violations)
    assert len(res.violations) == len(t.donated)


def test_donation_mutation_base_alias_fires():
    # mutation: a step donates and overwrites the FROZEN base in place
    base = {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))}

    def bad(b):
        return jax.tree.map(lambda x: x * 2.0, b)

    hlo = aliasing.compile_text(bad, (base,), (0,))
    res = aliasing.check_donation(
        hlo, [], target="mutated",
        frozen_leaves=aliasing.donated_leaf_paths(base))
    assert not res.ok
    assert any("base" in v.message for v in res.violations)


# --------------------------------------------------------------- poolcopy
def test_poolcopy_clean_on_real_step(decode_target):
    t = decode_target
    res = jaxpr_passes.check_pool_copies(t.jaxpr(), t.protected_sigs,
                                         target=t.name)
    assert res.ok, [str(v) for v in res.violations]
    assert res.checked["inplace_writes"] >= 1


def test_poolcopy_mutation_arithmetic_fires(decode_target):
    t = decode_target

    def bad(*args):            # mutation: full-pool arithmetic after the tick
        # probed decode targets return (logits, finite, caches); the pool
        # caches are always the LAST output either way
        *out, caches = t.fn(*args)
        return (*out, jax.tree.map(lambda x: x * jnp.asarray(2, x.dtype),
                                   caches))

    jx = jax.make_jaxpr(bad)(*t.args)
    res = jaxpr_passes.check_pool_copies(jx, t.protected_sigs,
                                         target="mutated")
    assert not res.ok
    assert any("materializes a pool-sized" in v.message
               for v in res.violations)


def test_poolcopy_mutation_scan_ys_fires(decode_target):
    t = decode_target
    caches = t.args[2]

    def bad(caches):           # mutation: a loop stacking the pool (PR 5 bug)
        def body(c, _):
            return c, c["layers"]["k"]
        return jax.lax.scan(body, caches, None, length=2)

    jx = jax.make_jaxpr(bad)(caches)
    res = jaxpr_passes.check_pool_copies(jx, t.protected_sigs,
                                         target="mutated")
    assert any("scan stacks a pool-sized ys" in v.message
               for v in res.violations)


def test_poolcopy_reshape_alias_still_protected(decode_target):
    """A reshape of the pool is benign, but ops at the reshaped shape are
    still pool-sized — the signature set must follow the bitcast."""
    t = decode_target
    caches = t.args[2]

    def bad(caches):
        k = caches["layers"]["k"]
        folded = k.reshape((-1,) + k.shape[2:])     # benign layer fold
        return folded + 1.0                         # ...then a full copy

    jx = jax.make_jaxpr(bad)(caches)
    res = jaxpr_passes.check_pool_copies(jx, t.protected_sigs,
                                         target="mutated")
    assert any(v.detail.get("primitive") == "add" for v in res.violations)


# ------------------------------------------------ poolcopy: compact prefill
@pytest.fixture(scope="module")
def prefill_target():
    return next(t for t in serving_targets(DENSE)
                if t.name == "compact_prefill[dense-paged]")


def test_poolcopy_clean_on_compact_prefill(prefill_target):
    t = prefill_target
    res = jaxpr_passes.check_pool_copies(t.jaxpr(), t.protected_sigs,
                                         target=t.name)
    assert res.ok, [str(v) for v in res.violations]
    assert res.checked["inplace_writes"] >= 1


def test_poolcopy_mutation_compact_prefill_fires(prefill_target):
    t = prefill_target

    def bad(*args):            # mutation: full-pool copy after the prefill
        *out, caches = t.fn(*args)
        return (*out, jax.tree.map(lambda x: x * jnp.asarray(2, x.dtype),
                                   caches))

    jx = jax.make_jaxpr(bad)(*t.args)
    res = jaxpr_passes.check_pool_copies(jx, t.protected_sigs,
                                         target="mutated")
    assert not res.ok
    assert any("materializes a pool-sized" in v.message
               for v in res.violations)


def test_donation_clean_on_compact_prefill(prefill_target):
    t = prefill_target
    hlo = aliasing.compile_text(t.fn, t.args, t.donate_argnums)
    res = aliasing.check_donation(hlo, t.donated, target=t.name,
                                  frozen_leaves=t.frozen)
    assert res.ok, [str(v) for v in res.violations]


# --------------------------------------------------------------- moe remat
def test_moe_remat_clean_on_real_step(moe_train_target):
    res = jaxpr_passes.check_moe_checkpointed(moe_train_target.jaxpr(),
                                              target=moe_train_target.name)
    assert res.ok
    assert res.checked["top_k_eqns"] >= 1
    assert res.checked["remat_regions"] >= 1


def test_moe_remat_mutation_fires(monkeypatch, moe_train_target):
    # mutation: jax.checkpoint becomes the identity — the MoE routing body
    # is no longer rematerialized anywhere in the step
    monkeypatch.setattr(jax, "checkpoint", lambda f, *a, **k: f)
    fn = symbiosis.make_compact_train_step(tiny_config(MOE), LORA)
    jx = jax.make_jaxpr(fn)(*moe_train_target.args)
    res = jaxpr_passes.check_moe_checkpointed(jx, target="mutated")
    assert not res.ok
    assert any("outside any jax.checkpoint" in v.message
               for v in res.violations)


# --------------------------------------------------------------- taint
def test_frozen_base_taint_clean_on_real_step(train_target):
    t = train_target
    res = taint.check_frozen_base(t.fn, t.args,
                                  update_argnums=t.donate_argnums,
                                  target=t.name)
    assert res.ok, [str(v) for v in res.violations]


def test_frozen_base_taint_mutation_fires(train_target):
    t = train_target

    def bad(base, bank, opt, batch, slots, rmask, hyper):
        nb, no, metrics = t.fn(base, bank, opt, batch, slots, rmask, hyper)
        # mutation: the step also "updates" the frozen base
        new_base = jax.tree.map(lambda w: w - 1e-4 * w, base)
        return new_base, nb, no, metrics

    res = taint.check_frozen_base(bad, t.args, update_argnums=(1, 2),
                                  target="mutated")
    assert not res.ok
    assert any("updated base" in v.message for v in res.violations)


def test_row_isolation_probe_clean(train_target):
    t = train_target
    iso = t.isolation
    res = taint.check_row_isolation(
        t.fn, t.args, perturb_row=iso["perturb_row"],
        victim_slot=iso["victim_slot"],
        perturb_argnums=iso["perturb_argnums"], target=t.name)
    assert res.ok, [str(v) for v in res.violations]
    assert res.checked["row_leaves_checked"] >= 1


# --------------------------------------------------------------- buckets
def test_trace_domain_check_states():
    d = tracecount.TraceDomain()
    d.declare("prefill", {(0, 8), (0, 16)})
    d.declare("train", predicate=lambda k: k[1] % 2 == 0)
    d.declare("misc", unbounded=True)
    assert d.check("prefill", (0, 8)) == tracecount.OK
    assert d.check("prefill", (0, 6)) == tracecount.OUT_OF_DOMAIN
    assert d.check("train", ("bank", 4)) == tracecount.OK
    assert d.check("train", ("bank", 3)) == tracecount.OUT_OF_DOMAIN
    assert d.check("misc", object()) == tracecount.UNBOUNDED
    assert d.check("never-declared", 1) == tracecount.UNDECLARED


def test_trace_guard_flags_out_of_domain_and_recompile():
    class Owner:
        _trace_epoch = 0

        def trace_domain(self):
            return tracecount.TraceDomain().declare("step", {8})

    owner = Owner()
    fn = jax.jit(lambda x: x * 2)
    with tracecount.guard("unit") as g:
        tracecount.dispatch(owner, "step", 8, fn, jnp.ones((8,)))   # legal
        tracecount.dispatch(owner, "step", 8, fn, jnp.ones((8,)))   # cached
        tracecount.dispatch(owner, "step", 6, fn, jnp.ones((6,)))   # illegal
        # same declared key compiled AGAIN (dtype leaked past the bucket)
        tracecount.dispatch(owner, "step", 8, fn,
                            jnp.ones((8,), jnp.int32))
    res = g.result()
    assert res.checked["calls"] == 4
    assert res.checked["compiles"] == 3
    msgs = [v.message for v in res.violations]
    assert any("outside the declared bucket set" in m for m in msgs)
    assert any("RECOMPILE" in m for m in msgs)


def test_bucket_guard_fires_on_unbucketed_prefill(monkeypatch):
    from repro.serving.engine import Request, ServingEngine

    cfg = tiny_config(DENSE)
    scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8)
    base, bank, _ = symbiosis.init_system(cfg, LORA, 2, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, LORA, scfg, base, bank, max_batch_per_client=2)
    # mutation: prompt bucketing disabled — prefill compiles raw lengths
    monkeypatch.setattr(ServingEngine, "_bucket", lambda self, S: S)
    with tracecount.guard("mutated-engine") as g:
        eng.submit(Request(client_id=0, prompt=np.ones((1, 6), np.int32),
                           max_new_tokens=2))
        eng.run()
    res = g.result()
    assert not res.ok
    assert any("outside the declared bucket set" in v.message
               for v in res.violations)


def test_dispatch_without_guard_is_plain_call(monkeypatch):
    # the tier-1 autouse fixture keeps a guard active for every test, so
    # explicitly clear it: unguarded dispatch must not touch the owner at
    # all (the owner here has no trace_domain())
    monkeypatch.setattr(tracecount, "_ACTIVE", None)
    fn = jax.jit(lambda x: x + 1)
    out = tracecount.dispatch(object(), "step", 1, fn, jnp.ones((3,)))
    np.testing.assert_allclose(np.asarray(out), 2.0)
