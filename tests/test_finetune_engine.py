"""Fine-tuning-as-a-service: FinetuneEngine / SymbiosisEngine behaviour.

The load-bearing contract (ISSUE 4): every job admitted to the service —
whatever its PEFT method, hyperparameters, or the join/leave churn and
decode interleaving around it — produces per-step grads, adapter params and
optimizer state BITWISE equal to a dedicated ``make_baseline_train_step``
run of that job alone."""
import functools

import jax
import numpy as np
import pytest

from repro.config import AdapterConfig, FinetuneConfig, ServeConfig, TrainConfig
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.models import get_model
from repro.optim import adamw_init
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import PlacementRouter, Slot
from repro.training import (FinetuneEngine, FinetuneJob, SymbiosisEngine,
                            job_hbm_bytes, make_job_stream)
from conftest import tiny


def _job(cfg, method="lora", seed=0, steps=4, batch=2, seq=16, **kw):
    targets = {"lora": ("q", "v"), "ia3": ("k", "v", "down"),
               "prefix": ("q", "v")}[method]
    acfg = kw.pop("acfg", None) or AdapterConfig(method=method, rank=4,
                                                 alpha=8.0, targets=targets)
    defaults = {"lr": 1e-2, "warmup_steps": 1, "max_grad_norm": 1.0}
    defaults.update(kw)
    return FinetuneJob(acfg=acfg, data=make_job_stream(cfg, batch, seq, seed=seed),
                       batch_size=batch, seq_len=seq, steps=steps, seed=seed,
                       name=f"{method}-{seed}", **defaults)


@functools.lru_cache(maxsize=None)
def _oracle_step(cfg, acfg, tcfg):
    """One oracle compile per config tuple across the whole module."""
    return jax.jit(symbiosis.make_baseline_train_step(cfg, acfg, tcfg))


def _solo_oracle(cfg, base, job):
    """The dedicated run: make_baseline_train_step (its DEFAULT form — the
    torch-like baseline that differentiates through the base) over the
    job's own stream/schedule. Returns (adapter, opt, losses, gnorms)."""
    tcfg = TrainConfig(lr=job.lr, weight_decay=job.weight_decay,
                       warmup_steps=job.warmup_steps,
                       total_steps=job.schedule_total,
                       max_grad_norm=job.max_grad_norm, remat=False,
                       microbatch=job.microbatch)
    step_fn = _oracle_step(cfg, job.acfg, tcfg)
    adapter = ad_lib.init_adapter(cfg, job.acfg, jax.random.PRNGKey(job.seed))
    opt = adamw_init(adapter)
    losses, gnorms = [], []
    for t in range(job.start_step, job.steps):
        adapter, opt, m = step_fn(base, adapter, opt, job.data.batch(t), t)
        losses.append(float(np.asarray(m["loss"])))
        gnorms.append(np.asarray(m["gnorm"]))
    return adapter, opt, losses, gnorms


def _assert_job_matches_oracle(cfg, base, job):
    # Comparing the FULL optimizer state bitwise pins the PER-STEP grads,
    # not just the endpoint: m_1 = (1-b1)·g_1 exactly, and each m_t/v_t is
    # reconstructible from (m_{t-1}, g_t) — so any step's grad deviating by
    # even one bit would surface in the final moments.
    adapter, opt, losses, _ = _solo_oracle(cfg, base, job)
    assert job.result is not None, f"{job.name} never retired"
    for a, b in zip(jax.tree.leaves((adapter, opt)),
                    jax.tree.leaves((job.result.adapter, job.result.opt))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{job.name} diverged from solo")
    # the loss scalar is a reduction over the same logits; XLA may fuse it
    # differently per row-bucket shape (grads/params above are the bitwise
    # contract), so last-bits tolerance here
    np.testing.assert_allclose(job.result.losses, losses, rtol=1e-6)


@pytest.fixture
def base(key):
    return get_model(tiny()).init_params(key)


def _solo_reference_via_engine(cfg, scfg, base, bank, acfg, req):
    """The request served alone through a fresh, router-less engine."""
    eng = ServingEngine(cfg, acfg, scfg, base, bank, max_batch_per_client=1)
    solo = Request(client_id=req.client_id, prompt=req.prompt.copy(),
                   max_new_tokens=req.max_new_tokens)
    eng.submit(solo)
    (done,) = eng.run()
    return done.generated


class TestByteIdentity:
    @pytest.mark.parametrize("method", ["lora", "ia3", "prefix"])
    def test_bank_matches_solo_baseline(self, base, method):
        """One bank, three jobs with HETEROGENEOUS hyperparameters (lr,
        weight decay, clipping, schedules): each matches its dedicated
        run bitwise."""
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        jobs = [
            _job(cfg, method, seed=0, steps=3, lr=1e-2, weight_decay=0.0),
            _job(cfg, method, seed=1, steps=4, lr=3e-3, weight_decay=0.1,
                 max_grad_norm=0.5, warmup_steps=0, total_steps=20),
            _job(cfg, method, seed=2, steps=5, lr=1e-3, max_grad_norm=0.0),
        ]
        for j in jobs:
            eng.submit(j)
        done = eng.run()
        assert len(done) == 3
        assert len(eng._banks) == 1, "same AdapterConfig+shape must share a bank"
        for j in jobs:
            _assert_job_matches_oracle(cfg, base, j)

    def test_join_leave_churn_byte_identity(self, base):
        """Jobs joining mid-run and leaving early never change any job's
        math — admission/retirement only decides WHICH rows exist."""
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        j0 = _job(cfg, "lora", seed=0, steps=6)
        j1 = _job(cfg, "lora", seed=1, steps=2)       # leaves early
        eng.submit(j0)
        eng.submit(j1)
        for _ in range(2):
            eng.train_tick()
        j2 = _job(cfg, "lora", seed=2, steps=3)       # joins mid-run
        eng.submit(j2)
        eng.run()
        for j in (j0, j1, j2):
            _assert_job_matches_oracle(cfg, base, j)

    def test_explicit_mid_run_retire(self, base):
        """An explicitly retired job hands back exactly the state of the
        steps it ran; survivors complete unperturbed."""
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        j0 = _job(cfg, "lora", seed=0, steps=8)
        j1 = _job(cfg, "lora", seed=1, steps=4)
        eng.submit(j0)
        eng.submit(j1)
        for _ in range(3):
            eng.train_tick()
        res = eng.retire(j0)                           # leaves at step 3
        assert res.step == 3
        eng.run()
        # oracle over the 3 steps actually run, on the ORIGINAL schedule
        # horizon (retiring early doesn't rewrite the lr schedule)
        j0.total_steps = j0.schedule_total
        j0.steps = 3
        _assert_job_matches_oracle(cfg, base, j0)
        _assert_job_matches_oracle(cfg, base, j1)

    def test_heterogeneous_banks_one_engine(self, base):
        """LoRA + IA3 + prefix + a different rank + a different batch shape:
        five jobs, several banks, ONE engine, one base — and every job
        still bitwise-matches its dedicated run (the multi-bank
        heterogeneous-methods ROADMAP item)."""
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        jobs = [
            _job(cfg, "lora", seed=0, steps=3),
            _job(cfg, "lora", seed=1, steps=3,
                 acfg=AdapterConfig(method="lora", rank=8, alpha=16.0,
                                    targets=("q", "k", "v", "o"))),
            _job(cfg, "ia3", seed=2, steps=4),
            _job(cfg, "prefix", seed=3, steps=4),
            _job(cfg, "lora", seed=4, steps=3, batch=4),   # same acfg, new shape
        ]
        for j in jobs:
            eng.submit(j)
        eng.run()
        assert len(eng._banks) == 5
        for j in jobs:
            _assert_job_matches_oracle(cfg, base, j)

    def test_bank_capacity_growth(self, base):
        """More jobs than any initial bucket: the bank doubles its capacity
        under admission without disturbing already-resident jobs."""
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        jobs = [_job(cfg, "lora", seed=i, steps=2 + i % 2) for i in range(5)]
        for j in jobs:
            eng.submit(j)
        eng.run()
        (bank,) = eng._banks.values()
        assert bank.cap == 8
        for j in jobs:
            _assert_job_matches_oracle(cfg, base, j)

    def test_microbatched_job_matches_solo(self, base):
        """Grad-accum microbatching is part of the bank key and of the
        shared row-grads program — accumulation math identical to solo."""
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        jobs = [_job(cfg, "lora", seed=0, steps=3, batch=4, microbatch=2),
                _job(cfg, "lora", seed=1, steps=3, batch=4)]   # separate bank
        for j in jobs:
            eng.submit(j)
        eng.run()
        assert len(eng._banks) == 2
        for j in jobs:
            _assert_job_matches_oracle(cfg, base, j)


class TestIsolation:
    def test_churn_never_perturbs_survivors(self, base):
        """Satellite: the training analogue of the serving cross-client
        isolation test. A survivor's per-tick params, optimizer state and
        loss sequence are identical whether or not other jobs join/leave
        around it — snapshots compared tick by tick, bitwise."""
        cfg = tiny()

        def survivor():
            return _job(cfg, "lora", seed=0, steps=5)

        def run(churn):
            eng = FinetuneEngine(cfg, base)
            job = survivor()
            eng.submit(job)
            if churn:
                eng.submit(_job(cfg, "lora", seed=1, steps=2))
            snaps = []
            t = 0
            while eng.pending():
                if churn and t == 2:
                    eng.submit(_job(cfg, "lora", seed=2, steps=2))
                eng.train_tick()
                if job.result is None:
                    snaps.append(jax.tree.map(np.asarray,
                                              eng.job_state(job)[:2]))
                t += 1
            return job, snaps

        quiet_job, quiet_snaps = run(churn=False)
        churn_job, churn_snaps = run(churn=True)
        # params/opt below are the bitwise contract; the loss SCALAR is a
        # report whose final reduction XLA fuses differently per row-bucket
        # shape (churn changes the bucket), hence last-bits tolerance
        np.testing.assert_allclose(quiet_job.result.losses,
                                   churn_job.result.losses, rtol=1e-6)
        for sq, sc in zip(quiet_snaps, churn_snaps):
            for a, b in zip(jax.tree.leaves(sq), jax.tree.leaves(sc)):
                np.testing.assert_array_equal(a, b)
        for a, b in zip(
                jax.tree.leaves((quiet_job.result.adapter, quiet_job.result.opt)),
                jax.tree.leaves((churn_job.result.adapter, churn_job.result.opt))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCheckpointResume:
    def test_retire_checkpoint_readmit_bitwise(self, base, tmp_path):
        """Satellite: a job retired mid-service, checkpointed, and
        re-admitted resumes with bitwise-identical adapter + optimizer
        state and continues the SAME loss trajectory as the uninterrupted
        run."""
        from repro.checkpoint import restore_job_state, save_job_state
        cfg = tiny()
        # uninterrupted reference (through the engine, alongside a neighbour
        # so bucket shapes match the interrupted run's early ticks)
        ref_eng = FinetuneEngine(cfg, base)
        ref = _job(cfg, "ia3", seed=0, steps=6)
        ref_eng.submit(ref)
        ref_eng.submit(_job(cfg, "ia3", seed=1, steps=3))
        ref_eng.run()

        eng = FinetuneEngine(cfg, base)
        job = _job(cfg, "ia3", seed=0, steps=6)
        eng.submit(job)
        eng.submit(_job(cfg, "ia3", seed=1, steps=3))
        for _ in range(3):
            eng.train_tick()
        res = eng.retire(job)
        assert res.step == 3
        save_job_state(tmp_path, res.step, res.adapter, res.opt, name="j")
        like_a = ad_lib.init_adapter(cfg, job.acfg, jax.random.PRNGKey(9))
        adapter, opt = restore_job_state(tmp_path, res.step, like_a,
                                         adamw_init(like_a), name="j")
        # roundtrip is exact
        for a, b in zip(jax.tree.leaves((res.adapter, res.opt)),
                        jax.tree.leaves((adapter, opt))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        resumed = _job(cfg, "ia3", seed=0, steps=6)
        resumed.init_adapter, resumed.init_opt = adapter, opt
        resumed.start_step = res.step
        eng.submit(resumed)
        eng.run()
        assert resumed.result.step == 6
        for a, b in zip(jax.tree.leaves((ref.result.adapter, ref.result.opt)),
                        jax.tree.leaves((resumed.result.adapter,
                                         resumed.result.opt))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(res.losses + resumed.result.losses,
                                   ref.result.losses, rtol=1e-6)
        # and the oracle agrees end to end
        _assert_job_matches_oracle(cfg, base, ref)


class TestAdmission:
    def test_router_backpressure_serializes_jobs(self, base):
        """With one slot sized for a single job's adapter+optimizer+
        activation charge, a second job queues until the first retires."""
        cfg = tiny()
        probe = _job(cfg, "lora", seed=0, steps=2)
        nbytes = job_hbm_bytes(cfg, probe)
        router = PlacementRouter(cfg, [Slot(0, free_hbm=nbytes * 1.5)],
                                 host_free_bytes=0)
        eng = FinetuneEngine(cfg, base, router=router)
        eng.submit(_job(cfg, "lora", seed=0, steps=2))
        eng.submit(_job(cfg, "lora", seed=1, steps=2))
        done = eng.run()
        assert len(done) == 2
        assert eng.stats["peak_jobs"] == 1
        for j in done:
            _assert_job_matches_oracle(cfg, base, j)

    def test_unadmittable_job_raises(self, base):
        cfg = tiny()
        router = PlacementRouter(cfg, [Slot(0, free_hbm=16.0)],
                                 host_free_bytes=0)
        eng = FinetuneEngine(cfg, base, router=router)
        eng.submit(_job(cfg, "lora", seed=0, steps=2))
        with pytest.raises(RuntimeError, match="never be admitted"):
            eng.run()

    def test_max_jobs_ceiling(self, base):
        cfg = tiny()
        eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=2))
        for i in range(4):
            eng.submit(_job(cfg, "lora", seed=i, steps=2))
        done = eng.run()
        assert len(done) == 4 and eng.stats["peak_jobs"] == 2

    def test_submit_validation(self, base):
        cfg = tiny()
        eng = FinetuneEngine(cfg, base)
        bad = _job(cfg, "lora", steps=2)
        bad.init_adapter = {}
        with pytest.raises(ValueError, match="both init_adapter and init_opt"):
            eng.submit(bad)
        late = _job(cfg, "lora", steps=2)
        late.start_step = 2
        with pytest.raises(ValueError, match="nothing to run"):
            eng.submit(late)


class TestSymbiosisService:
    def _system(self, key):
        cfg = tiny()
        acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
        scfg = ServeConfig(n_clients=2, max_seq=48)
        base, bank, _ = symbiosis.init_system(cfg, acfg, 2, key)
        return cfg, acfg, scfg, base, bank

    def _requests(self, cfg):
        rng = np.random.default_rng(5)
        return [Request(client_id=i % 2,
                        prompt=rng.integers(0, cfg.vocab, (1, 6)).astype(np.int32),
                        max_new_tokens=7, arrive_tick=i) for i in range(4)]

    def test_interleaving_changes_nothing(self, key):
        """Decode ticks interleaved with train steps against ONE base:
        serving outputs and every job's trajectory are identical to each
        engine running alone."""
        cfg, acfg, scfg, base, bank = self._system(key)

        def jobs():
            return [_job(cfg, "lora", seed=0, steps=4),
                    _job(cfg, "ia3", seed=1, steps=6)]

        sym = SymbiosisEngine(
            serving=ServingEngine(cfg, acfg, scfg, base, bank,
                                  max_batch_per_client=2),
            finetune=FinetuneEngine(cfg, base))
        mixed_reqs, mixed_jobs = self._requests(cfg), jobs()
        for r in mixed_reqs:
            sym.submit(r)
        for j in mixed_jobs:
            sym.submit(j)
        done_r, done_j = sym.run()
        assert len(done_r) == 4 and len(done_j) == 2
        assert sym.stats["decode_ticks"] > 0 and sym.stats["train_ticks"] > 0

        solo_serv = ServingEngine(cfg, acfg, scfg, base, bank,
                                  max_batch_per_client=2)
        solo_reqs = self._requests(cfg)
        for r in solo_reqs:
            solo_serv.submit(r)
        solo_serv.run()
        for a, b in zip(mixed_reqs, solo_reqs):
            np.testing.assert_array_equal(a.generated, b.generated)

        solo_ft = FinetuneEngine(cfg, base)
        solo_jobs = jobs()
        for j in solo_jobs:
            solo_ft.submit(j)
        solo_ft.run()
        for a, b in zip(mixed_jobs, solo_jobs):
            assert a.result.losses == b.result.losses
            for x, y in zip(jax.tree.leaves((a.result.adapter, a.result.opt)),
                            jax.tree.leaves((b.result.adapter, b.result.opt))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            _assert_job_matches_oracle(cfg, base, a)

    def test_shared_router_stall_is_not_fatal(self, key):
        """ONE PlacementRouter shared by both engines: a request queued
        behind HBM pinned by a fine-tuning job must WAIT (not trip the
        standalone 'can never be admitted' error) and admit once the job
        retires — and vice versa. Standalone engines still raise."""
        cfg, acfg, scfg, base, bank = self._system(key)
        from repro.serving import kvcache
        job = _job(cfg, "lora", seed=0, steps=3)
        req_need = kvcache.cache_bytes(cfg, scfg.max_seq, 1)
        job_need = job_hbm_bytes(cfg, job)
        # fits the training job OR one request, never both
        router = PlacementRouter(
            cfg, [Slot(0, free_hbm=max(req_need, job_need) * 1.2)],
            host_free_bytes=0)
        serving = ServingEngine(cfg, acfg, scfg, base, bank,
                                max_batch_per_client=1, router=router)
        ft = FinetuneEngine(cfg, base, router=router)
        sym = SymbiosisEngine(serving=serving, finetune=ft)
        sym.submit(job)
        sym.tick()                        # job admitted, holds the slot HBM
        req = self._requests(cfg)[0]
        req.arrive_tick = 0
        sym.submit(req)
        done_r, done_j = sym.run()        # must NOT raise
        assert len(done_r) == 1 and len(done_j) == 1
        assert sym.stats["admission_stalls"] > 0
        np.testing.assert_array_equal(
            done_r[0].generated,
            _solo_reference_via_engine(cfg, scfg, base, bank, acfg, req))
        # the standalone engine still fails fast when truly stuck
        solo = ServingEngine(cfg, acfg, scfg, base, bank,
                             max_batch_per_client=1,
                             router=PlacementRouter(cfg, [Slot(0, free_hbm=16.0)],
                                                    host_free_bytes=0))
        solo.submit(self._requests(cfg)[0])
        with pytest.raises(RuntimeError, match="never be admitted"):
            solo.run()

    def test_rejects_split_base(self, key):
        """A COPY of the base is not the shared base — admitting it would
        silently double base HBM, the thing the service exists to avoid."""
        cfg, acfg, scfg, base, bank = self._system(key)
        serving = ServingEngine(cfg, acfg, scfg, base, bank)
        copied = jax.tree.map(lambda x: x + 0, base)
        with pytest.raises(ValueError, match="share ONE frozen base"):
            SymbiosisEngine(serving=serving,
                            finetune=FinetuneEngine(cfg, copied))

    def test_train_only_and_serve_only(self, key):
        cfg, acfg, scfg, base, bank = self._system(key)
        with pytest.raises(ValueError):
            SymbiosisEngine()
        ft = FinetuneEngine(cfg, base)
        sym = SymbiosisEngine(finetune=ft)
        job = _job(cfg, "lora", seed=0, steps=2)
        sym.submit(job)
        done_r, done_j = sym.run()
        assert done_r == [] and len(done_j) == 1
        with pytest.raises(ValueError, match="no serving engine"):
            sym.submit(self._requests(cfg)[0])
