"""Pallas kernels vs pure-jnp oracles (interpret mode), hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property sweeps are optional-dep gated
from hypothesis import given, settings, strategies as st

from repro.kernels import (sgmv, sgmv_ref, ragged_linear, ragged_linear_ref,
                           decode_attn, decode_attn_ref,
                           flash_attn, flash_attn_ref)

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.bfloat16 else \
           {"rtol": 2e-4, "atol": 2e-4}


class TestSGMV:
    @given(
        nb=st.integers(1, 4),
        din=st.sampled_from([32, 64, 100]),
        r=st.sampled_from([4, 8, 16]),
        dout=st.sampled_from([48, 128, 200]),
        n_adapters=st.integers(1, 4),
        dt=st.sampled_from(DTYPES),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, nb, din, r, dout, n_adapters, dt, seed):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        T = nb * 128
        x = jax.random.normal(ks[0], (T, din), jnp.float32).astype(dt)
        A = (jax.random.normal(ks[1], (n_adapters, din, r), jnp.float32) * 0.3).astype(dt)
        B = (jax.random.normal(ks[2], (n_adapters, r, dout), jnp.float32) * 0.3).astype(dt)
        ids = jax.random.randint(ks[3], (nb,), -1, n_adapters).astype(jnp.int32)
        y = sgmv(x, A, B, ids, scale=0.5)
        yr = sgmv_ref(x, A, B, ids, block_t=128, scale=0.5)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dt))

    def test_dead_blocks_zero(self):
        x = jnp.ones((256, 32))
        A = jnp.ones((2, 32, 4))
        B = jnp.ones((2, 4, 16))
        y = sgmv(x, A, B, jnp.array([-1, 0], jnp.int32))
        assert float(jnp.abs(y[:128]).max()) == 0.0
        assert float(jnp.abs(y[128:]).max()) > 0.0


class TestRaggedLinear:
    @given(
        budget=st.sampled_from([64, 200, 512]),
        din=st.sampled_from([32, 100, 256]),
        dout=st.sampled_from([16, 130, 384]),
        bias=st.booleans(),
        dt=st.sampled_from(DTYPES),
        live_frac=st.floats(0.0, 1.0),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, budget, din, dout, bias, dt, live_frac):
        key = jax.random.PRNGKey(0)
        buf = jax.random.normal(key, (budget, din), jnp.float32).astype(dt)
        w = (jax.random.normal(jax.random.PRNGKey(1), (din, dout), jnp.float32)
             * 0.1).astype(dt)
        b = (jax.random.normal(jax.random.PRNGKey(2), (dout,), jnp.float32)
             .astype(dt) if bias else None)
        n_live = int(budget * live_frac)
        y = ragged_linear(buf, w, b, n_live)
        yr = ragged_linear_ref(buf, w, b, n_live)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dt))


class TestDecodeAttn:
    @given(
        B=st.integers(1, 3),
        K=st.sampled_from([1, 2, 4]),
        G=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([32, 64]),
        T=st.sampled_from([128, 300, 1024]),
        window=st.sampled_from([0, 64]),
        dt=st.sampled_from(DTYPES),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, B, K, G, hd, T, window, dt, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (B, K, G, hd), jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (B, T, K, hd), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (B, T, K, hd), jnp.float32).astype(dt)
        pos = jax.random.randint(ks[3], (B,), 0, T)
        y = decode_attn(q, k, v, pos, window=window, block_kv=128)
        yr = decode_attn_ref(q, k, v, pos, window=window)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dt))

    def test_pos_zero_single_entry(self):
        """Numerical edge: only one valid cache entry."""
        q = jnp.ones((1, 1, 2, 32))
        k = jnp.ones((1, 256, 1, 32))
        v = jnp.full((1, 256, 1, 32), 2.0)
        y = decode_attn(q, k, v, jnp.zeros((1,), jnp.int32))
        np.testing.assert_allclose(np.asarray(y), 2.0, rtol=1e-5)

    def test_block_table_matches_dense(self):
        """Paged layout: pools + block tables reproduce the dense result —
        the reference contract behind serving's paged KV slots."""
        B, T, K, G, hd, blk = 2, 256, 2, 2, 32, 64
        n_blocks = T // blk
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (B, K, G, hd))
        k = jax.random.normal(ks[1], (B, T, K, hd))
        v = jax.random.normal(ks[2], (B, T, K, hd))
        pos = jnp.array([100, 255], jnp.int32)
        # scatter the dense rows into a shuffled pool
        perm = jax.random.permutation(ks[3], B * n_blocks)
        tbl = perm.reshape(B, n_blocks).astype(jnp.int32)
        pool_k = jnp.zeros((B * n_blocks, blk, K, hd))
        pool_v = jnp.zeros((B * n_blocks, blk, K, hd))
        kb = k.reshape(B * n_blocks, blk, K, hd)
        vb = v.reshape(B * n_blocks, blk, K, hd)
        pool_k = pool_k.at[perm].set(kb)
        pool_v = pool_v.at[perm].set(vb)
        y_ref = decode_attn_ref(q, k, v, pos)
        y_paged_ref = decode_attn_ref(q, pool_k, pool_v, pos, block_tbl=tbl)
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_paged_ref))
        y_kernel = decode_attn(q, pool_k, pool_v, pos, block_tbl=tbl,
                               block_kv=64)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


class TestFlashAttn:
    @given(
        B=st.integers(1, 2),
        S=st.sampled_from([128, 300, 512]),
        K=st.sampled_from([1, 2, 4]),
        G=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([32, 64]),
        window=st.sampled_from([0, 64]),
        dt=st.sampled_from(DTYPES),
        seed=st.integers(0, 30),
    )
    @settings(max_examples=20, deadline=None)
    def test_causal_matches_ref(self, B, S, K, G, hd, window, dt, seed):
        H = K * G
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32).astype(dt)
        y = flash_attn(q, k, v, window=window, block_q=128, block_kv=128)
        yr = flash_attn_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dt))

    def test_noncausal_cross(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 4, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 32))
        y = flash_attn(q, k, v, causal=False, block_q=128, block_kv=128)
        yr = flash_attn_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)
