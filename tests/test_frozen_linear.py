"""Memory-optimized frozen backward (paper §3.6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frozen_linear import frozen_dense, frozen_expert


def _plain(x, w, b=None):
    y = x @ w
    return y + b if b is not None else y


class TestFrozenDense:
    def test_forward_matches(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
        b = jax.random.normal(jax.random.PRNGKey(2), (24,))
        np.testing.assert_allclose(frozen_dense(x, w), _plain(x, w), rtol=1e-6)
        np.testing.assert_allclose(frozen_dense(x, w, b), _plain(x, w, b), rtol=1e-6)

    def test_dx_matches_autodiff(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
        g = lambda f: jax.grad(lambda x_: f(x_, w).sum())(x)
        np.testing.assert_allclose(g(frozen_dense), g(_plain), rtol=1e-5)

    def test_dw_is_zero(self, key):
        """The base weight is frozen: its cotangent is structurally zero
        (paper: no parameter update at the base executor)."""
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
        dw = jax.grad(lambda w_: frozen_dense(x, w_).sum())(w)
        assert float(jnp.abs(dw).max()) == 0.0

    def test_no_activation_residuals(self, key):
        """§3.6's memory claim, structurally: the VJP closure must not
        capture any tensor shaped like the activations — only the weight."""
        x = jax.random.normal(key, (32, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
        _, vjp = jax.vjp(lambda x_: frozen_dense(x_, w), x)
        leaves = jax.tree.leaves(vjp)
        act_shaped = [l for l in leaves if hasattr(l, "shape")
                      and l.shape[:1] == (32,)]
        assert not act_shaped, f"residuals hold activations: {[l.shape for l in act_shaped]}"

    def test_grad_through_composition(self, key):
        """dx flows through a chain of frozen layers + nonlinearity."""
        x = jax.random.normal(key, (4, 16))
        w1 = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
        w2 = jax.random.normal(jax.random.PRNGKey(2), (16, 16))

        def f(fn, x):
            return fn(jax.nn.gelu(fn(x, w1)), w2).sum()

        np.testing.assert_allclose(
            jax.grad(lambda x_: f(frozen_dense, x_))(x),
            jax.grad(lambda x_: f(_plain, x_))(x), rtol=1e-5)


class TestFrozenExpert:
    def test_forward_and_grad(self, key):
        x = jax.random.normal(key, (3, 8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 24))
        ref = jnp.einsum("eci,eio->eco", x, w)
        np.testing.assert_allclose(frozen_expert(x, w), ref, rtol=1e-5)
        dx = jax.grad(lambda x_: frozen_expert(x_, w).sum())(x)
        dx_ref = jax.grad(lambda x_: jnp.einsum("eci,eio->eco", x_, w).sum())(x)
        np.testing.assert_allclose(dx, dx_ref, rtol=1e-5)
        dw = jax.grad(lambda w_: frozen_expert(x, w_).sum())(w)
        assert float(jnp.abs(dw).max()) == 0.0
