"""Sharding plans + launch specs (1-device mesh; full meshes live in dryrun)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import SHAPES
from repro.configs import ASSIGNED, get_config
from repro.core import symbiosis
from repro.launch import shardings, specs
from repro.launch.mesh import make_host_mesh, batch_size, model_size
from repro.launch.specs import DEFAULT_ADAPTER, is_applicable


class TestMesh:
    def test_host_mesh_axes(self):
        mesh = make_host_mesh()
        assert set(mesh.axis_names) == {"data", "model"}
        assert batch_size(mesh) == 1 and model_size(mesh) == 1


class TestSpecRules:
    def test_base_specs_cover_tree(self):
        mesh = make_host_mesh()
        for arch in ("granite-3-8b", "deepseek-moe-16b", "rwkv6-7b",
                     "jamba-v0.1-52b", "whisper-small"):
            cfg = get_config(arch)
            shape = jax.eval_shape(
                lambda: symbiosis.init_system(cfg, DEFAULT_ADAPTER, 2,
                                              jax.random.PRNGKey(0)))
            spec = shardings.base_param_specs(cfg, mesh, shape[0])
            leaves = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))
            assert len(leaves) == len(jax.tree.leaves(shape[0]))

    def test_divisibility_fallback(self):
        """Granite's odd vocab (49155) must not be model-sharded on the
        vocab axis; the lm_head falls back to row-parallel."""
        import types
        mesh16 = types.SimpleNamespace(  # stand-in: only sizes matter
            axis_names=("data", "model"),
            shape={"data": 16, "model": 16})
        cfg = get_config("granite-3-8b")
        shape = jax.eval_shape(
            lambda: symbiosis.init_system(cfg, DEFAULT_ADAPTER, 2,
                                          jax.random.PRNGKey(0)))
        spec = shardings.base_param_specs(cfg, mesh16, shape[0])
        lm = spec["lm_head"]
        # d_model sharded, vocab replicated (canonical form: trailing
        # replicated entries are trimmed to match XLA's output shardings)
        assert lm == P("model")

    def test_kv_cache_t_axis_sharded(self):
        import types
        mesh16 = types.SimpleNamespace(axis_names=("data", "model"),
                                       shape={"data": 16, "model": 16})
        cfg = get_config("granite-3-8b")
        cache = jax.eval_shape(
            lambda: symbiosis.init_client_caches(cfg, 16, 2, 32768))
        spec = shardings.client_state_specs(cfg, mesh16, cache)
        k_spec = spec["layers"]["k"]
        assert k_spec[0] == "data" and k_spec[3] == "model"


class TestInputSpecs:
    def test_all_applicable_pairs_build(self):
        """Every (arch x shape) either builds a spec bundle on the host mesh
        or is a documented skip — no exceptions."""
        mesh = make_host_mesh()
        built = skipped = 0
        for arch in ASSIGNED:
            for shape in SHAPES:
                ok, note = is_applicable(arch, shape)
                if not ok:
                    skipped += 1
                    continue
                b = specs.input_specs(arch, shape, mesh)
                assert b.n_clients * b.batch_per_client == SHAPES[shape].global_batch
                assert callable(b.fn)
                for leaf in jax.tree.leaves(b.args):
                    assert hasattr(leaf, "shape")
                built += 1
        assert built == 33 and skipped == 7   # 3 long_500k run, 7 skip

    def test_spec_is_allocation_free(self):
        mesh = make_host_mesh()
        b = specs.input_specs("qwen3-4b", "decode_32k", mesh)
        for leaf in jax.tree.leaves(b.args):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_host_mesh_lowers_tiny(self):
        """End-to-end lower+compile on the 1-device mesh with a reduced
        config (the real meshes are exercised by repro.launch.dryrun)."""
        mesh = make_host_mesh()
        cfg = get_config("qwen3-4b").reduced()
        from repro.config import TrainConfig
        tcfg = TrainConfig(n_clients=2, remat=True)
        fn = symbiosis.make_multi_client_train_step(cfg, DEFAULT_ADAPTER, tcfg)
        sys_shape = jax.eval_shape(
            lambda: symbiosis.init_system(cfg, DEFAULT_ADAPTER, 2,
                                          jax.random.PRNGKey(0)))
        base = shardings.attach(mesh, sys_shape[0],
                                shardings.base_param_specs(cfg, mesh, sys_shape[0]))
        bank = shardings.attach(mesh, sys_shape[1],
                                shardings.client_state_specs(cfg, mesh, sys_shape[1]))
        opt = shardings.attach(mesh, sys_shape[2],
                               shardings.client_state_specs(cfg, mesh, sys_shape[2]))
        batch = {"tokens": jax.ShapeDtypeStruct((2, 2, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 2, 32), jnp.int32)}
        compiled = jax.jit(fn).lower(base, bank, opt, batch, 0).compile()
        assert compiled.cost_analysis() is not None


class TestHloAnalysis:
    def test_collective_parser_on_synthetic(self):
        from repro.launch.hlo_analysis import collective_bytes
        hlo = """
HloModule m

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%p), replica_groups={}
}
"""
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 32
        assert out["total"] == 32

    def test_loop_multiplication(self):
        from repro.launch.hlo_analysis import collective_bytes
        hlo = """
HloModule m

%cond (t: (s32[], f32[4])) -> pred[] {
  %t = (s32[], f32[4]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%body (t: (s32[], f32[4])) -> (s32[], f32[4]) {
  %t = (s32[], f32[4]{0}) parameter(0)
  %x = f32[4]{0} get-tuple-element(%t), index=1
  %ag = f32[4]{0} all-reduce(%x), replica_groups={}
  %i = s32[] get-tuple-element(%t), index=0
  ROOT %r = (s32[], f32[4]{0}) tuple(%i, %ag)
}

ENTRY %main (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]{0}) parameter(0)
  ROOT %w = (s32[], f32[4]{0}) while(%p), condition=%cond, body=%body
}
"""
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 16 * 10, out
