"""Placement router (§3.4 provider-side decisions)."""
import pytest

from repro.configs import get_config
from repro.serving.router import PlacementRouter, Slot


@pytest.fixture
def router():
    cfg = get_config("symbiosis-llama2-13b")
    return PlacementRouter(cfg, [Slot(0, free_hbm=10e9), Slot(1, free_hbm=10e9)])


class TestRouting:
    def test_short_context_goes_gpu(self, router):
        p = router.route(context_len=2_000)
        assert p.mode == "gpu" and p.slot_id is not None

    def test_long_context_goes_hetero(self, router):
        p = router.route(context_len=262_144)
        assert p.mode == "hetero" and p.slot_id is None

    def test_mid_context_offloads(self, router):
        # 32k cache ~26 GB: too big for a 10 GB slot, too fast for CPU-only?
        p = router.route(context_len=32_768, latency_sensitive=False)
        assert p.mode in ("gpu_offload", "hetero")

    def test_hbm_accounting(self, router):
        p1 = router.route(context_len=4_000)
        assert p1.mode == "gpu"
        free_after = router.slots[p1.slot_id].free_hbm
        assert free_after < 10e9
        router.release(p1)
        assert router.slots[p1.slot_id].free_hbm == pytest.approx(10e9)

    def test_fleet_fills_then_spills(self, router):
        placements = [router.route(context_len=8_000) for _ in range(4)]
        modes = [p.mode for p in placements]
        assert modes[0] == "gpu"
        # eventually the 10 GB slots fill (8k cache ~6.5 GB each) and
        # requests spill to offload/CPU
        assert any(m != "gpu" for m in modes)

    def test_batch_not_double_counted(self, router):
        """Regression: route() used to check fits(need * batch) while
        cache_bytes(…, batch) already includes the batch factor, then
        commit() deducted only `need` — over-rejecting by batch× and
        desynchronizing the accounting."""
        cfg = get_config("symbiosis-llama2-13b")
        from repro.serving.kvcache import cache_bytes
        need = cache_bytes(cfg, 4_000, batch=4)
        # a slot that fits the true batch-4 footprint but not 4x it
        r = PlacementRouter(cfg, [Slot(0, free_hbm=need * 1.5)],
                            host_free_bytes=0)
        p = r.route(context_len=4_000, batch=4)
        assert p.mode == "gpu" and p.cache_bytes == need

    def test_commit_release_round_trip(self, router):
        """commit() and release() must be exact inverses across all modes."""
        snapshot = ({sid: s.free_hbm for sid, s in router.slots.items()},
                    router.host_free)
        placements = [router.route(context_len=cl, batch=b,
                                   latency_sensitive=ls)
                      for cl, b, ls in [(2_000, 1, True), (4_000, 4, True),
                                        (32_768, 2, False), (262_144, 1, False)]]
        assert {p.mode for p in placements} >= {"gpu", "hetero"}
        for p in placements:
            router.release(p)
        assert router.host_free == pytest.approx(snapshot[1])
        for sid, s in router.slots.items():
            assert s.free_hbm == pytest.approx(snapshot[0][sid])

    def test_oom_raises(self):
        cfg = get_config("symbiosis-llama2-13b")
        r = PlacementRouter(cfg, [Slot(0, free_hbm=1e9)], host_free_bytes=1e9)
        with pytest.raises(RuntimeError):
            r.route(context_len=500_000)
