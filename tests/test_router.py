"""Placement router (§3.4 provider-side decisions)."""
import pytest

from repro.configs import get_config
from repro.serving.router import PlacementRouter, Slot


@pytest.fixture
def router():
    cfg = get_config("symbiosis-llama2-13b")
    return PlacementRouter(cfg, [Slot(0, free_hbm=10e9), Slot(1, free_hbm=10e9)])


class TestRouting:
    def test_short_context_goes_gpu(self, router):
        p = router.route(context_len=2_000)
        assert p.mode == "gpu" and p.slot_id is not None

    def test_long_context_goes_hetero(self, router):
        p = router.route(context_len=262_144)
        assert p.mode == "hetero" and p.slot_id is None

    def test_mid_context_offloads(self, router):
        # 32k cache ~26 GB: too big for a 10 GB slot, too fast for CPU-only?
        p = router.route(context_len=32_768, latency_sensitive=False)
        assert p.mode in ("gpu_offload", "hetero")

    def test_hbm_accounting(self, router):
        p1 = router.route(context_len=4_000)
        assert p1.mode == "gpu"
        free_after = router.slots[p1.slot_id].free_hbm
        assert free_after < 10e9
        router.release(p1)
        assert router.slots[p1.slot_id].free_hbm == pytest.approx(10e9)

    def test_fleet_fills_then_spills(self, router):
        placements = [router.route(context_len=8_000) for _ in range(4)]
        modes = [p.mode for p in placements]
        assert modes[0] == "gpu"
        # eventually the 10 GB slots fill (8k cache ~6.5 GB each) and
        # requests spill to offload/CPU
        assert any(m != "gpu" for m in modes)

    def test_oom_raises(self):
        cfg = get_config("symbiosis-llama2-13b")
        r = PlacementRouter(cfg, [Slot(0, free_hbm=1e9)], host_free_bytes=1e9)
        with pytest.raises(RuntimeError):
            r.route(context_len=500_000)
