"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps with 4 clients sharing the base, checkpoint, restore, serve.

  PYTHONPATH=src python examples/finetune_e2e.py [--steps 200]

~100M params: 4 layers x d_model 768 + vocab 49k embeddings (granite
family). Takes a few minutes on CPU; loss per client drops markedly.
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig, TrainConfig, ServeConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.common.tree import tree_count
from repro.data import make_client_batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("granite-3-8b").reduced(n_layers=4, d_model=768,
                                             vocab=8192)
    acfg = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))
    tcfg = TrainConfig(n_clients=args.clients, lr=3e-3,
                       total_steps=args.steps, warmup_steps=20)

    key = jax.random.PRNGKey(0)
    base, bank, opt = symbiosis.init_system(cfg, acfg, args.clients, key)
    n_base = tree_count(base)
    n_adapter = tree_count(jax.tree.map(lambda x: x[0], bank))
    print(f"base: {n_base/1e6:.1f}M params (frozen, shared); "
          f"adapter: {n_adapter/1e3:.0f}K params/client "
          f"({100*n_adapter/n_base:.2f}% of base)")

    step_fn = jax.jit(symbiosis.make_multi_client_train_step(cfg, acfg, tcfg),
                      donate_argnums=(1, 2))
    stream = make_client_batches(cfg, args.clients, 4, args.seq)

    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        bank, opt, m = step_fn(base, bank, opt, stream.batch(step), step)
        loss = np.asarray(m["loss"])
        if step == 0:
            first = loss.copy()
        last = loss
        if step % 25 == 0 or step == args.steps - 1:
            tok_s = args.clients * 4 * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss/client={np.round(loss, 3)} "
                  f"({tok_s:,.0f} tok/s)")
    drop = 100 * (first - last) / first
    print(f"loss drop per client: {np.round(drop, 1)}%")
    assert (last < first).all(), "training must reduce loss for every client"

    # checkpoint the client bank (base saved separately, once — the
    # as-a-service split) and restore into a fresh serving session
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, args.steps, base, name="base")
        save_checkpoint(d, args.steps, bank, name="bank")
        sizes = {n: sum(os.path.getsize(os.path.join(r, f))
                        for r, _, fs in os.walk(os.path.join(
                            d, f"step_{args.steps:08d}", n)) for f in fs)
                 for n in ("base", "bank")}
        print(f"checkpoints: base {sizes['base']/1e6:.1f}MB (shared), "
              f"bank {sizes['bank']/1e6:.1f}MB ({args.clients} clients)")
        bank2 = restore_checkpoint(d, args.steps, bank, name="bank")

    scfg = ServeConfig(n_clients=args.clients, max_seq=64)
    caches = symbiosis.init_client_caches(cfg, args.clients, 2, 64)
    prefill = jax.jit(symbiosis.make_multi_client_prefill(cfg, acfg, scfg))
    logits, _ = prefill(base, bank2, caches,
                        {"tokens": jnp.ones((args.clients, 2, 16), jnp.int32)})
    assert np.isfinite(np.asarray(logits)).all()
    print("restored bank serves correctly — e2e OK")


if __name__ == "__main__":
    main()
