"""Mixed serving + fine-tuning on one base (paper §4.4, Fig 22/23).

6 inference clients decode continuously while 2 fine-tuning clients train,
all against the same resident frozen base — the provider time-multiplexes
one model instance instead of deploying eight.

  PYTHONPATH=src python examples/mixed_inference_finetune.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig, ServeConfig, TrainConfig
from repro.configs import get_config
from repro.core import symbiosis
from repro.data import make_client_batches

cfg = get_config("jamba-v0.1-52b").reduced(n_layers=4, d_model=256)
print(f"model: {cfg.name} (hybrid mamba+attn, MoE) reduced to "
      f"{cfg.n_layers}L d={cfg.d_model} E={cfg.n_experts}")

N_INF, N_FT, B = 6, 2, 2
acfg = AdapterConfig(method="lora", rank=8, targets=("q", "v"))
tcfg = TrainConfig(n_clients=N_FT, lr=3e-3)
scfg = ServeConfig(n_clients=N_INF, max_seq=64)

key = jax.random.PRNGKey(0)
base, ft_bank, ft_opt = symbiosis.init_system(cfg, acfg, N_FT, key)
_, inf_bank, _ = symbiosis.init_system(cfg, acfg, N_INF, jax.random.PRNGKey(1))
caches = symbiosis.init_client_caches(cfg, N_INF, B, 64)

mixed = jax.jit(symbiosis.make_mixed_step(cfg, acfg, tcfg, scfg))
stream = make_client_batches(cfg, N_FT, B, 64)

tok = jnp.ones((N_INF, B), jnp.int32)
t0 = time.time()
losses = []
for step in range(10):
    ft_bank, ft_opt, caches, logits, metrics = mixed(
        base, ft_bank, ft_opt, stream.batch(step), inf_bank, caches, tok, step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    losses.append(float(np.asarray(metrics["loss"]).mean()))
dt = time.time() - t0

inf_tokens = 10 * N_INF * B
ft_tokens = 10 * N_FT * B * 64
print(f"10 mixed steps in {dt:.1f}s: {inf_tokens} inference tokens decoded, "
      f"{ft_tokens} fine-tuning tokens trained "
      f"({(inf_tokens + ft_tokens) / dt:,.0f} tok/s combined)")
print(f"fine-tuning loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
print(f"decode positions advanced to {int(np.asarray(caches['pos']).max())}")
print("mixed workload OK")
