"""Mixed serving + fine-tuning on one base (paper §4.4, Fig 22/23) — the
full service shape, driven by the SymbiosisEngine.

Six inference requests stream through a continuous-batching ServingEngine
while three fine-tuning jobs — LoRA, IA3 and prefix, i.e. THREE different
PEFT methods in three banks — train through a FinetuneEngine, all against
the SAME resident frozen base: the provider time-multiplexes one model
instance instead of deploying one per workload. Interleaving decode ticks
with train steps changes when work runs, never its math (each job still
matches its dedicated run bit-for-bit; see tests/test_finetune_engine.py).

  PYTHONPATH=src python examples/mixed_inference_finetune.py
  PYTHONPATH=src python examples/mixed_inference_finetune.py --serve-mixed

``--serve-mixed`` additionally makes the SERVING side heterogeneous
(ISSUE 5): the inference clients become one LoRA + one IA3 + one prefix
bank inside a single paged ServingEngine, every decode tick carrying all
three methods — so BOTH halves of the service mix PEFT methods over the
one resident base.
"""
import argparse
import time

import jax
import numpy as np

from repro.config import AdapterConfig, FinetuneConfig, ServeConfig
from repro.configs import get_config
from repro.core import adapters as ad_lib
from repro.core import symbiosis
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.serving.engine import Request, ServingEngine
from repro.training import (FinetuneEngine, FinetuneJob, SymbiosisEngine,
                            make_job_stream)

ap = argparse.ArgumentParser()
ap.add_argument("--serve-mixed", action="store_true",
                help="serve LoRA + IA3 + prefix inference banks through one "
                     "mixed-method engine (paged layout, compacted decode)")
args = ap.parse_args()

cfg = get_config("jamba-v0.1-52b").reduced(n_layers=4, d_model=256)
print(f"model: {cfg.name} (hybrid mamba+attn, MoE) reduced to "
      f"{cfg.n_layers}L d={cfg.d_model} E={cfg.n_experts}")

N_INF, B, SEQ = 3, 2, 48
acfg_inf = AdapterConfig(method="lora", rank=8, targets=("q", "v"))

key = jax.random.PRNGKey(0)
if args.serve_mixed:
    # three single-client banks, three PEFT methods, ONE serving engine —
    # mixed banks ride the compacted decode, which needs the paged layout
    scfg = ServeConfig(n_clients=N_INF, max_seq=64, page_block=8)
    from repro.models import get_model
    base = get_model(cfg).init_params(key)
    serve_cfgs = [acfg_inf,
                  AdapterConfig(method="ia3", targets=("k", "v", "down")),
                  AdapterConfig(method="prefix", targets=("q", "v"),
                                n_prefix=8)]
    inf_banks = [ad_lib.init_client_bank(cfg, a, 1, jax.random.PRNGKey(5 + i))
                 for i, a in enumerate(serve_cfgs)]
    spec = EngineSpec(cfg=cfg, serve=scfg, max_batch_per_client=B,
                      banks=tuple(BankSpec(a.method, a, capacity=1)
                                  for a in serve_cfgs))
    serving = ServingEngine(spec, base, inf_banks)
    print("serving: MIXED banks (lora + ia3 + prefix) in one engine")
else:
    scfg = ServeConfig(n_clients=N_INF, max_seq=64)
    base, inf_bank, _ = symbiosis.init_system(cfg, acfg_inf, N_INF, key)
    spec = EngineSpec(cfg=cfg, serve=scfg, max_batch_per_client=B,
                      banks=(BankSpec("tenants", acfg_inf, capacity=N_INF),))
    serving = ServingEngine(spec, base, [inf_bank])
finetune = FinetuneEngine(EngineSpec(cfg=cfg,
                                     finetune=FinetuneConfig(max_jobs=4)),
                          base)
engine = SymbiosisEngine(serving=serving, finetune=finetune)

# three PEFT METHODS fine-tuning concurrently -> three banks, one base
jobs = []
for i, (method, targets) in enumerate([("lora", ("q", "v")),
                                       ("ia3", ("k", "v", "down")),
                                       ("prefix", ("q", "v"))]):
    jobs.append(FinetuneJob(
        acfg=AdapterConfig(method=method, rank=8, targets=targets),
        data=make_job_stream(cfg, B, SEQ, seed=i), batch_size=B, seq_len=SEQ,
        steps=10, lr=3e-3, warmup_steps=1, seed=i, name=method))
    engine.submit(jobs[-1])

rng = np.random.default_rng(0)
for i in range(6):
    engine.submit(Request(client_id=i % N_INF,
                          prompt=rng.integers(0, cfg.vocab, (1, 8)).astype(np.int32),
                          max_new_tokens=10, arrive_tick=i))

t0 = time.time()
done_requests, done_jobs = engine.run()
dt = time.time() - t0

inf_tokens = sum(r.generated.size for r in done_requests)
ft_tokens = finetune.stats["train_tokens"]
print(f"service drained in {dt:.1f}s: {len(done_requests)} requests "
      f"({inf_tokens} tokens decoded) + {len(done_jobs)} fine-tuning jobs "
      f"({ft_tokens} tokens trained) = {(inf_tokens + ft_tokens) / dt:,.0f} tok/s combined")
print(f"interleaving: {engine.stats['decode_ticks']} decode ticks / "
      f"{engine.stats['train_ticks']} train ticks, "
      f"{len(finetune._banks)} adapter banks (lora+ia3+prefix) on ONE base")
for j in done_jobs:
    print(f"  job {j.name:6s}: loss {j.result.losses[0]:.3f} -> "
          f"{j.result.losses[-1]:.3f} over {j.result.step} steps")
print("mixed workload OK")
