"""Privacy-preserving multi-tenancy (paper §3.8 / Fig 21).

A tenant fine-tunes an adapter on "confidential" data, then serves through
an UNTRUSTED base executor: every activation shipped to a frozen base layer
carries additive noise; the pre-computed noise effect is subtracted from
the output. The demo shows (a) what the executor observes is decorrelated
from the true activations, (b) the final outputs are exactly those of the
non-private run.

  PYTHONPATH=src python examples/multi_tenant_private_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig
from repro.configs import get_config
from repro.core import adapters as ad_lib, privacy
from repro.core.virtlayer import make_client_ctx, attach_privacy
from repro.models import get_model

cfg = get_config("granite-3-8b").reduced(n_layers=2, d_model=256)
model = get_model(cfg)
acfg = AdapterConfig(method="lora", rank=8, targets=("q", "v"))

key = jax.random.PRNGKey(0)
base = model.init_params(key)                       # provider-side
adapter = ad_lib.init_adapter(cfg, acfg, jax.random.PRNGKey(1))  # tenant-side
adapter = jax.tree.map(lambda x: x + 0.03, adapter)  # "fine-tuned"

# Tenant generates a secret noise bank (2 variants, rotated across layers/
# iterations) and asks the executor's BIAS-FREE flow for the noise effects.
dims = {p: d for p, d in ad_lib.resolve_targets(cfg, acfg)}
noise = privacy.make_noise(jax.random.PRNGKey(42), dims, n_variants=2, scale=3.0)
adapter_priv = attach_privacy(adapter, cfg, base, noise)

ctx_plain = make_client_ctx(cfg, acfg)
ctx_priv = make_client_ctx(cfg, acfg, privacy_noise=noise, privacy_variant=1)

batch = {"tokens": jax.random.randint(key, (2, 24), 0, cfg.vocab)}
y_plain, _ = model.forward(base, batch, ctx_plain, adapter)
y_priv, _ = model.forward(base, batch, ctx_priv, adapter_priv)

err = float(jnp.abs(y_plain - y_priv).max())
print(f"max |logit difference| private vs plain: {err:.2e}  (exactness, Fig 21)")
assert err < 1e-2

# What does the executor see? x+n instead of x:
x = jax.random.normal(key, (4, cfg.d_model))
n = privacy.select_variant(noise, "q", 1)
seen = x + n
corr = np.corrcoef(np.asarray(x).ravel(), np.asarray(seen).ravel())[0, 1]
print(f"correlation(executor-observed, true activations) = {corr:.3f} "
      f"(noise scale {float(jnp.std(n)):.1f} vs activation scale "
      f"{float(jnp.std(x)):.1f})")

# Fig 8's attack: with LoRA, (C - B)/A leaks Wa.Wb — under noise the
# executor's observed input is x+n, so the recovered 'adapter effect' is
# polluted by n's projection, and variant rotation prevents averaging it out.
print("privacy demo OK")

# ---------------------------------------------------------------------------
# Multi-tenant continuous-batching service (§3.7): three tenants' adapters in
# one bank, requests arriving staggered; the engine opportunistically batches
# whoever is ready each tick. The exactness contract extends to the serving
# layer: every tenant's stream is byte-identical to being served alone.
# ---------------------------------------------------------------------------
from repro.config import ServeConfig
from repro.core import symbiosis
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.serving.engine import ServingEngine, Request

n_tenants = 3
# KV-layout knobs (see ServeConfig / serving/kvcache.py): page_block > 0
# pages the KV cache — each tenant holds 16-token pages only for tokens it
# has actually produced, so admission charges pages instead of full
# max_seq-deep rows (≥1.5x more tenants at a fixed HBM budget in
# bench_multiclient). Add kv_quant=True for int8 KV entries (≈0.5x cache
# bytes; int8-tolerance drift instead of exactness).
#
# Occupancy knob: with paging the engine defaults to the COMPACTED decode
# tick — each tick runs only the tenants' actively decoding slots (gathered
# across tenants into one dense batch; per-tenant LoRA applied row-wise via
# the SGMV kernel), so a mostly-idle bank decodes at the cost of its live
# requests, not its provisioned slots (≥2x decode tok/s at ≤25% occupancy
# in bench_multiclient). Pass compact_decode=False to ServingEngine to see
# the masked bank-wide ablation — outputs are byte-identical either way.
scfg = ServeConfig(n_clients=n_tenants, max_seq=64, page_block=16)
_, bank, _ = symbiosis.init_system(cfg, acfg, n_tenants, jax.random.PRNGKey(7))

rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab, (1, 8 + 4 * t)).astype(np.int32)
           for t in range(n_tenants)]

spec = EngineSpec(cfg=cfg, serve=scfg, max_batch_per_client=2,
                  banks=(BankSpec("tenants", acfg, capacity=n_tenants),))
eng = ServingEngine(spec, base, [bank])
for t in range(n_tenants):
    eng.submit(Request(client_id=t, prompt=prompts[t], max_new_tokens=8,
                       arrive_tick=3 * t))     # tenants join mid-stream
served = {r.client_id: r.generated for r in eng.run()}

for t in range(n_tenants):
    solo_eng = ServingEngine(spec, base, [bank])
    solo_eng.submit(Request(client_id=t, prompt=prompts[t], max_new_tokens=8))
    (solo,) = solo_eng.run()
    assert np.array_equal(served[t], solo.generated), f"tenant {t} diverged"

print(f"continuous-batching service OK: {n_tenants} tenants, "
      f"stats={eng.stats} — outputs byte-identical to solo serving")
