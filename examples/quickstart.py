"""Quickstart: share one frozen base across 3 fine-tuning clients with
different PEFT methods, train them simultaneously, then serve one of them.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig, TrainConfig, ServeConfig
from repro.configs import get_config
from repro.core import symbiosis, adapters as ad_lib
from repro.data import make_client_batches
from repro.optim import adamw_init

# 1. Pick an assigned architecture, reduced so it runs on CPU. On TPU you'd
#    use the full config + repro.launch.mesh.make_production_mesh().
cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=256)
print(f"model: {cfg.name} ({cfg.arch}), {cfg.n_layers}L d={cfg.d_model}")

# 2. One frozen base, one bank of LoRA clients (each client trains its own
#    adapter; base parameters are shared and never updated).
acfg = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))
tcfg = TrainConfig(n_clients=3, lr=5e-3, total_steps=30)
base, bank, opt = symbiosis.init_system(cfg, acfg, 3, jax.random.PRNGKey(0))

train_step = jax.jit(symbiosis.make_multi_client_train_step(cfg, acfg, tcfg))
stream = make_client_batches(cfg, n_clients=3, batch_per_client=4, seq_len=64)

print("fine-tuning 3 clients against the shared base:")
for step in range(30):
    bank, opt, metrics = train_step(base, bank, opt, stream.batch(step), step)
    if step % 10 == 0 or step == 29:
        print(f"  step {step:3d} loss/client = "
              f"{np.round(np.asarray(metrics['loss']), 3)}")

# 3. A second bank with a DIFFERENT PEFT method shares the same base.
ia3 = AdapterConfig(method="ia3", targets=("k", "v", "down"))
ia3_bank = ad_lib.init_client_bank(cfg, ia3, 2, jax.random.PRNGKey(7))
ia3_opt = jax.vmap(adamw_init)(ia3_bank)
ia3_step = jax.jit(symbiosis.make_multi_client_train_step(
    cfg, ia3, TrainConfig(n_clients=2, lr=5e-3)))
ia3_stream = make_client_batches(cfg, 2, 4, 64, seed=9)
for step in range(5):
    ia3_bank, ia3_opt, m = ia3_step(base, ia3_bank, ia3_opt,
                                    ia3_stream.batch(step), step)
print(f"IA3 bank trained against the SAME base, loss = "
      f"{np.round(np.asarray(m['loss']), 3)}")

# 4. Serve: prefill + decode with the fine-tuned adapters.
scfg = ServeConfig(n_clients=3, max_seq=96)
caches = symbiosis.init_client_caches(cfg, 3, 2, 96)
prefill = jax.jit(symbiosis.make_multi_client_prefill(cfg, acfg, scfg))
decode = jax.jit(symbiosis.make_multi_client_decode_step(cfg, acfg, scfg))

prompt = jnp.ones((3, 2, 16), jnp.int32)
logits, caches = prefill(base, bank, caches, {"tokens": prompt})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
generated = [tok]
for _ in range(8):
    logits, caches = decode(base, bank, caches, tok)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated.append(tok)
out = jnp.stack(generated, axis=-1)
print(f"generated tokens per client (batch row 0): \n{np.asarray(out[:, 0])}")
print("quickstart OK")
