"""Configuration system: model / adapter / train / serve / shape configs.

Every assigned architecture gets a ``ModelConfig`` in ``repro.configs.<id>``.
Reduced variants for CPU smoke tests come from ``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Sequence


# Architecture families.
DENSE = "dense"
MOE = "moe"
RWKV = "rwkv"      # attention-free SSM-style (RWKV6)
HYBRID = "hybrid"  # Jamba: mamba + attention interleave + MoE
ENCDEC = "encdec"  # Whisper backbone
VLM = "vlm"        # LLaVA backbone (dense + patch-embedding frontend stub)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    head_pad: int = 0                 # extra ZERO-WEIGHT q-heads so that
                                      # (n_heads+head_pad) divides the TP
                                      # size (exact: padded wo rows are 0)
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0                 # per-expert hidden dim (fine-grained MoE); 0 -> d_ff
    moe_every: int = 1                # MoE FFN on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    first_dense_layers: int = 0       # DeepSeek-MoE: first layer uses a dense FFN
    dense_residual: bool = False      # Arctic: dense FFN in parallel with MoE
    # --- Hybrid (Jamba) / SSM ---
    attn_every: int = 0               # attention on layers where (layer+1) % attn_every == 0
    d_state: int = 16                 # Mamba state dim
    d_conv: int = 4
    mamba_expand: int = 2
    # --- Encoder-decoder (Whisper) ---
    n_enc_layers: int = 0
    n_frontend_tokens: int = 0        # encoder frame tokens (audio) / image patch tokens (vlm)
    # --- Attention variants ---
    sliding_window: int = 0           # 0 -> full attention
    # --- dtypes ---
    dtype: str = "bfloat16"           # activations
    param_dtype: str = "bfloat16"     # frozen base weights
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def hp(self) -> int:
        """Padded q-head count used by the attention implementation."""
        return self.n_heads + self.head_pad

    @property
    def q_per_kv(self) -> int:
        return self.hp // self.n_kv_heads

    @property
    def ffn_hidden(self) -> int:
        return self.d_expert or self.d_ff

    def is_moe_layer(self, layer: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer % self.moe_every == self.moe_offset

    def is_attn_layer(self, layer: int) -> bool:
        """For hybrid archs: which decoder layers use attention (others use Mamba)."""
        if self.arch != HYBRID:
            return True
        return (layer + 1) % self.attn_every == 0

    def reduced(self, n_layers: int = 2, d_model: int = 256, n_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        heads = max(1, min(self.n_heads, d_model // 64))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        changes = {
            "name": self.name + "-smoke",
            "n_layers": n_layers,
            "d_model": d_model,
            "n_heads": heads,
            "n_kv_heads": kv,
            "head_dim": 64 if self.head_dim else 0,
            "d_ff": d_model * 3,
            "vocab": vocab,
            "dtype": "float32",
            "param_dtype": "float32",
        }
        if self.n_experts:
            changes.update(
                n_experts=min(self.n_experts, n_experts),
                top_k=min(self.top_k, 2),
                n_shared_experts=min(self.n_shared_experts, 1),
                d_expert=(d_model // 2) if self.d_expert else 0,
                moe_every=self.moe_every,
                moe_offset=min(self.moe_offset, n_layers - 1),
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.arch == HYBRID:
            changes.update(attn_every=2, n_layers=max(n_layers, 2))
        if self.arch == ENCDEC:
            changes.update(n_enc_layers=n_layers, n_frontend_tokens=16)
        if self.arch == VLM:
            changes.update(n_frontend_tokens=16)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class AdapterConfig:
    """A client's PEFT selection (paper goal 6: multiple PEFT methods)."""
    method: str = "lora"              # lora | ia3 | prefix
    rank: int = 8                     # lora
    alpha: float = 16.0               # lora
    targets: Sequence[str] = ("q", "v")   # subset of q,k,v,o,gate,up,down
    n_prefix: int = 16                # prefix tuning: virtual tokens per layer


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    n_clients: int = 4                # concurrent fine-tuning clients sharing the base
    microbatch: int = 0               # 0 -> no gradient accumulation
    lr: float = 1e-4
    weight_decay: float = 0.0
    warmup_steps: int = 10
    total_steps: int = 100
    max_grad_norm: float = 1.0
    remat: bool = True                # activation checkpointing of the layer body
    memory_optimized_backward: bool = True   # paper §3.6 (Symbiosis-MO); False = torch-like baseline
    seed: int = 0


@dataclass(frozen=True)
class FinetuneConfig:
    """Fine-tuning-as-a-service configuration (``training.FinetuneEngine``).

    Service-level knobs only; per-JOB choices (PEFT method/rank/targets,
    batch size, optimizer schedule, microbatching, step budget) live on
    ``training.FinetuneJob`` — heterogeneity across jobs is the point.

    * ``max_jobs`` — service-wide concurrent-job ceiling across all banks.
      Admission scans the queue in submit order each tick; a job that
      doesn't fit yet stays queued WITHOUT blocking later jobs (the same
      continuous-admission rule as the serving engine — strict FIFO
      head-of-line blocking is deliberately not implemented).
    * ``memory_optimized`` — §3.6 frozen-base backward for every job (the
      Symbiosis-MO path); False emulates the torch-like baseline.
    * ``remat`` — activation checkpointing of the layer body inside every
      job's step.
    """
    max_jobs: int = 16
    memory_optimized: bool = True
    remat: bool = False


@dataclass(frozen=True)
class ServeConfig:
    """Serving-engine configuration.

    KV layout knobs (beyond-paper; see serving/kvcache.py):

    * ``page_block`` — 0 keeps the dense fixed-depth (``max_seq``) cache
      rows; > 0 pages the KV cache: each client owns a shared pool of
      ``page_block``-token pages and each sequence slot maps its logical
      positions through a block table, so a slot only holds pages for
      tokens it has actually produced. Attention-bearing families only
      (dense/MoE/VLM/hybrid/enc-dec); recurrent families have O(1) state
      and ignore it.
    * ``pool_pages`` — pages per client pool; 0 sizes the pool for full
      provisioning (``max_batch_per_client * ceil(max_seq/page_block)``).
      Smaller pools trade admission backpressure for HBM.
    * ``kv_quant`` — int8 KV entries + per-head f32 scales (≈0.5× cache
      bytes). Composes with paging. Dense/MoE/VLM families only; ignored
      for architectures without a pure-KV decode cache.
    """
    n_clients: int = 8
    max_seq: int = 2048
    token_budget: int = 4096          # packed base-executor buffer capacity (paper §3.7)
    policy: str = "opportunistic"     # lockstep | nolockstep | opportunistic
    wait_fraction: float = 0.1        # opportunistic wait deadline as a fraction of request cost
    privacy: bool = False             # paper §3.8 activation noise
    page_block: int = 0               # 0 = dense max_seq rows; >0 = paged KV (tokens/page)
    pool_pages: int = 0               # pages per client pool (0 = full provisioning)
    kv_quant: bool = False            # int8 KV cache entries + f32 per-head scales
    seed: int = 0
