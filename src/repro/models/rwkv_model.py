"""RWKV6 full model assembly (attention-free; O(1) decode state)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks, rwkv
from repro.models.transformer import LinCtx, DEFAULT_CTX, embed_tokens, lm_head


def _layer_init(key, cfg, dtype):
    k1, = jax.random.split(key, 1)
    p = rwkv.rwkv_init(k1, cfg, dtype)
    p["ln1"] = blocks.rmsnorm_init(cfg.d_model, dtype)
    p["ln2"] = blocks.rmsnorm_init(cfg.d_model, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "embed": blocks.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": blocks.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": blocks.dense_init(ks[1], cfg.d_model, cfg.vocab, dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.n_layers)),
    }


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int = 0, dtype=None):
    """RWKV decode state: per-layer wkv state + token-shift tails. max_seq is
    ignored — the state is O(1) in sequence length (the long_500k story)."""
    H = cfg.d_model // cfg.hd
    L, d = cfg.n_layers, cfg.d_model
    return {
        "wkv": jnp.zeros((L, batch_size, H, cfg.hd, cfg.hd), jnp.float32),
        "tm_x": jnp.zeros((L, batch_size, 1, d), jnp.dtype(cfg.dtype)),
        "cm_x": jnp.zeros((L, batch_size, 1, d), jnp.dtype(cfg.dtype)),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def _layer(p, cfg, x, lin, state):
    """One RWKV layer. state: (wkv, tm_x, cm_x) or None (training, zeros)."""
    wkv_st, tm_x, cm_x = state if state is not None else (None, None, None)
    B = x.shape[0]
    H = cfg.d_model // cfg.hd
    if wkv_st is None:
        wkv_st = jnp.zeros((B, H, cfg.hd, cfg.hd), jnp.float32)
    h = blocks.rmsnorm(p["ln1"], x)
    y, wkv_st, tm_tail = rwkv.time_mix(p["time_mix"], cfg, h, lin, wkv_st, tm_x)
    x = x + y
    h = blocks.rmsnorm(p["ln2"], x)
    y, cm_tail = rwkv.channel_mix(p["channel_mix"], h, lin, cm_x)
    x = x + y
    return x, (wkv_st, tm_tail, cm_tail)


def forward(cfg: ModelConfig, params, batch, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, remat: bool = True, moe_dispatch: str = "scatter",
            capacity_factor=None):
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens, ctx.top)
    scan_adapters = adapter.get("layers") if adapter else None

    def body(x, layer_in):
        p, ad = layer_in
        x, _ = _layer(p, cfg, x, ctx.for_layer(ad), None)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["layers"], scan_adapters))
    x = blocks.rmsnorm(params["final_norm"], x)
    return lm_head(cfg, params, x, ctx.top), jnp.zeros((), jnp.float32)


def _run_with_state(cfg, params, x, cache, ctx, adapter, remat=False):
    scan_adapters = adapter.get("layers") if adapter else None

    def body(x, layer_in):
        p, wkv_st, tm_x, cm_x, ad = layer_in
        x, (wkv_st, tm_x, cm_x) = _layer(p, cfg, x, ctx.for_layer(ad), (wkv_st, tm_x, cm_x))
        return x, (wkv_st, tm_x, cm_x)

    if remat:
        body = jax.checkpoint(body)
    x, (wkv, tm_x, cm_x) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["tm_x"], cache["cm_x"], scan_adapters))
    return x, wkv, tm_x, cm_x


def prefill(cfg: ModelConfig, params, batch, cache, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, lengths=None):
    """``lengths`` gathers logits at each row's last real position. NOTE:
    the RWKV state is recurrent — callers must pass prompts at their true
    length (no right-padding) for exact decode."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens, ctx.top)
    x, wkv, tm_x, cm_x = _run_with_state(cfg, params, x, cache, ctx, adapter, remat=True)
    x = blocks.rmsnorm(params["final_norm"], x)
    if lengths is None:
        logits = lm_head(cfg, params, x[:, -1:], ctx.top)[:, 0]
        new_pos = cache["pos"] + S
    else:
        lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
        xg = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
        logits = lm_head(cfg, params, xg, ctx.top)[:, 0]
        new_pos = cache["pos"] + lengths
    return logits, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x, "pos": new_pos}


def decode_step(cfg: ModelConfig, params, cache, token, ctx: LinCtx = DEFAULT_CTX,
                adapter=None):
    x = embed_tokens(cfg, params, token[:, None], ctx.top)
    x, wkv, tm_x, cm_x = _run_with_state(cfg, params, x, cache, ctx, adapter)
    x = blocks.rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params, x, ctx.top)[:, 0]
    return logits, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x, "pos": cache["pos"] + 1}
