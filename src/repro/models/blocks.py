"""Shared neural building blocks (pure JAX, functional).

Every frozen-base matmul in every architecture goes through a ``LinearFns``
hook. This is the JAX analogue of the paper's VirtLayer splice (§3.2): the
default hook executes the matmul inline ("fused baseline"); the Symbiosis core
substitutes a hook that applies the memory-optimized frozen linear (§3.6),
per-client PEFT adapters, and the privacy noise protocol (§3.8) — without any
change to model code (paper design goal 3: model transparency).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

# Paged decode attention goes through the table-aware kernel wrapper: pages
# are read in place from the pool via the scalar-prefetched block table —
# the dense per-row view is never gathered on a decode path (the gather
# survives only as the kernels' test oracle, see decode_attn(via_gather=...))
from repro.kernels.decode_attn import decode_attn


class LinearFns(NamedTuple):
    """Hook for base-model linear layers.

    dense(x, w, b, path):   x [..., din] @ w [din, dout] (+ b) -> [..., dout]
    expert(x, w, path):     x [E, C, din] @ w [E, din, dout]   -> [E, C, dout]
    """
    dense: Callable
    expert: Callable


def _default_dense(x, w, b, path):
    y = jnp.einsum("...i,io->...o", x, w)
    if b is not None:
        y = y + b
    return y


def _default_expert(x, w, path):
    return jnp.einsum("eci,eio->eco", x, w)


DEFAULT_LIN = LinearFns(dense=_default_dense, expert=_default_expert)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, din, dout, dtype):
    scale = 1.0 / math.sqrt(din)
    return (jax.random.uniform(key, (din, dout), jnp.float32, -scale, scale)).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(scale, x, eps: float = 1e-6):
    """qk-norm: normalize the last (head) dim. scale [hd]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)


def apply_rope(x, positions, theta: float):
    """x [..., S, H, hd]; positions [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked-causal for long sequences, decode with cache)
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype, causal=True):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.hp * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.hp * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _pick_chunk(S: int, B: int, H: int, T: int, chunk_q: int,
                budget_bytes: float = 256e6) -> int:
    """Query-chunk size: a divisor of S bounding the fp32 score buffer
    B*H*c*T*4 <= budget (the flash-attention memory property, statically)."""
    c = chunk_q
    while c > 16 and (S % c or B * H * c * T * 4 > budget_bytes):
        c //= 2
    while S % c and c > 1:   # S with odd factors: fall to a true divisor
        c -= 1
    return max(c, 1)


def mha_forward(params, cfg, x, positions, lin: LinearFns, *, causal: bool = True,
                kv_x: Optional[jnp.ndarray] = None, kv_positions=None,
                ext_kv=None, path_prefix: str = "", chunk_q: int = 1024):
    """Full attention over a sequence (training / prefill / encoder / cross-attn).

    x [B,S,d]. If kv_x is given this is cross-attention (non-causal over kv_x).

    ``ext_kv`` — optional ``(k, v, positions)`` of ALREADY-PROJECTED (post
    qk-norm, post-RoPE) external K/V lanes [B,E,K,hd]/[B,E] prepended to
    this call's own K/V: the suffix-prefill path attends over cached
    shared-prefix pages without recomputing them (docs/prefix_cache.md).
    Lanes whose position fails the causal mask (the engine marks unused
    lanes with a huge position) contribute exact zeros to the softmax, so
    a suffix prefill over valid ext lanes is bitwise the corresponding
    rows of a full prefill.

    Layout notes (GSPMD-friendliness, DESIGN.md §5): heads are kept *flat*
    [B,S,H,hd] and KV heads are replicated to H via ``jnp.repeat`` (classic
    kv-replication tensor parallelism) — the grouped [K,G] form cannot be
    sharded when K < the model-axis size, the flat form shards whenever
    H % model == 0. Long sequences are processed in query chunks to bound
    the score buffer (the pure-JAX analogue of flash attention's memory
    behaviour); the chunk adapts so the fp32 scores stay within budget.
    """
    B, S, _ = x.shape
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.hp
    G = H // K
    src = kv_x if kv_x is not None else x
    T = src.shape[1]
    if kv_positions is None:
        kv_positions = positions if kv_x is None else jnp.arange(T)[None, :].repeat(B, 0)

    q = lin.dense(x, params["wq"], params.get("bq"), path_prefix + "q")
    k = lin.dense(src, params["wk"], params.get("bk"), path_prefix + "k")
    v = lin.dense(src, params["wv"], params.get("bv"), path_prefix + "v")
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    if kv_x is None and cfg.rope_theta > 0:  # self-attention uses RoPE (except whisper-style)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    if ext_kv is not None:   # shared-prefix lanes ride in front, pre-replication
        ek, ev, epos = ext_kv
        k = jnp.concatenate([ek.astype(k.dtype), k], axis=1)
        v = jnp.concatenate([ev.astype(v.dtype), v], axis=1)
        kv_positions = jnp.concatenate([epos, kv_positions], axis=1)
        T = k.shape[1]
    if G > 1:   # kv-replication: [B,T,K,hd] -> [B,T,H,hd]
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    scale = 1.0 / math.sqrt(hd)
    window = cfg.sliding_window

    def attend_chunk(q_chunk, qpos_chunk):
        # q_chunk [B,c,H,hd] -> [B,c,H,hd]
        s = jnp.einsum("bshd,bthd->bhst", q_chunk, k).astype(jnp.float32) * scale
        if causal and kv_x is None:
            m = qpos_chunk[:, None, :, None] >= kv_positions[:, None, None, :]
            if window:
                m &= (qpos_chunk[:, None, :, None] - kv_positions[:, None, None, :]) < window
            s = jnp.where(m, s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", p, v)

    def attend_chunk_flash(q_chunk, qpos_chunk, block_kv: int):
        """Online-softmax over KV blocks: the [c, T] score matrix never
        materializes — only [c, block_kv] tiles and running (max, denom,
        acc) carries live at once (the in-JAX analogue of our Pallas
        decode/flash kernels; §Perf iteration 1)."""
        c = q_chunk.shape[1]
        nkv = T // block_kv
        kb = k.reshape(B, nkv, block_kv, H, hd).transpose(1, 0, 2, 3, 4)
        vb = v.reshape(B, nkv, block_kv, H, hd).transpose(1, 0, 2, 3, 4)
        pb = kv_positions.reshape(B, nkv, block_kv).transpose(1, 0, 2)
        m0 = jnp.full((B, H, c, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, c, 1), jnp.float32)
        a0 = jnp.zeros((B, H, c, hd), jnp.float32)

        def body(carry, blk):
            m, l, acc = carry
            kc, vc, pc = blk
            s = jnp.einsum("bshd,bthd->bhst", q_chunk, kc).astype(jnp.float32) * scale
            if causal and kv_x is None:
                msk = qpos_chunk[:, None, :, None] >= pc[:, None, None, :]
                if window:
                    msk &= (qpos_chunk[:, None, :, None]
                            - pc[:, None, None, :]) < window
                s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l = l * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhst,bthd->bhsd", p,
                                           vc.astype(jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
        out = acc / jnp.maximum(l, 1e-30)
        return out.transpose(0, 2, 1, 3).astype(v.dtype)      # [B,c,H,hd]

    # §Perf iterations 2/3: online-softmax (flash) only pays off once T is
    # large enough that score-matrix traffic dominates its loop-carry
    # traffic (empirically T > 8k); below that, plain chunked attention
    # with a 1 GB score budget (64 MB/device under 16-way head sharding)
    # minimizes K/V re-reads.
    block_kv = 1024 if T % 1024 == 0 else (512 if T % 512 == 0 else 0)
    use_flash = block_kv > 0 and T > 8192 and kv_x is None
    if use_flash:
        chunk = _pick_chunk(S, B, H, block_kv, max(chunk_q, 2048))
    else:
        chunk = _pick_chunk(S, B, H, T, chunk_q, budget_bytes=1e9)
    att = ((lambda qc, pc: attend_chunk_flash(qc, pc, block_kv))
           if use_flash else attend_chunk)
    if S <= chunk:
        out = att(q, positions)
    else:
        n = S // chunk
        qc = q.reshape(B, n, chunk, H, hd).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(B, n, chunk).transpose(1, 0, 2)
        out = jax.lax.map(lambda args: att(*args), (qc, pc))
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)

    out = out.reshape(B, S, H * hd)
    return lin.dense(out, params["wo"], params.get("bo"), path_prefix + "o")


def quantize_head(x):
    """Per-head symmetric int8 quantization. x [..., hd] ->
    (q int8 [..., hd], scale f32 [..., 1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Decode attention internals (shared by the dense, quantized and paged paths)
# ---------------------------------------------------------------------------

def _decode_qkv(params, cfg, x, pos, lin: LinearFns, path_prefix: str):
    """Single-token q/k/v projections + qk-norm + RoPE. x [B,1,d]; pos [B].
    Returns q [B,1,H,hd], k/v [B,1,K,hd]."""
    B = x.shape[0]
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.hp
    q = lin.dense(x, params["wq"], params.get("bq"), path_prefix + "q").reshape(B, 1, H, hd)
    k = lin.dense(x, params["wk"], params.get("bk"), path_prefix + "k").reshape(B, 1, K, hd)
    v = lin.dense(x, params["wv"], params.get("bv"), path_prefix + "v").reshape(B, 1, K, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    if cfg.rope_theta > 0:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    return q, k, v


def _decode_valid(cfg, pos, T: int, ring: bool):
    """[B,T] validity of cache lanes for a query at position pos."""
    t_ar = jnp.arange(T)[None, :]
    if ring:
        # slot s holds absolute position p: p % T == s, p <= pos, p > pos - T
        cycle = (pos[:, None] - t_ar) // T
        abs_pos = cycle * T + t_ar
        valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
        if cfg.sliding_window:
            valid &= (pos[:, None] - abs_pos) < cfg.sliding_window
    else:
        valid = (t_ar <= pos[:, None])
        if cfg.sliding_window:
            valid &= (pos[:, None] - t_ar) < cfg.sliding_window
    return valid


def _decode_attend(params, cfg, q, cache_k, cache_v, valid, lin: LinearFns,
                   path_prefix: str):
    """Attention of one query token against a dense [B,T,K,hd] cache view.

    Grouped GQA einsum (NOT kv-replicated): with the cache sharded on T,
    scores stay T-local and only the softmax max/sum and the T-contraction
    psum cross chips (flash-decode style). Repeating KV to H here would
    make GSPMD reshard the whole repeated cache (all-to-all) every layer."""
    B = q.shape[0]
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.hp
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, cache_k).astype(jnp.float32) * scale
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, cache_v).reshape(B, 1, H * hd)
    return lin.dense(out, params["wo"], params.get("bo"), path_prefix + "o")


def _decode_attend_quant(params, cfg, q, cache_k, cache_ks, cache_v, cache_vs,
                         valid, lin: LinearFns, path_prefix: str, out_dtype):
    """Attention of one query token against an int8 [B,T,K,hd] cache view
    with per-entry f32 scales [B,T,K,1]."""
    B = q.shape[0]
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.hp
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    # int8 scores with per-entry rescale: q·(kq*ks) == (q·kq)*ks
    s = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32))
    s = s * cache_ks[..., 0].transpose(0, 2, 1)[:, :, None, None, :] * scale
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * cache_vs[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bkgst,btkh->bskgh", pv,
                     cache_v.astype(jnp.float32)).astype(out_dtype)
    out = out.reshape(B, 1, H * hd)
    return lin.dense(out, params["wo"], params.get("bo"), path_prefix + "o")


# ---------------------------------------------------------------------------
# Paged KV primitives (vLLM-style block pool + per-slot block tables)
# ---------------------------------------------------------------------------
#
# A paged cache stores K/V in a *pool* of fixed-size pages shared by all
# sequence slots of one client: pool [P, block, ...]. Each slot maps its
# logical token positions through a block table row tbl[b]: position t lives
# at pool[tbl[b, t // block], t % block]. A slot therefore only occupies
# pages for tokens it has actually produced; freeing a sequence returns its
# pages to the pool. Unallocated table entries may alias live pages of other
# slots — reads through them are always masked by position validity, and all
# writes are either bounded by true lengths (prefill) or dropped for
# inactive slots (decode), so cross-slot corruption is impossible.
#
# READS go through the table-aware decode kernel (kernels/decode_attn): the
# block table is scalar-prefetched and the kernel's index_map reads each
# row's pages straight out of the pool — attention math is the kernel's
# blocked online softmax, byte-identical between the bank-wide masked decode
# and the engine's compacted decode (the kernel's custom_vmap rule folds a
# vmapped client axis into extra pool pages, so both are literally the same
# computation). ``_ORACLE`` reroutes the read through the gather-based test
# oracle (same blocked math on a materialized dense view) — tests only.

_ORACLE = False


@contextmanager
def paged_gather_oracle():
    """TEST ORACLE: route paged decode reads through gather_paged_kv + the
    identical blocked kernel math. Byte-equality of a decode under this
    context and without it is the paged kernel's correctness contract.
    The flag is read at TRACE time: use only around direct model calls,
    never while constructing engines (their memoized jitted steps would
    bake the oracle in)."""
    global _ORACLE
    _ORACLE = True
    try:
        yield
    finally:
        _ORACLE = False


def _paged_attend(params, cfg, q, pools, tbl, pos, lin: LinearFns,
                  path_prefix: str):
    """Attention of one query token read in place from paged pools.

    q [B,1,H,hd]; pools = (k, v) or (k, k_s, v, v_s) page pools; tbl
    [B, n_blocks]; pos [B]. Returns [B,1,d_model] after the o-projection."""
    B = q.shape[0]
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.hp
    G = H // K
    qg = q.reshape(B, K, G, hd)
    kw = {}
    if len(pools) == 4:
        pool_k, pool_ks, pool_v, pool_vs = pools
        kw = {"k_scale": pool_ks, "v_scale": pool_vs}
    else:
        pool_k, pool_v = pools
    out = decode_attn(qg, pool_k, pool_v, pos, window=cfg.sliding_window,
                      block_tbl=tbl, via_gather=_ORACLE, **kw)
    out = out.reshape(B, 1, H * hd)
    return lin.dense(out, params["wo"], params.get("bo"), path_prefix + "o")

def paged_token_write(pool, tbl, pos, x, active=None):
    """Write one token's row x [B, ...] at logical position pos [B] through
    the block table. Rows with active == False are dropped (their target
    page index is pushed out of bounds), which is what lets a bank-wide
    masked decode share one pool: inactive slots never touch it.

    The write is a custom_vmap op: when the masked decode vmaps a bank of
    clients over a SHARED (unbatched) global pool, the rule flattens the
    client axis into more rows and issues ONE scatter — a naive vmap of a
    scatter onto an unbatched operand would broadcast the pool per lane
    (C copies of the whole pool per layer). Clients' pages are disjoint by
    the engine allocator's page-range invariant, so the flattened scatter
    touches disjoint slots."""
    active = jnp.ones(tbl.shape[:1], bool) if active is None else active
    return _paged_token_write(pool, tbl, pos.astype(jnp.int32), x, active)


@custom_vmap
def _paged_token_write(pool, tbl, pos, x, active):
    P, blk = pool.shape[:2]
    page = jnp.take_along_axis(tbl, (pos // blk)[:, None], axis=1)[:, 0]
    page = jnp.where(active, page, P)                # P is out of bounds
    return pool.at[page, pos % blk].set(x.astype(pool.dtype), mode="drop")


@_paged_token_write.def_vmap
def _paged_token_write_vmap(axis_size, in_batched, pool, tbl, pos, x, active):
    pool_b, tbl_b, pos_b, x_b, act_b = in_batched
    assert tbl_b or pool_b, \
        "paged_token_write under vmap: lanes must differ in table or pool"
    C = axis_size
    lift = lambda a, b: a if b else jnp.broadcast_to(a, (C,) + a.shape)
    tbl = lift(tbl, tbl_b)
    pos, x, active = lift(pos, pos_b), lift(x, x_b), lift(active, act_b)
    B = tbl.shape[1]
    flat = lambda a: a.reshape((C * B,) + a.shape[2:])
    if pool_b:
        # batched per-client pools: fold clients into pages ([C,P]->[C*P])
        P = pool.shape[1]
        pool = pool.reshape((C * P,) + pool.shape[2:])
        tbl = tbl + (jnp.arange(C, dtype=tbl.dtype) * P)[:, None, None]
        out = _paged_token_write(pool, flat(tbl), flat(pos), flat(x), flat(active))
        return out.reshape((C, P) + out.shape[1:]), True
    # shared global pool: one scatter for all lanes, result stays shared
    # (clients' pages are disjoint by the allocator's page-range invariant)
    out = _paged_token_write(pool, flat(tbl), flat(pos), flat(x), flat(active))
    return out, False


def paged_prefill_write(pool, tbl, x, lengths=None, start=None):
    """Scatter prefill rows x [B, S, ...] into the pool through the block
    table, writing ONLY positions < lengths — right-pad positions never
    touch the pool (pages beyond a row's true length stay unallocated,
    unlike the dense path which writes stale pad K/V to be overwritten
    later). lengths None writes all S positions.

    ``start`` [B] int32 (optional) offsets every row's writes by that many
    LOGICAL positions: token i of x lands at cache position start+i — the
    suffix-prefill path, which skips a row's shared-prefix pages and only
    fills from its first non-cached token onward."""
    P, blk = pool.shape[:2]
    B, S = x.shape[:2]
    t = jnp.arange(S)
    if start is None:
        page = jnp.take(tbl, t // blk, axis=1)       # [B, S]
        off = jnp.broadcast_to((t % blk)[None, :], (B, S))
    else:
        logical = jnp.asarray(start, jnp.int32)[:, None] + t[None, :]
        page = jnp.take_along_axis(tbl, logical // blk, axis=1, mode="clip")
        off = logical % blk
    if lengths is not None:
        valid = t[None, :] < jnp.broadcast_to(jnp.asarray(lengths, jnp.int32),
                                              (B,))[:, None]
        page = jnp.where(valid, page, P)             # P is out of bounds
    return pool.at[page, off].set(x.astype(pool.dtype), mode="drop")


def mha_decode_quant(params, cfg, x, cache_k, cache_ks, cache_v, cache_vs,
                     pos, lin: LinearFns, *, path_prefix: str = "",
                     ring: bool = False):
    """Decode against an int8-quantized KV cache (beyond-paper §Perf
    optimization: halves the HBM bytes of the cache read, the dominant
    roofline term of decode shapes).

    cache_k/v int8 [B,T,K,hd]; cache_ks/vs f32 [B,T,K,1] per-head scales.
    Returns (out, new_k, new_ks, new_v, new_vs)."""
    T = cache_k.shape[1]
    q, k, v = _decode_qkv(params, cfg, x, pos, lin, path_prefix)
    kq, ks = quantize_head(k)
    vq, vs = quantize_head(v)
    slot = (pos % T) if ring else pos
    idx = slot[:, None, None, None]
    t_iota = jnp.arange(T)[None, :, None, None]
    write = t_iota == idx
    cache_k = jnp.where(write, kq, cache_k)
    cache_ks = jnp.where(write, ks, cache_ks)
    cache_v = jnp.where(write, vq, cache_v)
    cache_vs = jnp.where(write, vs, cache_vs)
    valid = _decode_valid(cfg, pos, T, ring)
    out = _decode_attend_quant(params, cfg, q, cache_k, cache_ks, cache_v,
                               cache_vs, valid, lin, path_prefix, x.dtype)
    return out, cache_k, cache_ks, cache_v, cache_vs


def mha_decode(params, cfg, x, cache_k, cache_v, pos, lin: LinearFns,
               *, path_prefix: str = "", ring: bool = False):
    """Single-token decode. x [B,1,d]; cache_k/v [B,T,K,hd]; pos [B] int32.

    ring=True treats the cache as a ring buffer of size T (< full context):
    slot = pos % T, validity derived from absolute positions — the
    sliding-window long-context variant (cfg.sliding_window must be <= T).

    Returns (out [B,1,d], new_k, new_v).
    """
    T = cache_k.shape[1]
    q, k, v = _decode_qkv(params, cfg, x, pos, lin, path_prefix)

    # Write this token's K/V at its slot (per batch row). The write is an
    # ELEMENTWISE select over the T axis (not a scatter): per-row vector
    # scatters defeat GSPMD partitioning of a T-sharded cache (it falls back
    # to all-to-all resharding of the whole cache every layer), while the
    # broadcast-compare select partitions locally on every axis.
    slot = (pos % T) if ring else pos
    idx = slot[:, None, None, None]
    t_iota = jnp.arange(T)[None, :, None, None]
    write = t_iota == idx
    cache_k = jnp.where(write, k.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(write, v.astype(cache_v.dtype), cache_v)

    valid = _decode_valid(cfg, pos, T, ring)
    out = _decode_attend(params, cfg, q, cache_k, cache_v, valid, lin, path_prefix)
    return out, cache_k, cache_v


def mha_decode_paged(params, cfg, x, pool_k, pool_v, tbl, pos, lin: LinearFns,
                     *, active=None, path_prefix: str = ""):
    """Single-token decode against a paged KV cache.

    pool_k/v [P, block, K, hd] page pools shared across the B slots;
    tbl [B, n_blocks] block table; pos [B]; active [B] bool (None = all).
    The new token's K/V is written through the table (dropped for inactive
    rows), then the table-aware kernel attends over the pages in place —
    no dense view is gathered. Returns (out, new_pool_k, new_pool_v)."""
    q, k, v = _decode_qkv(params, cfg, x, pos, lin, path_prefix)
    pool_k = paged_token_write(pool_k, tbl, pos, k[:, 0], active)
    pool_v = paged_token_write(pool_v, tbl, pos, v[:, 0], active)
    out = _paged_attend(params, cfg, q, (pool_k, pool_v), tbl, pos, lin,
                        path_prefix)
    return out, pool_k, pool_v


def mha_decode_quant_paged(params, cfg, x, pool_k, pool_ks, pool_v, pool_vs,
                           tbl, pos, lin: LinearFns, *, active=None,
                           path_prefix: str = ""):
    """Paged + int8-quantized decode: pools hold int8 entries [P,block,K,hd]
    and f32 per-head scales [P,block,K,1]. Same contract as
    ``mha_decode_paged``; the kernel dequantizes per page while streaming.
    Returns (out, k, ks, v, vs) pools."""
    q, k, v = _decode_qkv(params, cfg, x, pos, lin, path_prefix)
    kq, ks = quantize_head(k)
    vq, vs = quantize_head(v)
    pool_k = paged_token_write(pool_k, tbl, pos, kq[:, 0], active)
    pool_ks = paged_token_write(pool_ks, tbl, pos, ks[:, 0], active)
    pool_v = paged_token_write(pool_v, tbl, pos, vq[:, 0], active)
    pool_vs = paged_token_write(pool_vs, tbl, pos, vs[:, 0], active)
    out = _paged_attend(params, cfg, q, (pool_k, pool_ks, pool_v, pool_vs),
                        tbl, pos, lin, path_prefix)
    return out, pool_k, pool_ks, pool_v, pool_vs


def cross_decode(params, cfg, x, enc_k, enc_v, lin: LinearFns, *, path_prefix: str = "xattn_"):
    """Cross-attention decode against a fixed encoder cache. x [B,1,d]."""
    B = x.shape[0]
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.hp
    G = H // K
    q = lin.dense(x, params["wq"], params.get("bq"), path_prefix + "q").reshape(B, 1, K, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", q, enc_k).astype(jnp.float32) / math.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1).astype(enc_v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, enc_v).reshape(B, 1, H * hd)
    return lin.dense(out, params["wo"], params.get("bo"), path_prefix + "o")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, dtype, d_ff=None, gelu=False, bias=False):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if gelu:
        p = {"fc1": dense_init(ks[0], cfg.d_model, d_ff, dtype),
             "fc2": dense_init(ks[1], d_ff, cfg.d_model, dtype)}
        if bias:
            p["b1"] = jnp.zeros((d_ff,), dtype)
            p["b2"] = jnp.zeros((cfg.d_model,), dtype)
        return p
    return {"gate": dense_init(ks[0], cfg.d_model, d_ff, dtype),
            "up": dense_init(ks[1], cfg.d_model, d_ff, dtype),
            "down": dense_init(ks[2], d_ff, cfg.d_model, dtype)}


def mlp_forward(params, x, lin: LinearFns, *, path_prefix: str = ""):
    if "fc1" in params:  # GELU MLP (whisper-style)
        h = lin.dense(x, params["fc1"], params.get("b1"), path_prefix + "fc1")
        h = jax.nn.gelu(h)
        return lin.dense(h, params["fc2"], params.get("b2"), path_prefix + "fc2")
    g = lin.dense(x, params["gate"], None, path_prefix + "gate")
    u = lin.dense(x, params["up"], None, path_prefix + "up")
    return lin.dense(jax.nn.silu(g) * u, params["down"], None, path_prefix + "down")
