"""Whisper-style encoder-decoder backbone.

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings [B, T_enc, d]. We
implement the transformer backbone: bidirectional encoder, causal decoder
with cross-attention, learned positional embeddings, GELU MLPs with bias
(Whisper-faithful), MHA (n_kv_heads == n_heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks
from repro.models.transformer import LinCtx, DEFAULT_CTX, default_block_table
from repro.models.blocks import dense_init


MAX_DEC_POS = 32768  # learned decoder positions (stress configs go far beyond 448)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": blocks.rmsnorm_init(cfg.d_model, dtype),
        "ln2": blocks.rmsnorm_init(cfg.d_model, dtype),
        "attn": blocks.attn_init(k1, cfg, dtype),
        "mlp": blocks.mlp_init(k2, cfg, dtype, gelu=True, bias=True),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": blocks.rmsnorm_init(cfg.d_model, dtype),
        "ln_x": blocks.rmsnorm_init(cfg.d_model, dtype),
        "ln2": blocks.rmsnorm_init(cfg.d_model, dtype),
        "attn": blocks.attn_init(k1, cfg, dtype),
        "xattn": blocks.attn_init(k2, cfg, dtype),
        "mlp": blocks.mlp_init(k3, cfg, dtype, gelu=True, bias=True),
    }


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "embed": blocks.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "enc_pos": blocks.embed_init(ks[1], cfg.n_frontend_tokens, cfg.d_model, dtype),
        "dec_pos": blocks.embed_init(ks[2], MAX_DEC_POS, cfg.d_model, dtype),
        "enc_norm": blocks.rmsnorm_init(cfg.d_model, dtype),
        "final_norm": blocks.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(
            jax.random.split(ks[4], cfg.n_enc_layers)),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(
            jax.random.split(ks[5], cfg.n_layers)),
    }


def encode(cfg, params, frames, ctx: LinCtx, adapter=None):
    """frames [B,T_enc,d] (frontend stub output) -> encoder states."""
    B, T, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + params["enc_pos"][None, :T].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    scan_ad = adapter.get("enc_layers") if adapter else None

    def body(x, layer_in):
        p, ad = layer_in
        lin = ctx.for_layer(ad)
        h = blocks.rmsnorm(p["ln1"], x)
        x = x + blocks.mha_forward(p["attn"], cfg, h, positions, lin, causal=False)
        h = blocks.rmsnorm(p["ln2"], x)
        x = x + blocks.mlp_forward(p["mlp"], h, lin)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, (params["enc_layers"], scan_ad))
    return blocks.rmsnorm(params["enc_norm"], x)


def _dec_layer(p, cfg, x, positions, enc, lin):
    h = blocks.rmsnorm(p["ln1"], x)
    x = x + blocks.mha_forward(p["attn"], cfg, h, positions, lin, causal=True)
    h = blocks.rmsnorm(p["ln_x"], x)
    x = x + blocks.mha_forward(p["xattn"], cfg, h, positions, lin, kv_x=enc,
                               path_prefix="xattn_")
    h = blocks.rmsnorm(p["ln2"], x)
    return x + blocks.mlp_forward(p["mlp"], h, lin)


def forward(cfg: ModelConfig, params, batch, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, remat: bool = True, moe_dispatch: str = "scatter",
            capacity_factor=None):
    """Training forward: encoder over frames + teacher-forced decoder."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc = encode(cfg, params, batch["frames"], ctx, adapter)
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + params["dec_pos"][None, :S].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    scan_ad = adapter.get("dec_layers") if adapter else None

    def body(x, layer_in):
        p, ad = layer_in
        return _dec_layer(p, cfg, x, positions, enc, ctx.for_layer(ad)), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["dec_layers"], scan_ad))
    x = blocks.rmsnorm(params["final_norm"], x)
    logits = ctx.top.dense(x, params["lm_head"], None, "lm_head")
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None,
               *, page_block: int = 0, pool_pages: int = 0):
    """page_block > 0 pages the decoder self-attention KV (per-layer page
    pools + a shared ``block_tbl``); the cross-attention cache has fixed
    depth ``n_frontend_tokens`` and stays dense."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    Te = cfg.n_frontend_tokens
    kv = cfg.n_kv_heads
    tbl = None
    if page_block:
        _, P, tbl = default_block_table(batch_size, max_seq, page_block,
                                        pool_pages)
        self_lead = (L, P, page_block)
    else:
        self_lead = (L, batch_size, max_seq)
    cache = {
        "self_k": jnp.zeros(self_lead + (kv, cfg.hd), dtype),
        "self_v": jnp.zeros(self_lead + (kv, cfg.hd), dtype),
        "cross_k": jnp.zeros((L, batch_size, Te, kv, cfg.hd), dtype),
        "cross_v": jnp.zeros((L, batch_size, Te, kv, cfg.hd), dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }
    if tbl is not None:
        cache["block_tbl"] = tbl
    return cache


def prefill(cfg: ModelConfig, params, batch, cache, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, lengths=None):
    """Encode frames, fill cross-attn caches, then prefill decoder prompt.

    ``lengths`` gathers logits at each row's last real decoder position and
    starts ``pos`` there (right-padded decoder prompts are safe: decoder
    self-attention is causal and decode overwrites a pad slot before first
    reading it)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc = encode(cfg, params, batch["frames"], ctx, adapter)
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + params["dec_pos"][None, :S].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    scan_ad = adapter.get("dec_layers") if adapter else None
    Te = enc.shape[1]
    kvh, hd = cfg.n_kv_heads, cfg.hd
    tbl = cache.get("block_tbl")
    wlen = None if lengths is None else jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (B,))

    # Paged decoder self-attention pools ride the scan as CARRY, fused
    # [L, P, ..] -> [L*P, ..] with per-layer table offsets (mirroring
    # decode_step): as xs/ys the whole pool was re-materialized once per
    # ADMISSION. The cross caches are replaced wholesale and stay ys.
    paged = tbl is not None
    if paged:
        Pl = cache["self_k"].shape[1]
        fuse = lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:])
        kv0 = (fuse(cache["self_k"]), fuse(cache["self_v"]))
    else:
        kv0 = (cache["self_k"], cache["self_v"])

    def capture(p, x, ad, sk, sv, layer_tbl):
        lin = ctx.for_layer(ad)
        h = blocks.rmsnorm(p["ln1"], x)
        k = lin.dense(h, p["attn"]["wk"], p["attn"].get("bk"), "k").reshape(B, S, kvh, hd)
        v = lin.dense(h, p["attn"]["wv"], p["attn"].get("bv"), "v").reshape(B, S, kvh, hd)
        if cfg.rope_theta > 0:
            k = blocks.apply_rope(k, positions, cfg.rope_theta)
        if paged:
            ck = blocks.paged_prefill_write(sk, layer_tbl, k, wlen)
            cv = blocks.paged_prefill_write(sv, layer_tbl, v, wlen)
        else:
            ck = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype), (0, 0, 0, 0))
        xk = lin.dense(enc, p["xattn"]["wk"], p["xattn"].get("bk"), "xattn_k").reshape(B, Te, kvh, hd)
        xv = lin.dense(enc, p["xattn"]["wv"], p["xattn"].get("bv"), "xattn_v").reshape(B, Te, kvh, hd)
        x = _dec_layer(p, cfg, x, positions, enc, lin)
        return x, ck, cv, xk.astype(sk.dtype), xv.astype(sk.dtype)

    if paged:
        def body(carry, layer_in):
            x, (sk, sv), i = carry
            p, ad = layer_in
            x, ck, cv, xk, xv = capture(p, x, ad, sk, sv, tbl + i * Pl)
            return (x, (ck, cv), i + 1), (xk, xv)

        (x, (sk, sv), _), (xk, xv) = jax.lax.scan(
            jax.checkpoint(body), (x, kv0, jnp.int32(0)),
            (params["dec_layers"], scan_ad))
        sk = sk.reshape(cache["self_k"].shape)
        sv = sv.reshape(cache["self_v"].shape)
    else:
        def body(x, layer_in):
            p, sk, sv, ad = layer_in
            x, ck, cv, xk, xv = capture(p, x, ad, sk, sv, None)
            return x, (ck, cv, xk, xv)

        x, (sk, sv, xk, xv) = jax.lax.scan(
            jax.checkpoint(body), x,
            (params["dec_layers"], cache["self_k"], cache["self_v"], scan_ad))
    x = blocks.rmsnorm(params["final_norm"], x)
    if lengths is None:
        logits = ctx.top.dense(x[:, -1:], params["lm_head"], None, "lm_head")[:, 0]
        pos = jnp.full((B,), S, jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
        xg = jnp.take_along_axis(x, (pos - 1)[:, None, None], axis=1)
        logits = ctx.top.dense(xg, params["lm_head"], None, "lm_head")[:, 0]
    new_cache = {"self_k": sk, "self_v": sv, "cross_k": xk, "cross_v": xv,
                 "pos": pos}
    if tbl is not None:
        new_cache["block_tbl"] = tbl
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, cache, token, ctx: LinCtx = DEFAULT_CTX,
                adapter=None, *, active=None):
    B = token.shape[0]
    pos = cache["pos"]
    tbl = cache.get("block_tbl")
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + jnp.take(params["dec_pos"], jnp.clip(pos, 0, MAX_DEC_POS - 1),
                     axis=0)[:, None].astype(x.dtype)
    scan_ad = adapter.get("dec_layers") if adapter else None

    # self-attention KV rides the scan as CARRY (see transformer.decode_step
    # for the layout rationale): paged pools are fused [L, P, ..]->[L*P, ..]
    # and addressed per layer through offset tables (the pool is never
    # sliced); dense caches use indexed in-place carry updates. Read-only
    # cross caches stay xs.
    paged = tbl is not None
    if paged:
        Pl = cache["self_k"].shape[1]
        fuse = lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:])
        kv0 = (fuse(cache["self_k"]), fuse(cache["self_v"]))
    else:
        kv0 = (cache["self_k"], cache["self_v"])

    def body(carry, layer_in):
        x, self_kv, i = carry
        p, xk, xv, ad = layer_in
        if paged:
            sk, sv = self_kv
        else:
            sk = jax.lax.dynamic_index_in_dim(self_kv[0], i, 0, keepdims=False)
            sv = jax.lax.dynamic_index_in_dim(self_kv[1], i, 0, keepdims=False)
        lin = ctx.for_layer(ad)
        h = blocks.rmsnorm(p["ln1"], x)
        if paged:
            y, sk, sv = blocks.mha_decode_paged(p["attn"], cfg, h, sk, sv,
                                                tbl + i * Pl, pos, lin,
                                                active=active)
        else:
            y, sk, sv = blocks.mha_decode(p["attn"], cfg, h, sk, sv, pos, lin)
        x = x + y
        h = blocks.rmsnorm(p["ln_x"], x)
        x = x + blocks.cross_decode(p["xattn"], cfg, h, xk, xv, lin)
        h = blocks.rmsnorm(p["ln2"], x)
        x = x + blocks.mlp_forward(p["mlp"], h, lin)
        if paged:
            self_kv = (sk, sv)
        else:
            self_kv = (jax.lax.dynamic_update_index_in_dim(
                           self_kv[0], sk.astype(self_kv[0].dtype), i, 0),
                       jax.lax.dynamic_update_index_in_dim(
                           self_kv[1], sv.astype(self_kv[1].dtype), i, 0))
        return (x, self_kv, i + 1), None

    (x, (sk, sv), _), _ = jax.lax.scan(
        body, (x, kv0, jnp.int32(0)),
        (params["dec_layers"], cache["cross_k"], cache["cross_v"], scan_ad))
    if paged:
        sk = sk.reshape(cache["self_k"].shape)
        sv = sv.reshape(cache["self_v"].shape)
    x = blocks.rmsnorm(params["final_norm"], x)
    logits = ctx.top.dense(x, params["lm_head"], None, "lm_head")[:, 0]
    new_cache = {"self_k": sk, "self_v": sv, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"], "pos": pos + 1}
    if tbl is not None:
        new_cache["block_tbl"] = tbl
    return logits, new_cache
