"""Loss functions."""
import jax
import jax.numpy as jnp


def lm_loss(logits, labels, mask=None, aux=0.0, aux_weight: float = 0.01):
    """Next-token cross entropy. logits [B,S,V] (S may exceed labels' S when a
    multimodal prefix was prepended — the prefix positions are ignored)."""
    B, S_lab = labels.shape
    S = logits.shape[1]
    if S != S_lab:  # strip multimodal prefix
        logits = logits[:, S - S_lab:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux
