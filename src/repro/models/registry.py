"""Model registry: uniform interface over all architecture families."""
from __future__ import annotations

from types import SimpleNamespace

from repro.config import ModelConfig, DENSE, MOE, RWKV, HYBRID, ENCDEC, VLM
from repro.models import transformer, hybrid, rwkv_model, encdec


_FAMILY = {
    DENSE: transformer,
    MOE: transformer,
    VLM: transformer,
    HYBRID: hybrid,
    RWKV: rwkv_model,
    ENCDEC: encdec,
}


def get_model(cfg: ModelConfig):
    """Returns a namespace with init_params / forward / init_cache / prefill /
    decode_step, all taking cfg as first arg pre-bound."""
    mod = _FAMILY[cfg.arch]

    def bind(fn_name):
        fn = getattr(mod, fn_name)
        return lambda *a, **kw: fn(cfg, *a, **kw)

    return SimpleNamespace(
        cfg=cfg,
        init_params=bind("init_params"),
        forward=bind("forward"),
        init_cache=bind("init_cache"),
        prefill=bind("prefill"),
        decode_step=bind("decode_step"),
    )
