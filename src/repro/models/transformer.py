"""Decoder-only transformer (dense, MoE, VLM backbones).

Uniform layers are stacked along a leading [L] axis and executed with
``lax.scan`` (keeps the HLO one-layer-sized for the 40-layer × 512-device
dry-runs). Heterogeneous prefixes (DeepSeek-MoE's first dense layer) are
unrolled before the scan.

The ``LinCtx`` hook threads Symbiosis split execution through every frozen
matmul; ``adapter`` is a per-client PEFT tree whose per-layer leaves are
sliced inside the scan (so adapters ride along with their layer).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, VLM
from repro.models import blocks, moe as moe_lib
from repro.models.blocks import DEFAULT_LIN, LinearFns


class LinCtx(NamedTuple):
    """Linear-hook context. `top` serves embed/lm_head; `for_layer` binds a
    per-layer adapter slice into a LinearFns."""
    top: LinearFns
    for_layer: Callable[[Any], LinearFns]


DEFAULT_CTX = LinCtx(top=DEFAULT_LIN, for_layer=lambda adapter_slice: DEFAULT_LIN)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, layer_idx: int, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "ln1": blocks.rmsnorm_init(cfg.d_model, dtype),
        "ln2": blocks.rmsnorm_init(cfg.d_model, dtype),
        "attn": blocks.attn_init(ks[0], cfg, dtype),
    }
    if cfg.is_moe_layer(layer_idx) and layer_idx >= cfg.first_dense_layers:
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype)
        if cfg.dense_residual:
            p["mlp"] = blocks.mlp_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = blocks.mlp_init(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    n_pre = cfg.first_dense_layers
    n_scan = cfg.n_layers - n_pre
    params = {
        "embed": blocks.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": blocks.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = blocks.dense_init(ks[1], cfg.d_model, cfg.vocab, dtype)
    if n_pre:
        params["pre_layers"] = [
            _layer_init(k, cfg, i, dtype)
            for i, k in enumerate(jax.random.split(ks[2], n_pre))
        ]
    params["layers"] = jax.vmap(
        lambda k: _layer_init(k, cfg, n_pre, dtype)  # scan layers share structure
    )(jax.random.split(ks[3], n_scan))
    return params


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _prefix_entries(adapter_slice):
    """[(prefix_k, prefix_v, rows_mask_or_None), ...] for a per-layer
    adapter slice. A plain slice carries its prefix leaves at top level
    (mask None: every row attends the prefix). A MIXED-method slice (the
    serving engine's per-row-method compacted batch) nests one ``m<id>``
    sub-dict per bank; prefix banks carry per-row gathered leaves plus a
    ``prefix_rows`` membership mask that gates the prefix-attention add —
    rows of other methods stay bitwise untouched."""
    if not isinstance(adapter_slice, dict):
        return []
    out = []
    if "prefix_k" in adapter_slice:
        out.append((adapter_slice["prefix_k"], adapter_slice["prefix_v"],
                    adapter_slice.get("prefix_rows")))
    for name in sorted(adapter_slice):
        sub = adapter_slice[name]
        if isinstance(sub, dict) and "prefix_k" in sub:
            out.append((sub["prefix_k"], sub["prefix_v"],
                        sub.get("prefix_rows")))
    return out


def _apply_prefixes(attn, p_attn, cfg, h, adapter_slice, lin):
    """Fold every prefix adapter's branch into the attention output, gating
    mixed-method rows by membership (a where-select keeps non-member rows'
    bits exact — adding a zeroed branch would flip -0.0 to +0.0)."""
    for pk, pv, rows in _prefix_entries(adapter_slice):
        pfx = _prefix_attend(p_attn, cfg, h, (pk, pv), lin)
        if rows is None:
            attn = attn + pfx
        else:
            attn = jnp.where(rows.reshape(rows.shape + (1,) * (attn.ndim - 1)),
                             attn + pfx, attn)
    return attn


def _layer_forward(p, cfg: ModelConfig, x, positions, lin: LinearFns, adapter_slice,
                   *, moe_dispatch: str = "scatter", capacity_factor=None,
                   ext_kv=None):
    h = blocks.rmsnorm(p["ln1"], x)
    attn = blocks.mha_forward(p["attn"], cfg, h, positions, lin, ext_kv=ext_kv)
    attn = _apply_prefixes(attn, p["attn"], cfg, h, adapter_slice, lin)
    x = x + attn
    h = blocks.rmsnorm(p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = moe_lib.moe_forward(p["moe"], cfg, h, lin, dispatch=moe_dispatch,
                                     capacity_factor=capacity_factor)
        if "mlp" in p:  # Arctic dense residual in parallel
            y = y + blocks.mlp_forward(p["mlp"], h, lin)
    else:
        y = blocks.mlp_forward(p["mlp"], h, lin)
    return x + y, aux


def _prefix_attend(attn_p, cfg, h, prefix_kv, lin: LinearFns):
    """Prefix-tuning: queries additionally attend to learned virtual KV pairs.

    Added as a separate softmax branch (an additive approximation that keeps
    the base attention untouched — the client-side op of paper §3.2).
    prefix_k/v: [n_prefix, K, hd] shared across the batch, or — in the
    engine's compacted decode tick, where every row may belong to a
    different client — per-row [B, n_prefix, K, hd].
    """
    import math
    B, S, _ = h.shape
    hd, K, H = cfg.hd, cfg.n_kv_heads, cfg.n_heads
    G = H // K
    pk, pv = prefix_kv
    q = lin.dense(h, attn_p["wq"], None, "q").reshape(B, S, K, G, hd)
    if pk.ndim == 4:      # per-row prefixes (compacted multi-client batch)
        s = jnp.einsum("bskgh,bpkh->bkgsp", q, pk.astype(h.dtype)).astype(jnp.float32)
    else:
        s = jnp.einsum("bskgh,pkh->bkgsp", q, pk.astype(h.dtype)).astype(jnp.float32)
    s = s / math.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1).astype(h.dtype)
    if pk.ndim == 4:
        out = jnp.einsum("bkgsp,bpkh->bskgh", p, pv.astype(h.dtype)).reshape(B, S, H * hd)
    else:
        out = jnp.einsum("bkgsp,pkh->bskgh", p, pv.astype(h.dtype)).reshape(B, S, H * hd)
    return lin.dense(out, attn_p["wo"], None, "o") * 0.1


def _layer_decode(p, cfg: ModelConfig, x, cache, pos, lin: LinearFns, adapter_slice,
                  *, ring: bool = False, tbl=None, active=None):
    """One decoder layer's single-token step. The cache variant is derived
    from the cache leaves themselves: ``k_s`` present -> int8-quantized
    entries + scales; ``tbl`` given -> k/v are page pools addressed through
    the block table (paged and quantized compose)."""
    h = blocks.rmsnorm(p["ln1"], x)
    if "k_s" in cache:   # int8-quantized cache (beyond-paper decode variant)
        if tbl is not None:
            attn, ck, cks, cv, cvs = blocks.mha_decode_quant_paged(
                p["attn"], cfg, h, cache["k"], cache["k_s"], cache["v"],
                cache["v_s"], tbl, pos, lin, active=active)
        else:
            attn, ck, cks, cv, cvs = blocks.mha_decode_quant(
                p["attn"], cfg, h, cache["k"], cache["k_s"], cache["v"],
                cache["v_s"], pos, lin, ring=ring)
        new_cache = {"k": ck, "k_s": cks, "v": cv, "v_s": cvs}
    else:
        if tbl is not None:
            attn, ck, cv = blocks.mha_decode_paged(
                p["attn"], cfg, h, cache["k"], cache["v"], tbl, pos, lin,
                active=active)
        else:
            attn, ck, cv = blocks.mha_decode(p["attn"], cfg, h, cache["k"],
                                             cache["v"], pos, lin, ring=ring)
        new_cache = {"k": ck, "v": cv}
    attn = _apply_prefixes(attn, p["attn"], cfg, h, adapter_slice, lin)
    x = x + attn
    h = blocks.rmsnorm(p["ln2"], x)
    if "moe" in p:
        y, _ = moe_lib.moe_forward(p["moe"], cfg, h, lin)
        if "mlp" in p:
            y = y + blocks.mlp_forward(p["mlp"], h, lin)
    else:
        y = blocks.mlp_forward(p["mlp"], h, lin)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def _adapter_layers(adapter, cfg):
    """Split an adapter tree into (scan-stacked part, pre-layer list part)."""
    if adapter is None:
        return None, None
    lay = adapter.get("layers") if isinstance(adapter, dict) else None
    pre = adapter.get("pre_layers") if isinstance(adapter, dict) else None
    return lay, pre


def embed_tokens(cfg, params, tokens, lin: LinearFns):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return x


def lm_head(cfg, params, x, lin: LinearFns):
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    return lin.dense(x, w, None, "lm_head")


def forward(cfg: ModelConfig, params, batch, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, remat: bool = True, moe_dispatch: str = "scatter",
            capacity_factor=None):
    """Training / scoring forward. batch: tokens [B,S] (+ 'img_embed' [B,Ti,d]
    for VLM). Returns (logits [B,S_total,V], aux_loss).

    capacity_factor=None keeps MoE dispatch drop-free (exact); training
    callers pass a float to trade exactness for bounded expert buffers."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens, ctx.top)
    if cfg.arch == VLM and "img_embed" in batch:
        img = batch["img_embed"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)            # image prefix, then text
    S_total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_total)[None, :], (B, S_total))

    scan_adapters, pre_adapters = _adapter_layers(adapter, cfg)
    aux_total = jnp.zeros((), jnp.float32)

    for i, p in enumerate(params.get("pre_layers", [])):
        ad = pre_adapters[i] if pre_adapters is not None else None
        x, aux = _layer_forward(p, cfg, x, positions, ctx.for_layer(ad), ad,
                                moe_dispatch=moe_dispatch,
                                capacity_factor=capacity_factor)
        aux_total += aux

    def body(carry, layer_in):
        x, aux_acc = carry
        p, ad = layer_in
        x, aux = _layer_forward(p, cfg, x, positions, ctx.for_layer(ad), ad,
                                moe_dispatch=moe_dispatch,
                                capacity_factor=capacity_factor)
        return (x, aux_acc + aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                     (params["layers"], scan_adapters))
    x = blocks.rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params, x, ctx.top)
    return logits, aux_total


def default_block_table(batch_size: int, max_seq: int, page_block: int,
                        pool_pages: int = 0):
    """(n_blocks, pool size, initial table) for a paged cache. With an
    auto-sized pool (pool_pages=0) the pool fully provisions every slot and
    the table is the identity layout — a standalone paged cache then works
    without any allocator (slot b owns pages [b*n_blocks, (b+1)*n_blocks)).
    An explicit pool size means a caller-managed table: it starts zeroed and
    the owner (the serving engine's page allocator) assigns pages."""
    n_blocks = -(-max_seq // page_block)
    if pool_pages:
        return n_blocks, pool_pages, jnp.zeros((batch_size, n_blocks), jnp.int32)
    tbl = jnp.arange(batch_size * n_blocks, dtype=jnp.int32).reshape(
        batch_size, n_blocks)
    return n_blocks, batch_size * n_blocks, tbl


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None,
               *, window: int = 0, quant: bool = False, page_block: int = 0,
               pool_pages: int = 0):
    """window > 0 -> ring-buffer cache of that depth (sliding-window archs can
    decode contexts far beyond the cache size; use decode_step(ring=True)).
    quant=True -> int8 KV entries + per-head f32 scales (halves the HBM
    bytes of the decode cache read).
    page_block > 0 -> paged cache: K/V live in a page pool shared by the
    batch's slots ([pool_pages, page_block, K, hd] per layer) addressed
    through a per-slot block table (cache key ``block_tbl``); composes with
    quant. pool_pages=0 fully provisions (batch * ceil(max_seq/block))."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_pre = cfg.first_dense_layers
    n_scan = cfg.n_layers - n_pre
    K, hd = cfg.n_kv_heads, cfg.hd
    if page_block:
        assert not window, "paged cache subsumes the ring-buffer variant"
        n_blocks, P, tbl = default_block_table(batch_size, max_seq,
                                               page_block, pool_pages)
        kv_shape = (P, page_block)
    else:
        T = min(window, max_seq) if window else max_seq
        kv_shape = (batch_size, T)
    if quant:
        def layer_kv(lead=()):
            return {"k": jnp.zeros(lead + kv_shape + (K, hd), jnp.int8),
                    "k_s": jnp.zeros(lead + kv_shape + (K, 1), jnp.float32),
                    "v": jnp.zeros(lead + kv_shape + (K, hd), jnp.int8),
                    "v_s": jnp.zeros(lead + kv_shape + (K, 1), jnp.float32)}
    else:
        def layer_kv(lead=()):
            return {"k": jnp.zeros(lead + kv_shape + (K, hd), dtype),
                    "v": jnp.zeros(lead + kv_shape + (K, hd), dtype)}
    cache = {
        "layers": layer_kv((n_scan,)),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }
    if page_block:
        cache["block_tbl"] = tbl
    if n_pre:
        cache["pre_layers"] = [layer_kv() for _ in range(n_pre)]
    return cache


def decode_step(cfg: ModelConfig, params, cache, token, ctx: LinCtx = DEFAULT_CTX,
                adapter=None, *, ring: bool = False, active=None):
    """One decode step. token [B] int32. Returns (logits [B,V], new_cache).
    ring=True: the KV cache is a ring buffer (see init_cache(window=...)).
    For a paged cache (``block_tbl`` present) ``active`` [B] bool gates the
    pool writes: inactive slots leave the shared page pool untouched (their
    pos/logits are discarded by the caller's merge instead)."""
    B = token.shape[0]
    pos = cache["pos"]
    tbl = cache.get("block_tbl")
    x = embed_tokens(cfg, params, token[:, None], ctx.top)

    scan_adapters, pre_adapters = _adapter_layers(adapter, cfg)
    new_pre = []
    for i, p in enumerate(params.get("pre_layers", [])):
        ad = pre_adapters[i] if pre_adapters is not None else None
        x, c = _layer_decode(p, cfg, x, cache["pre_layers"][i], pos, ctx.for_layer(ad), ad,
                             ring=ring, tbl=tbl, active=active)
        new_pre.append(c)

    # The layer-stacked cache rides the scan as CARRY, not as xs/ys: scanned
    # ys re-materialize their whole stacked buffer every step, which made
    # each decode tick copy the entire KV cache / page pool — a per-tick
    # cost proportional to bank size. As a carry, XLA aliases the buffer
    # through the loop (and, with the serving engine's donated cache
    # argument, across ticks) so a tick only touches the lanes it writes.
    #
    # PAGED caches go one step further: the layer axis is fused into the
    # page axis ([L, P, ..] -> [L*P, ..], a free reshape) and each layer
    # addresses its own page range through an offset block table — the pool
    # is never even sliced per layer, so decode-tick HBM traffic is the
    # token writes + the pages the tables name, nothing else.
    if tbl is not None:
        Pl = jax.tree.leaves(cache["layers"])[0].shape[1]
        fused = jax.tree.map(
            lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]),
            cache["layers"])

        def body(carry, layer_in):
            x, pools, i = carry
            p, ad = layer_in
            x, pools = _layer_decode(p, cfg, x, pools, pos, ctx.for_layer(ad),
                                     ad, ring=ring, tbl=tbl + i * Pl,
                                     active=active)
            return (x, pools, i + 1), None

        (x, fused, _), _ = jax.lax.scan(
            body, (x, fused, jnp.int32(0)), (params["layers"], scan_adapters))
        new_layers = jax.tree.map(
            lambda t, old: t.reshape(old.shape), fused, cache["layers"])
    else:
        def body(carry, layer_in):
            x, layers, i = carry
            p, ad = layer_in
            c = jax.tree.map(lambda t: jax.lax.dynamic_index_in_dim(
                t, i, 0, keepdims=False), layers)
            x, c = _layer_decode(p, cfg, x, c, pos, ctx.for_layer(ad), ad,
                                 ring=ring, tbl=None, active=active)
            layers = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full, one.astype(full.dtype), i, 0), layers, c)
            return (x, layers, i + 1), None

        (x, new_layers, _), _ = jax.lax.scan(
            body, (x, cache["layers"], jnp.int32(0)),
            (params["layers"], scan_adapters))
    x = blocks.rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params, x, ctx.top)[:, 0]
    new_cache = {"layers": new_layers, "pos": pos + 1}
    if tbl is not None:
        new_cache["block_tbl"] = tbl
    if new_pre:
        new_cache["pre_layers"] = new_pre
    return logits, new_cache


def prefill(cfg: ModelConfig, params, batch, cache, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, lengths=None, starts=None, ext_blocks=0):
    """Prefill: forward over the prompt, filling the KV cache.

    Implemented as forward + bulk cache write (projections recomputed per
    layer would double base-linear work; instead we run the layer bodies and
    capture K/V via the same decode-path projections).

    ``lengths`` ([B] int32 or scalar, optional) supports right-padded
    prompts: logits are gathered at each row's last real position and the
    returned ``pos`` starts decode there. On the dense path, stale pad K/V
    beyond a row's length is safe — decode writes slot ``pos`` before
    attending to it, so a pad slot is overwritten in the step that would
    first read it. On the paged path (``block_tbl`` in the cache) pads are
    never written at all: the K/V scatter through the block table is bounded
    by the row's true length, so only pages covering real tokens are touched
    (a row with length 0 writes nothing — how the engine's masked prefill
    keeps non-admitted slots' pages untouched). Quantized caches (``k_s``
    leaves) get per-head int8 quantization at capture time, matching what
    decode would have written.

    ``starts`` ([B] int32, paged caches only) makes this a SUFFIX prefill:
    each row already holds ``starts[b]`` tokens of K/V in the pages its
    block table names (shared-prefix pages mapped at admission —
    docs/prefix_cache.md), this call's tokens are logical positions
    ``starts[b] .. starts[b]+lengths[b]-1``, and the first ``ext_blocks``
    table entries per row are gathered BEFORE the layer scan and attended
    to as read-only external K/V lanes. ``ext_blocks`` is static (a jit
    bucket); rows with fewer cached tokens mask their unused ext lanes by
    position, so ext_blocks=0 with starts of zeros is the full prefill
    program. Requires an unquantized paged cache when ext_blocks > 0
    (shared pages hold exact K/V; int8 scales don't round-trip).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens, ctx.top)
    if cfg.arch == VLM and "img_embed" in batch:
        x = jnp.concatenate([batch["img_embed"].astype(x.dtype), x], axis=1)
    S_total = x.shape[1]
    prefix = S_total - S                          # leading image tokens (VLM)
    scan_adapters, pre_adapters = _adapter_layers(adapter, cfg)
    tbl = cache.get("block_tbl")
    if starts is None:
        positions = jnp.broadcast_to(jnp.arange(S_total)[None, :], (B, S_total))
    else:
        if tbl is None:
            raise ValueError("suffix prefill (starts=) needs a paged cache")
        starts = jnp.broadcast_to(jnp.asarray(starts, jnp.int32), (B,))
        positions = starts[:, None] + jnp.arange(S_total, dtype=jnp.int32)[None, :]
    if lengths is None:
        wlen = None                               # write all S_total positions
    else:
        wlen = prefix + jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    epos = None
    if ext_blocks:
        if starts is None:
            raise ValueError("ext_blocks needs starts (suffix prefill)")
        if "k_s" in cache["layers"]:
            raise ValueError("shared-prefix prefill needs an unquantized "
                             "paged cache (int8 K/V doesn't round-trip)")
        blk = jax.tree.leaves(cache["layers"])[0].shape[2]
        etbl = tbl[:, :ext_blocks]                # [B, E] page ids
        lane = jnp.arange(ext_blocks * blk, dtype=jnp.int32)[None, :]
        # lanes at/after a row's start are not cached prefix: push their
        # position out of every causal mask (exact-zero softmax weight)
        epos = jnp.where(lane < starts[:, None], lane, jnp.int32(1 << 30))

        def egather(leaf):    # [L, P, blk, K, hd] -> [L, B, E*blk, K, hd]
            g = leaf[:, etbl]
            return g.reshape(g.shape[:1] + (B, ext_blocks * blk) + g.shape[4:])

        def egather_pre(leaf):  # [P, blk, K, hd] -> [B, E*blk, K, hd]
            g = leaf[etbl]
            return g.reshape((B, ext_blocks * blk) + g.shape[3:])

    def capture_layer(p, x, lin, ad, ext=None):
        """Run one layer, also returning its K/V for the cache."""
        h = blocks.rmsnorm(p["ln1"], x)
        hd, K = cfg.hd, cfg.n_kv_heads
        k = lin.dense(h, p["attn"]["wk"], p["attn"].get("bk"), "k").reshape(B, S_total, K, hd)
        v = lin.dense(h, p["attn"]["wv"], p["attn"].get("bv"), "v").reshape(B, S_total, K, hd)
        if cfg.qk_norm:
            k = blocks.head_rmsnorm(p["attn"]["k_norm"], k)
        if cfg.rope_theta > 0:
            k = blocks.apply_rope(k, positions, cfg.rope_theta)
        ext_kv = None if ext is None else (ext[0], ext[1], epos)
        x, _ = _layer_forward(p, cfg, x, positions, lin, ad, ext_kv=ext_kv)
        return x, k, v

    def write_kv(c, k, v, layer_tbl=None):
        """Write captured K/V [B, S_total, K, hd] into one layer's cache
        slice, handling every layout: dense / paged x full / int8.
        ``layer_tbl`` carries the per-layer page offsets on the fused
        paged path (see below)."""
        if "k_s" in c:
            parts = zip(("k", "k_s", "v", "v_s"),
                        blocks.quantize_head(k) + blocks.quantize_head(v))
        else:
            parts = (("k", k), ("v", v))
        if tbl is not None:
            return {n: blocks.paged_prefill_write(
                c[n], tbl if layer_tbl is None else layer_tbl, val, wlen,
                start=starts)
                    for n, val in parts}
        return {n: jax.lax.dynamic_update_slice(c[n], val.astype(c[n].dtype),
                                                (0, 0, 0, 0))
                for n, val in parts}

    new_pre = []
    for i, p in enumerate(params.get("pre_layers", [])):
        ad = pre_adapters[i] if pre_adapters is not None else None
        ext = None
        if ext_blocks:
            cp = cache["pre_layers"][i]
            ext = (egather_pre(cp["k"]), egather_pre(cp["v"]))
        x, k, v = capture_layer(p, x, ctx.for_layer(ad), ad, ext)
        new_pre.append(write_kv(cache["pre_layers"][i], k, v))

    # Paged pools ride the scan as CARRY with the layer axis fused into the
    # page axis, exactly like decode_step: scanning the layer-stacked pool
    # as xs/ys re-materializes the WHOLE pool every prefill — one pool copy
    # per ADMISSION, a cost proportional to bank size, not prompt length.
    # As a fused carry ([L, P, ..] -> [L*P, ..], a free reshape; each layer
    # writes through an offset block table) the admission only touches the
    # pages the prompt actually fills, and the engine's donated cache
    # buffer updates in place (no-copy assertion in
    # tests/test_paged_kvcache.py).
    if tbl is not None:
        Pl = jax.tree.leaves(cache["layers"])[0].shape[1]
        fused = jax.tree.map(
            lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]),
            cache["layers"])

        if ext_blocks:
            # gather every layer's shared-prefix lanes BEFORE the scan,
            # from the unfused input leaves, and ride them as xs: the scan
            # carry (the donated pool) is written by the same dispatch, so
            # reading prefix pages through it would race the suffix writes
            ext_k = egather(cache["layers"]["k"])
            ext_v = egather(cache["layers"]["v"])

            def body(carry, layer_in):
                x, pools, i = carry
                p, ad, ek, ev = layer_in
                x, k, v = capture_layer(p, x, ctx.for_layer(ad), ad, (ek, ev))
                pools = write_kv(pools, k, v, layer_tbl=tbl + i * Pl)
                return (x, pools, i + 1), None

            xs = (params["layers"], scan_adapters, ext_k, ext_v)
        else:
            def body(carry, layer_in):
                x, pools, i = carry
                p, ad = layer_in
                x, k, v = capture_layer(p, x, ctx.for_layer(ad), ad)
                pools = write_kv(pools, k, v, layer_tbl=tbl + i * Pl)
                return (x, pools, i + 1), None

            xs = (params["layers"], scan_adapters)

        (x, fused, _), _ = jax.lax.scan(
            jax.checkpoint(body), (x, fused, jnp.int32(0)), xs)
        new_layers = jax.tree.map(lambda t, old: t.reshape(old.shape),
                                  fused, cache["layers"])
    else:
        def body(x, layer_in):
            p, c, ad = layer_in
            x, k, v = capture_layer(p, x, ctx.for_layer(ad), ad)
            return x, write_kv(c, k, v)

        x, new_layers = jax.lax.scan(
            jax.checkpoint(body), x,
            (params["layers"], cache["layers"], scan_adapters))
    x = blocks.rmsnorm(params["final_norm"], x)
    if lengths is None:
        logits = lm_head(cfg, params, x[:, -1:], ctx.top)[:, 0]
        pos = jnp.full((B,), S_total, jnp.int32)
    else:
        lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
        idx = prefix + lengths - 1
        xg = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = lm_head(cfg, params, xg, ctx.top)[:, 0]
        pos = prefix + lengths
    if starts is not None:      # decode resumes after prefix + this suffix
        pos = starts + pos
    new_cache = {"layers": new_layers, "pos": pos}
    if tbl is not None:
        new_cache["block_tbl"] = tbl
    if new_pre:
        new_cache["pre_layers"] = new_pre
    return logits, new_cache
