"""Mamba (S6) block for the Jamba hybrid — TPU-adapted selective scan.

TPU adaptation (DESIGN.md §2): the reference implementation is a fused CUDA
selective-scan kernel streaming (dA, dBx) through SRAM. There is no TPU
analogue of that kernel's warp-level pipelining; the TPU-idiomatic equivalent
is a *chunked associative scan*: split time into chunks, materialize the
per-step transition (a_t, b_t) only chunk-by-chunk, run an intra-chunk
``associative_scan`` (parallel, MXU/VPU friendly) and carry the [B, ED, N]
state across chunks with ``lax.scan``. The chunk body is checkpointed so
training memory is O(S/chunk * state) instead of O(S * state).

Base/client split: in_proj, x_proj, dt_proj, out_proj are frozen base linears
(LinearFns); the depthwise conv, A/D parameters and the scan itself are
client-side stateful ops (paper §3.2 rule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import LinearFns, dense_init


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    ed = cfg.mamba_expand * d
    N = cfg.d_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (ed, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * ed, dtype),          # -> x, z
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, ed), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((ed,), dtype),
        "x_proj": dense_init(ks[2], ed, dt_rank + 2 * N, dtype), # -> dt, B, C
        "dt_proj": dense_init(ks[3], dt_rank, ed, dtype),
        "dt_bias": jnp.zeros((ed,), jnp.float32),
        "A_log": jnp.log(A),                                     # [ED,N] f32
        "D": jnp.ones((ed,), jnp.float32),
        "out_proj": dense_init(ks[4], ed, d, dtype),
    }


def selective_scan(x, dt, Bc, Cc, A, D, h0, chunk: int = 256):
    """Selective SSM.

    x [B,S,ED]; dt [B,S,ED] (softplus'd); Bc, Cc [B,S,N]; A [ED,N] (negative);
    D [ED]; h0 [B,ED,N]. Returns (y [B,S,ED], h_final).

    Discretization (ZOH): a_t = exp(dt_t * A);  b_t = dt_t * B_t * x_t.
    """
    B, S, ED = x.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    n = S // chunk

    def chunk_body(h, inp):
        xc, dtc, Bcc, Ccc = inp                                  # [chunk,B,...] f32
        a = jnp.exp(dtc[..., None] * A)                          # [c,B,ED,N]
        b = dtc[..., None] * Bcc[:, :, None, :] * xc[..., None]  # [c,B,ED,N]

        # intra-chunk parallel scan of the linear recurrence
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=0)
        hs = a_sc * h[None] + b_sc                               # [c,B,ED,N]
        y = jnp.einsum("cbdn,cbn->cbd", hs, Ccc)
        return hs[-1], y

    seq = lambda t: t.astype(jnp.float32).reshape(t.shape[0], n, chunk, *t.shape[2:]) \
                     .transpose(1, 2, 0, *range(3, t.ndim + 1))
    h, y = jax.lax.scan(jax.checkpoint(chunk_body), h0.astype(jnp.float32),
                        (seq(x), seq(dt), seq(Bc), seq(Cc)))
    y = y.reshape(n * chunk, B, ED).transpose(1, 0, 2)
    y = y + x.astype(jnp.float32) * D
    return y, h


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x [B,S,ED]; w [K,ED]; conv_state [B,K-1,ED] or None."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                       # [B,S+K-1,ED]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out + b, new_state


def mamba_forward(p, cfg, x, lin: LinearFns, state, *, path_prefix="", chunk: int = 256):
    """x [B,S,d]; state = dict(h [B,ED,N] f32, conv [B,K-1,ED]) or None (zeros).

    Returns (y [B,S,d], new_state).
    """
    Bsz, S, d = x.shape
    ed = cfg.mamba_expand * d
    N = cfg.d_state
    dt_rank = max(1, d // 16)
    if state is None:
        state = {
            "h": jnp.zeros((Bsz, ed, N), jnp.float32),
            "conv": jnp.zeros((Bsz, cfg.d_conv - 1, ed), jnp.float32),
        }

    xz = lin.dense(x, p["in_proj"], None, path_prefix + "in_proj")
    xi, z = jnp.split(xz, 2, axis=-1)                            # [B,S,ED] each
    xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], state["conv"])
    xi = jax.nn.silu(xi)

    dbc = lin.dense(xi, p["x_proj"], None, path_prefix + "x_proj")
    dt, Bc, Cc = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = lin.dense(dt, p["dt_proj"], None, path_prefix + "dt_proj")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    A = -jnp.exp(p["A_log"])                                     # [ED,N], negative
    y, h = selective_scan(xi, dt, Bc, Cc, A, p["D"], state["h"], chunk=chunk)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = lin.dense(y, p["out_proj"], None, path_prefix + "out_proj")
    return out, {"h": h, "conv": conv_state}
