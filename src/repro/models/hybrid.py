"""Jamba-style hybrid: Mamba + attention 1:7 interleave, MoE every 2nd layer.

Layer pattern (period = cfg.attn_every, Jamba: 8): layers 0..6 are Mamba,
layer 7 is attention; MoE FFN on odd layers within each period (Jamba: 16e
top-2 every 2). The period is the scan unit: we scan over n_layers/period
"groups", each group's 8 sublayers unrolled (static structure), params
stacked over groups. State: per-group mamba states + one KV cache per group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks, mamba as mamba_lib, moe as moe_lib
from repro.models.transformer import (LinCtx, DEFAULT_CTX, default_block_table,
                                      embed_tokens, lm_head)


def _sub_is_attn(cfg, j):           # j = index within period
    return j == cfg.attn_every - 1


def _sub_is_moe(cfg, j):
    return cfg.n_experts > 0 and (j % cfg.moe_every == cfg.moe_offset)


def _group_init(key, cfg: ModelConfig, dtype):
    period = cfg.attn_every
    subs = []
    ks = jax.random.split(key, period)
    for j in range(period):
        k1, k2 = jax.random.split(ks[j])
        p = {"ln1": blocks.rmsnorm_init(cfg.d_model, dtype),
             "ln2": blocks.rmsnorm_init(cfg.d_model, dtype)}
        if _sub_is_attn(cfg, j):
            p["attn"] = blocks.attn_init(k1, cfg, dtype)
        else:
            p["mamba"] = mamba_lib.mamba_init(k1, cfg, dtype)
        if _sub_is_moe(cfg, j):
            p["moe"] = moe_lib.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = blocks.mlp_init(k2, cfg, dtype)
        subs.append(p)
    return {f"sub{j}": subs[j] for j in range(period)}


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    assert cfg.n_layers % cfg.attn_every == 0
    n_groups = cfg.n_layers // cfg.attn_every
    ks = jax.random.split(key, 3)
    return {
        "embed": blocks.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": blocks.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": blocks.dense_init(ks[1], cfg.d_model, cfg.vocab, dtype),
        "groups": jax.vmap(lambda k: _group_init(k, cfg, dtype))(
            jax.random.split(ks[2], n_groups)),
    }


def _zero_group_state(cfg: ModelConfig, B: int, kv_lead, dtype):
    """kv_lead: leading dims of the attention K/V tensors — (B, T) for the
    dense layout, (pool_pages, page_block) for the paged layout (pool shared
    across the B slots). Mamba/conv state is per-slot either way."""
    ed = cfg.mamba_expand * cfg.d_model
    st = {}
    for j in range(cfg.attn_every):
        if _sub_is_attn(cfg, j):
            st[f"sub{j}"] = {
                "k": jnp.zeros(kv_lead + (cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros(kv_lead + (cfg.n_kv_heads, cfg.hd), dtype),
            }
        else:
            st[f"sub{j}"] = {
                "h": jnp.zeros((B, ed, cfg.d_state), jnp.float32),
                "conv": jnp.zeros((B, cfg.d_conv - 1, ed), jnp.float32),
            }
    return st


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None,
               *, page_block: int = 0, pool_pages: int = 0):
    """page_block > 0 pages the attention sublayers' KV (per-group page
    pools + one shared ``block_tbl``); Mamba state is O(1) and stays dense."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_groups = cfg.n_layers // cfg.attn_every
    tbl = None
    if page_block:
        _, P, tbl = default_block_table(batch_size, max_seq, page_block,
                                        pool_pages)
        kv_lead = (P, page_block)
    else:
        kv_lead = (batch_size, max_seq)
    one = _zero_group_state(cfg, batch_size, kv_lead, dtype)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one)
    cache = {"groups": stacked, "pos": jnp.zeros((batch_size,), jnp.int32)}
    if tbl is not None:
        cache["block_tbl"] = tbl
    return cache


def _group_forward(gp, cfg, x, positions, lin, state, *, capture_kv: bool,
                   moe_dispatch: str = "scatter", capacity_factor=None,
                   tbl=None, lengths=None):
    """Run one period of sublayers. state: group state dict (or None for
    training). Returns (x, aux, new_state). capacity_factor=None keeps the
    MoE sublayers drop-free — required for prefill/decode exactness (drops
    depend on tokens-in-flight, which differ between the two paths).
    ``tbl`` switches the K/V capture to the paged scatter (bounded by
    ``lengths`` so pads / zero-length rows never touch the shared pool)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_state = {}
    B, S, _ = x.shape
    for j in range(cfg.attn_every):
        p = gp[f"sub{j}"]
        st = state[f"sub{j}"] if state is not None else None
        h = blocks.rmsnorm(p["ln1"], x)
        if "attn" in p:
            y = blocks.mha_forward(p["attn"], cfg, h, positions, lin)
            if capture_kv:
                hd, K = cfg.hd, cfg.n_kv_heads
                k = lin.dense(h, p["attn"]["wk"], None, "k").reshape(B, S, K, hd)
                v = lin.dense(h, p["attn"]["wv"], None, "v").reshape(B, S, K, hd)
                k = blocks.apply_rope(k, positions, cfg.rope_theta)
                if tbl is not None:
                    ck = blocks.paged_prefill_write(st["k"], tbl, k, lengths)
                    cv = blocks.paged_prefill_write(st["v"], tbl, v, lengths)
                else:
                    ck = jax.lax.dynamic_update_slice(st["k"], k.astype(st["k"].dtype), (0, 0, 0, 0))
                    cv = jax.lax.dynamic_update_slice(st["v"], v.astype(st["v"].dtype), (0, 0, 0, 0))
                new_state[f"sub{j}"] = {"k": ck, "v": cv}
            elif st is not None:
                new_state[f"sub{j}"] = st
        else:
            mamba_state = st if (st is not None and "h" in st) else None
            y, mst = mamba_lib.mamba_forward(p["mamba"], cfg, h, lin, mamba_state)
            new_state[f"sub{j}"] = mst if st is not None else None
        x = x + y
        h = blocks.rmsnorm(p["ln2"], x)
        if "moe" in p:
            y, aux = moe_lib.moe_forward(p["moe"], cfg, h, lin,
                                         dispatch=moe_dispatch,
                                         capacity_factor=capacity_factor)
            aux_total += aux
        else:
            y = blocks.mlp_forward(p["mlp"], h, lin)
        x = x + y
    return x, aux_total, (new_state if state is not None else None)


def forward(cfg: ModelConfig, params, batch, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, remat: bool = True, moe_dispatch: str = "scatter",
            capacity_factor=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens, ctx.top)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    scan_adapters = adapter.get("groups") if adapter else None

    def body(carry, grp_in):
        x, aux_acc = carry
        gp, ad = grp_in
        x, aux, _ = _group_forward(gp, cfg, x, positions, ctx.for_layer(ad), None,
                                   capture_kv=False, moe_dispatch=moe_dispatch,
                                   capacity_factor=capacity_factor)
        return (x, aux_acc + aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["groups"], scan_adapters))
    x = blocks.rmsnorm(params["final_norm"], x)
    return lm_head(cfg, params, x, ctx.top), aux


def prefill(cfg: ModelConfig, params, batch, cache, ctx: LinCtx = DEFAULT_CTX,
            adapter=None, *, lengths=None):
    """``lengths`` gathers logits at each row's last real position and starts
    ``pos`` there. NOTE: unlike pure-attention families, the Mamba sublayers
    carry recurrent state through padded positions — callers must pass
    prompts at their true length (no right-padding) for exact decode."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens, ctx.top)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    scan_adapters = adapter.get("groups") if adapter else None
    tbl = cache.get("block_tbl")
    wlen = None if lengths is None else jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (B,))

    # Paged attention-sublayer pools ride the scan as CARRY, fused
    # [G, P, ..] -> [G*P, ..] with per-group table offsets (mirroring
    # decode_step): as xs/ys the whole pool was re-materialized once per
    # ADMISSION. Mamba/conv state is per-slot and small; it stays xs/ys.
    grp = cache["groups"]
    pool_subs = {name for name, sub in grp.items()
                 if tbl is not None and "k" in sub}
    pools0 = {n: jax.tree.map(
        lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]), grp[n])
        for n in pool_subs}
    states0 = {n: grp[n] for n in grp if n not in pool_subs}
    Pg = (jax.tree.leaves(grp[next(iter(pool_subs))])[0].shape[1]
          if pool_subs else 0)

    def body(carry, grp_in):
        x, pools, i = carry
        gp, st_sliced, ad = grp_in
        st = dict(pools)
        st.update(st_sliced)
        x, _, new_st = _group_forward(gp, cfg, x, positions, ctx.for_layer(ad), st,
                                      capture_kv=True,
                                      tbl=None if tbl is None else tbl + i * Pg,
                                      lengths=wlen)
        pools = {n: new_st[n] for n in pools}
        return (x, pools, i + 1), {n: new_st[n] for n in st_sliced}

    (x, pools, _), states = jax.lax.scan(
        jax.checkpoint(body), (x, pools0, jnp.int32(0)),
        (params["groups"], states0, scan_adapters))
    new_groups = {n: (jax.tree.map(lambda t, old: t.reshape(old.shape),
                                   pools[n], grp[n]) if n in pools
                      else states[n])
                  for n in grp}
    x = blocks.rmsnorm(params["final_norm"], x)
    if lengths is None:
        logits = lm_head(cfg, params, x[:, -1:], ctx.top)[:, 0]
        pos = jnp.full((B,), S, jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
        xg = jnp.take_along_axis(x, (pos - 1)[:, None, None], axis=1)
        logits = lm_head(cfg, params, xg, ctx.top)[:, 0]
    new_cache = {"groups": new_groups, "pos": pos}
    if tbl is not None:
        new_cache["block_tbl"] = tbl
    return logits, new_cache


def _group_decode(gp, cfg, x, state, pos, lin, tbl=None, active=None):
    new_state = {}
    for j in range(cfg.attn_every):
        p = gp[f"sub{j}"]
        st = state[f"sub{j}"]
        h = blocks.rmsnorm(p["ln1"], x)
        if "attn" in p:
            if tbl is not None:
                y, ck, cv = blocks.mha_decode_paged(p["attn"], cfg, h, st["k"],
                                                    st["v"], tbl, pos, lin,
                                                    active=active)
            else:
                y, ck, cv = blocks.mha_decode(p["attn"], cfg, h, st["k"], st["v"], pos, lin)
            new_state[f"sub{j}"] = {"k": ck, "v": cv}
        else:
            y, mst = mamba_lib.mamba_forward(p["mamba"], cfg, h, lin, st)
            new_state[f"sub{j}"] = mst
        x = x + y
        h = blocks.rmsnorm(p["ln2"], x)
        if "moe" in p:
            y, _ = moe_lib.moe_forward(p["moe"], cfg, h, lin)
        else:
            y = blocks.mlp_forward(p["mlp"], h, lin)
        x = x + y
    return x, new_state


def decode_step(cfg: ModelConfig, params, cache, token, ctx: LinCtx = DEFAULT_CTX,
                adapter=None, *, active=None):
    B = token.shape[0]
    pos = cache["pos"]
    tbl = cache.get("block_tbl")
    x = embed_tokens(cfg, params, token[:, None], ctx.top)
    scan_adapters = adapter.get("groups") if adapter else None

    # Group state rides the scan as CARRY (see transformer.decode_step for
    # the layout rationale): paged attention-sublayer pools are fused
    # [G, P, ..] -> [G*P, ..] and addressed per group through offset block
    # tables (never sliced); Mamba state uses indexed in-place carry
    # updates.
    grp = cache["groups"]
    pool_subs = {name for name, sub in grp.items()
                 if tbl is not None and "k" in sub}
    pools0 = {n: jax.tree.map(
        lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]), grp[n])
        for n in pool_subs}
    states0 = {n: grp[n] for n in grp if n not in pool_subs}
    Pg = (jax.tree.leaves(grp[next(iter(pool_subs))])[0].shape[1]
          if pool_subs else 0)

    def body(carry, grp_in):
        x, pools, states, i = carry
        gp, ad = grp_in
        st = dict(pools)
        st.update({n: jax.tree.map(lambda t: jax.lax.dynamic_index_in_dim(
            t, i, 0, keepdims=False), sub) for n, sub in states.items()})
        x, new_st = _group_decode(gp, cfg, x, st, pos, ctx.for_layer(ad),
                                  tbl=None if tbl is None else tbl + i * Pg,
                                  active=active)
        pools = {n: new_st[n] for n in pools}
        states = {n: jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_index_in_dim(
                full, one.astype(full.dtype), i, 0), sub, new_st[n])
            for n, sub in states.items()}
        return (x, pools, states, i + 1), None

    (x, pools, states, _), _ = jax.lax.scan(
        body, (x, pools0, states0, jnp.int32(0)),
        (params["groups"], scan_adapters))
    new_groups = {n: (jax.tree.map(lambda t, old: t.reshape(old.shape),
                                   pools[n], grp[n]) if n in pools
                      else states[n])
                  for n in grp}
    x = blocks.rmsnorm(params["final_norm"], x)
    logits = lm_head(cfg, params, x, ctx.top)[:, 0]
    new_cache = {"groups": new_groups, "pos": pos + 1}
    if tbl is not None:
        new_cache["block_tbl"] = tbl
    return logits, new_cache
