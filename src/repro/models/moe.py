"""Mixture-of-Experts FFN with capacity-based dispatch (TPU-idiomatic).

Supports DeepSeek-MoE fine-grained experts (shared + routed top-k) and
Arctic's dense-residual-in-parallel-with-MoE. Expert weights are frozen base
parameters (expert-parallel over the `model` mesh axis); the router is a
client-tunable layer when targeted by an adapter.

Two dispatch strategies:
  * ``scatter`` (default): scatter-add tokens into per-expert capacity
    buffers, gather-combine back. Intermediates are O(E*cap*d) — feasible at
    1M-token global batches. The GPU all-to-all of expert parallelism becomes
    the collective XLA inserts at the (expert-sharded buffer) boundary.
  * ``einsum``: classic one-hot dispatch/combine einsums. O(T*k*E*cap)
    intermediate — only viable for small shapes; kept as the reference oracle
    (tests assert both paths agree).

Expert matmuls go through ``LinearFns.expert`` so the Symbiosis base executor
intercepts them like any other frozen base layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.blocks import LinearFns, dense_init


def moe_init(key, cfg, dtype):
    E, d, fe = cfg.n_experts, cfg.d_model, cfg.ffn_hidden
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept f32 for stable softmax
        "experts": {
            "gate": jax.vmap(lambda k: dense_init(k, d, fe, dtype))(jax.random.split(ks[1], E)),
            "up": jax.vmap(lambda k: dense_init(k, d, fe, dtype))(jax.random.split(ks[2], E)),
            "down": jax.vmap(lambda k: dense_init(k, fe, d, dtype))(jax.random.split(ks[3], E)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = blocks.mlp_init(ks[4], cfg, dtype, d_ff=fe * cfg.n_shared_experts)
    return p


def _capacity(n_tokens: int, E: int, k: int, factor) -> int:
    """factor=None -> drop-free: top-k indices are distinct, so one expert
    receives at most one slot per token; cap = n_tokens never drops. This is
    the *exact* mode inference paths rely on (prefill/decode token counts
    differ, so any capacity tied to tokens-in-flight breaks the paper's
    exact-output property) — and what the serving engine's paged-vs-dense
    byte-identity bar inherits for MoE clients: dispatch depends only on
    token values, never on the KV layout behind the attention sublayers.
    A float factor is the lossy training knob."""
    cap = n_tokens if factor is None else int(n_tokens * k / E * factor)
    return max(8, ((cap + 7) // 8) * 8)  # pad to 8 for clean tiling


def _route(params, cfg, xt, lin, path_prefix):
    """Router: returns (gate_vals [T,k], idx [T,k], aux scalar)."""
    T = xt.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = lin.dense(xt.astype(jnp.float32), params["router"], None,
                       path_prefix + "router")                       # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                         # [T,k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    # Load-balance auxiliary loss (Switch-style).
    me = probs.mean(0)                                               # [E]
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)
    return gate_vals, idx, aux


def _slot_positions(idx, E: int, cap: int):
    """Per-(token,slot) position within its expert's capacity buffer."""
    T, k = idx.shape
    onehot = jax.nn.one_hot(idx.reshape(T * k), E, dtype=jnp.int32)  # [T*k,E]
    pos = (jnp.cumsum(onehot, axis=0) - 1)                           # running count per expert
    pos_in_e = (pos * onehot).sum(-1).reshape(T, k)                  # [T,k]
    keep = pos_in_e < cap
    return pos_in_e, keep


def _expert_ffn(params, xe, lin, path_prefix):
    g = lin.expert(xe, params["experts"]["gate"], path_prefix + "experts_gate")
    u = lin.expert(xe, params["experts"]["up"], path_prefix + "experts_up")
    return lin.expert(jax.nn.silu(g) * u, params["experts"]["down"],
                      path_prefix + "experts_down")                  # [E,cap,d]


def moe_forward(params, cfg, x, lin: LinearFns, *, path_prefix: str = "",
                capacity_factor=None, dispatch: str = "scatter"):
    """x [B,S,d] -> ([B,S,d], aux_loss scalar).

    capacity_factor=None (default) is drop-free/exact; pass a float to cap
    expert buffers at factor * T * k / E (tokens beyond it are dropped).

    The route->dispatch->combine body runs inside ``jax.checkpoint``: its
    backward is a single self-contained subprogram (recomputed, not stitched
    from saved forward pieces). Without the boundary, XLA fuses the two
    cotangent paths that meet at the router probs (the combine-weight path
    and the aux-loss path) differently in a vmapped bank step than in the
    solo step — a 1-2 ulp vmap-vs-solo drift that appeared at some token
    counts and broke the FinetuneEngine's bitwise-faithfulness contract for
    MoE banks (either cotangent path alone is drift-free; see
    tests/test_moe.py::TestVmapBitwise). Values the ``lin`` hook closes over
    (the layer's adapter slice — e.g. a router-targeted LoRA) are hoisted
    into explicit checkpoint arguments via ``closure_convert``, so their
    cotangents also flow through the recomputed region instead of a
    fusion-exposed side path. Forward-only callers (decode) are unaffected —
    checkpoint is the identity without differentiation."""

    def body(params, x):
        B, S, d = x.shape
        E, k = cfg.n_experts, cfg.top_k
        T = B * S
        xt = x.reshape(T, d)
        cap = _capacity(T, E, k, capacity_factor)

        gate_vals, idx, aux = _route(params, cfg, xt, lin, path_prefix)
        pos_in_e, keep = _slot_positions(idx, E, cap)

        if dispatch == "scatter":
            dest = idx * cap + pos_in_e                              # [T,k] in [0, E*cap)
            dest = jnp.where(keep, dest, E * cap)                    # dropped -> OOB (ignored)
            src = jnp.repeat(xt, k, axis=0)                          # [T*k,d]
            xe = jnp.zeros((E * cap, d), x.dtype).at[dest.reshape(-1)].add(
                src, mode="drop")
            ye = _expert_ffn(params, xe.reshape(E, cap, d), lin, path_prefix)
            ye_flat = ye.reshape(E * cap, d)
            gathered = ye_flat.at[dest.reshape(-1)].get(mode="fill", fill_value=0.0)
            yt = (gathered.reshape(T, k, d)
                  * (gate_vals * keep).astype(x.dtype)[..., None]).sum(axis=1)
        elif dispatch == "einsum":
            disp = (jax.nn.one_hot(idx, E, dtype=x.dtype)[..., :, None]
                    * jax.nn.one_hot(pos_in_e, cap, dtype=x.dtype)[..., None, :]
                    * keep[..., None, None].astype(x.dtype))         # [T,k,E,cap]
            xe = jnp.einsum("td,tkec->ecd", xt, disp)
            ye = _expert_ffn(params, xe, lin, path_prefix)
            combine = disp * gate_vals[..., None, None].astype(x.dtype)
            yt = jnp.einsum("ecd,tkec->td", ye, combine)
        else:
            raise ValueError(f"unknown dispatch {dispatch}")

        if "shared" in params:
            yt = yt + blocks.mlp_forward(params["shared"], xt, lin,
                                         path_prefix=path_prefix + "shared_").astype(yt.dtype)
        return yt.reshape(B, S, d).astype(x.dtype), aux

    closed, hoisted = jax.closure_convert(body, params, x)
    return jax.checkpoint(closed)(params, x, *hoisted)
