"""RWKV6 ("Finch") blocks — attention-free, data-dependent decay linear attention.

TPU adaptation (DESIGN.md §2): the reference CUDA wkv6 kernel is a per-token
recurrence over a [H, dk, dv] state. We implement it as a *chunked* scan:
``lax.scan`` over time-chunks carrying the state matrix, with the per-chunk
recurrence unrolled via an inner scan. The chunk size bounds the live
activation set (VMEM-friendly) while keeping the sequential dependency exact.
The baseline uses chunk=1 semantics (plain scan); the perf-optimized variant
(§Perf hillclimb) uses the intra-chunk parallel form.

Base/client split (paper §3.2 rule): all projections (r,k,v,g,o and the
channel-mix linears) are frozen base layers routed through LinearFns; the
token-shift interpolation, data-dependent decay computation (small LoRA-style
``ddlerp`` params) and the stateful wkv recurrence are client-side ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import LinearFns, dense_init


def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    hd = cfg.hd
    H = d // hd
    ks = jax.random.split(key, 12)
    tm = {
        # token-shift mix coefficients (client-side, tiny)
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        # data-dependent decay: w_t = exp(-exp(decay + tanh(x W1) W2))
        "decay": jnp.zeros((d,), jnp.float32),
        "w1": dense_init(ks[0], d, 64, dtype), "w2": dense_init(ks[1], 64, d, dtype),
        "bonus": jnp.zeros((H, hd), jnp.float32),   # `u` term for current token
        # frozen base projections
        "wr": dense_init(ks[2], d, d, dtype), "wk": dense_init(ks[3], d, d, dtype),
        "wv": dense_init(ks[4], d, d, dtype), "wg": dense_init(ks[5], d, d, dtype),
        "wo": dense_init(ks[6], d, d, dtype),
        "ln_x": jnp.ones((d,), dtype),
    }
    cm = {
        "mix_k": jnp.full((d,), 0.5, dtype), "mix_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[7], d, cfg.d_ff, dtype),
        "wv": dense_init(ks[8], cfg.d_ff, d, dtype),
        "wr": dense_init(ks[9], d, d, dtype),
    }
    return {"time_mix": tm, "channel_mix": cm}


def _shift(x, last):
    """Token shift: prepend `last` [B,1,d] (or zeros) and drop final step."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mix(x, xs, m):
    return x * m + xs * (1.0 - m)


def wkv6_scan(r, k, v, w, bonus, state, chunk: int = 128):
    """The wkv6 recurrence, chunked.

    r,k [B,S,H,dk]; v [B,S,H,dv]; w [B,S,H,dk] (decay in (0,1)); bonus [H,dk];
    state [B,H,dk,dv]. Returns (out [B,S,H,dv], state').

      S_t = diag(w_t) S_{t-1} + k_t^T v_t
      o_t = r_t (S_{t-1} + diag(bonus) k_t^T v_t)
    """
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    n = S // chunk

    def outer(carry, inp):
        st = carry                                           # [B,H,dk,dv] f32
        # cast INSIDE the chunk body: rematted/scanned tensors stay bf16 in
        # HBM (and in any cross-chip resharding) — §Perf it10
        rc, kc, vc, wc = (t.astype(jnp.float32) for t in inp)

        def inner(st, t_inp):
            rt, kt, vt, wt = t_inp                           # [B,H,dk]/[B,H,dv]
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)         # [B,H,dk,dv]
            out = jnp.einsum("bhk,bhkv->bhv", rt, st + bonus[None, :, :, None] * kv)
            st = wt[..., None] * st + kv
            return st, out

        st, out = jax.lax.scan(inner, st, (rc, kc, vc, wc))
        return st, out                                       # out [chunk,B,H,dv]

    seq = lambda x: x.reshape(B, n, chunk, *x.shape[2:]).transpose(1, 2, 0, *range(3, x.ndim + 1))
    rs, ks, vs, ws = (seq(t) for t in (r, k, v, w.astype(r.dtype)))
    # checkpoint the chunk body: state is only materialized at chunk
    # boundaries; the intra-chunk recurrence is recomputed in the backward.
    state, out = jax.lax.scan(jax.checkpoint(outer), state.astype(jnp.float32),
                              (rs, ks, vs, ws))
    out = out.reshape(n * chunk, B, H, dv).transpose(1, 0, 2, 3)     # [B,S,H,dv]
    return out, state


def time_mix(p, cfg, x, lin: LinearFns, state, last_x, *, path_prefix=""):
    """RWKV6 time-mix. x [B,S,d]; state [B,H,dk,dv] f32; last_x [B,1,d] or None."""
    B, S, d = x.shape
    hd = cfg.hd
    H = d // hd
    xs = _shift(x, last_x)
    xr, xk, xv, xg, xw = (_mix(x, xs, p[m]) for m in ("mix_r", "mix_k", "mix_v", "mix_g", "mix_w"))

    from repro.common.constrain import constrain
    HP = (None, None, "model", None)             # [B,S,H,hd]: heads sharded
    r = constrain(lin.dense(xr, p["wr"], None, path_prefix + "r").reshape(B, S, H, hd), *HP)
    k = constrain(lin.dense(xk, p["wk"], None, path_prefix + "k").reshape(B, S, H, hd), *HP)
    v = constrain(lin.dense(xv, p["wv"], None, path_prefix + "v").reshape(B, S, H, hd), *HP)
    g = lin.dense(xg, p["wg"], None, path_prefix + "g")

    # Data-dependent decay (client-side: tiny LoRA-style projection).
    dd = jnp.tanh(xw.astype(jnp.float32) @ p["w1"].astype(jnp.float32)) @ p["w2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["decay"] + dd)).reshape(B, S, H, hd)      # in (0,1)
    w = constrain(w, *HP)   # keep the wkv scan head-sharded end-to-end

    out, state = wkv6_scan(r, k, v, w, p["bonus"], state)
    out = out.reshape(B, S, d)
    # group norm over heads (approximated by rmsnorm scale ln_x) + gating
    dt = x.dtype
    o32 = out.reshape(B, S, H, hd)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, axis=-1, keepdims=True) + 1e-6)
    out = (o32.reshape(B, S, d) * p["ln_x"].astype(jnp.float32)).astype(dt)
    out = out * jax.nn.silu(g)
    out = lin.dense(out, p["wo"], None, path_prefix + "o")
    return out, state, x[:, -1:]


def channel_mix(p, x, lin: LinearFns, last_x, *, path_prefix=""):
    xs = _shift(x, last_x)
    xk = _mix(x, xs, p["mix_k"])
    xr = _mix(x, xs, p["mix_r"])
    k = lin.dense(xk, p["wk"], None, path_prefix + "cm_k")
    k = jnp.square(jax.nn.relu(k))
    kv = lin.dense(k, p["wv"], None, path_prefix + "cm_v")
    r = jax.nn.sigmoid(lin.dense(xr, p["wr"], None, path_prefix + "cm_r"))
    return r * kv, x[:, -1:]
