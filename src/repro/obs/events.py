"""Structured, drainable event log — the client-visible side of telemetry.

Events are the discrete state changes a tenant can observe: ``admit``,
``retire``, ``reject``, ``bank_growth``, ``bank_retire``, ``quarantine``,
``retry``, ``backoff``, ``health``, ``compile`` / ``recompile``,
``capture_start`` / ``capture_stop`` / ``capture_failed``.  The engines emit
them (faults/health transitions and tracecount's dispatch choke point are the
sources); clients pull them with ``drain`` — filtered drains remove only the
matching events and leave the rest queued for other consumers.

The log is bounded: past ``maxlen`` the oldest events are dropped and
counted, never silently.  See docs/observability.md for the full schema.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

#: sentinel distinguishing "no tenant filter" from "tenant is None".
UNSET = object()


@dataclasses.dataclass(frozen=True)
class Event:
    """One engine state change.  ``tenant`` is a client id (serving) or job
    name (training); ``data`` is a sorted tuple of (key, value) pairs so the
    event is hashable and deterministic to serialize."""

    seq: int
    kind: str
    engine: str = ""
    tick: int = 0
    tenant: object = None
    data: Tuple[Tuple[str, object], ...] = ()

    def asdict(self) -> dict:
        return {
            "seq": self.seq,
            "kind": self.kind,
            "engine": self.engine,
            "tick": self.tick,
            "tenant": self.tenant,
            "data": {k: v for k, v in self.data},
        }


class EventLog:
    def __init__(self, maxlen: int = 10000) -> None:
        self.maxlen = int(maxlen)
        self.dropped = 0
        self._seq = 0
        self._buf: List[Event] = []

    def __len__(self) -> int:
        return len(self._buf)

    def emit(self, kind: str, *, engine: str = "", tick: int = 0,
             tenant: object = None, **data) -> Event:
        ev = Event(self._seq, kind, engine, int(tick), tenant,
                   tuple(sorted(data.items())))
        self._seq += 1
        if len(self._buf) >= self.maxlen:
            del self._buf[0]
            self.dropped += 1
        self._buf.append(ev)
        return ev

    def _match(self, ev: Event, tenant, kind, engine) -> bool:
        if tenant is not UNSET and ev.tenant != tenant:
            return False
        if kind is not None and ev.kind != kind:
            return False
        if engine is not None and ev.engine != engine:
            return False
        return True

    def peek(self, *, tenant=UNSET, kind: Optional[str] = None,
             engine: Optional[str] = None) -> List[Event]:
        """Non-destructive filtered view."""
        return [e for e in self._buf if self._match(e, tenant, kind, engine)]

    def drain(self, *, tenant=UNSET, kind: Optional[str] = None,
              engine: Optional[str] = None) -> List[Event]:
        """Remove and return matching events; non-matching events stay queued."""
        out, keep = [], []
        for e in self._buf:
            (out if self._match(e, tenant, kind, engine) else keep).append(e)
        self._buf = keep
        return out
