"""Tick-phase spans and the on-demand profiler capture window.

A ``Span`` is a reusable context manager for one named tick phase (the
taxonomy — ``admit``, ``prefill``, ``compact_gather``, ``jit_dispatch``,
``device_sync``, ``scatter``, ``health_audit``, ``train_step`` — is
documented in docs/observability.md).  Entering a span opens a
``jax.profiler.TraceAnnotation`` named scope (a host-side TraceMe: it tags
profiler timelines but never blocks on the device) and records a
``perf_counter`` pair into a per-phase latency histogram on exit.
Timestamps are taken only at phase boundaries — spans never call
``block_until_ready``, so whatever async dispatch the engine does is
unchanged.

``CaptureWindow`` arms a one-shot ``jax.profiler.start_trace`` /
``stop_trace`` pair spanning the next N engine ticks.  Capture is
best-effort: profiler failures are reported as events, never raised into
the tick loop.
"""
from __future__ import annotations

import time
from typing import Optional

try:  # pragma: no cover - import guard, exercised implicitly
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover
    _TraceAnnotation = None


class Span:
    """Reusable single-threaded context manager for one tick phase."""

    __slots__ = ("name", "_hist", "_t0", "_ann")

    def __init__(self, name: str, hist) -> None:
        self.name = name
        self._hist = hist  # obs-owned Histogram for this phase
        self._t0 = 0.0
        self._ann = None

    def __enter__(self) -> "Span":
        if _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(f"repro.obs/{self.name}")
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dt = time.perf_counter() - self._t0
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
            self._ann = None
        self._hist.observe(dt)
        return False


class CaptureWindow:
    """One-shot profiler capture armed for the next N ticks.

    ``request`` arms; the owning ``Obs`` calls ``on_tick_start`` /
    ``on_tick_end`` from the engine tick boundaries.  Returns event kinds
    ("capture_start", "capture_stop", "capture_failed") so the caller can
    log them; None when nothing happened.
    """

    def __init__(self) -> None:
        self.log_dir: Optional[str] = None
        self.ticks_left = 0
        self.active = False

    def request(self, log_dir: str, ticks: int = 1) -> None:
        self.log_dir = str(log_dir)
        self.ticks_left = max(1, int(ticks))

    def on_tick_start(self) -> Optional[str]:
        if self.active or self.log_dir is None:
            return None
        try:
            import jax.profiler as _prof

            _prof.start_trace(self.log_dir)
        except Exception:
            self.log_dir = None
            self.ticks_left = 0
            return "capture_failed"
        self.active = True
        return "capture_start"

    def on_tick_end(self) -> Optional[str]:
        if not self.active:
            return None
        self.ticks_left -= 1
        if self.ticks_left > 0:
            return None
        self.active = False
        self.log_dir = None
        try:
            import jax.profiler as _prof

            _prof.stop_trace()
        except Exception:
            return "capture_failed"
        return "capture_stop"
