"""repro.obs — tick-level telemetry for the symbiotic engines.

One ``Obs`` object bundles the three telemetry surfaces and is passed to the
engines as ``obs=`` (ServingEngine / FinetuneEngine / SymbiosisEngine.from_spec):

- ``obs.metrics``  — labeled counters/gauges/log-bucketed histograms
  (per-tenant tokens, pages, HBM charges, queue-wait, TTFT, inter-token
  latency; the engines' ``stats`` dicts are mirrored in as gauges at
  snapshot time, keeping ``stats`` as the compatibility view).
- ``obs.span(name)`` — reusable tick-phase spans emitting ``jax.profiler``
  named scopes plus per-phase latency histograms.
- ``obs.events`` / ``obs.event(...)`` — the structured, drainable event log
  (client-visible via ``engine.drain_events(client=...)``).

Hard contracts (tested in tests/test_obs.py):

- ``obs=None`` (the default) is a hard no-op: the engines' tick loops see
  only ``if self._obs is not None`` guards and shared null context
  managers — no timing machinery is even imported on that path.
- Enabled telemetry adds **no device syncs inside the tick** (all
  timestamps are host ``perf_counter`` calls at tick/phase boundaries),
  **no new jit traces** (the autouse trace-guard stays green), and leaves
  engine outputs **bitwise unchanged**.

``obs.request_capture(log_dir, ticks=N)`` arms an on-demand profiler
capture window spanning the next N engine ticks.  Export via
``repro.obs.export`` (JSONL + Prometheus text) or the
``python -m repro.obs`` CLI; full schema in docs/observability.md.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.obs.events import UNSET, Event, EventLog
from repro.obs.metrics import Counter, Gauge, Histogram, Metrics
from repro.obs.trace import CaptureWindow, Span

__all__ = [
    "Obs", "Metrics", "Counter", "Gauge", "Histogram",
    "Event", "EventLog", "Span", "CaptureWindow", "UNSET",
]


class Obs:
    """Telemetry facade shared by (possibly several) engines."""

    def __init__(self, *, max_events: int = 10000) -> None:
        self.metrics = Metrics()
        self.events = EventLog(maxlen=max_events)
        self._spans: Dict[str, Span] = {}
        self._engines: Dict[str, object] = {}
        self._capture = CaptureWindow()
        self._compiled: set = set()

    # -- engine registration / stats compatibility view ------------------
    def attach(self, label: str, engine) -> str:
        """Register an engine so snapshots mirror its ``stats`` dict."""
        base, n = label, 1
        while label in self._engines and self._engines[label] is not engine:
            n += 1
            label = f"{base}_{n}"
        self._engines[label] = engine
        return label

    def sync_stats(self) -> None:
        """Mirror every attached engine's ``stats`` dict into gauges.

        ``stats`` stays the authoritative compatibility view (checkpointing
        round-trips it); the mirror makes the same numbers exportable under
        one metric name: ``engine_stat{engine=...,key=...}``.
        """
        for label, eng in self._engines.items():
            for k, v in getattr(eng, "stats", {}).items():
                self.metrics.gauge("engine_stat", engine=label, key=k).set(v)

    # -- spans / tick boundaries -----------------------------------------
    def span(self, name: str) -> Span:
        sp = self._spans.get(name)
        if sp is None:
            sp = self._spans[name] = Span(
                name, self.metrics.histogram("span_seconds", phase=name))
        return sp

    def tick_start(self, engine: str) -> float:
        kind = self._capture.on_tick_start()
        if kind is not None:
            self.event(kind, engine=engine, log_dir=self._capture.log_dir or "")
        return time.perf_counter()

    def tick_end(self, engine: str, tick: int, t0: float) -> None:
        self.metrics.histogram("tick_seconds", engine=engine).observe(
            time.perf_counter() - t0)
        kind = self._capture.on_tick_end()
        if kind is not None:
            self.event(kind, engine=engine, tick=tick)

    def request_capture(self, log_dir: str, ticks: int = 1) -> None:
        """Arm a one-shot profiler capture for the next ``ticks`` engine ticks."""
        self._capture.request(log_dir, ticks)

    # -- events -----------------------------------------------------------
    def event(self, kind: str, **kw) -> Event:
        return self.events.emit(kind, **kw)

    def drain_events(self, *, client=UNSET, kind: Optional[str] = None,
                     engine: Optional[str] = None) -> List[Event]:
        """Destructive filtered drain (client= filters the tenant field)."""
        return self.events.drain(tenant=client, kind=kind, engine=engine)

    # -- tracecount hook ---------------------------------------------------
    def on_dispatch_compile(self, owner, family: str, key, epoch: int) -> None:
        """Called by ``tracecount.dispatch`` when a jitted hot-path function
        grew its cache.  First sighting of (owner, epoch, family, key) is a
        ``compile`` event; repeats are ``recompile`` — the signal the
        trace-guard turns into a hard failure in tests."""
        sig = (id(owner), epoch, family, repr(key))
        kind = "compile" if sig not in self._compiled else "recompile"
        self._compiled.add(sig)
        self.metrics.counter(f"jit_{kind}s_total", family=family).inc()
        # label the event with the engine's attach label ("serving" /
        # "finetune") so engine-filtered drains include compile events;
        # unattached owners fall back to their class name
        label = next((l for l, e in self._engines.items() if e is owner),
                     type(owner).__name__)
        self.event(kind, engine=label, family=family, key=repr(key))

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        self.sync_stats()
        return {
            "metrics": self.metrics.samples(),
            "events": [e.asdict() for e in self.events.peek()],
            "dropped_events": self.events.dropped,
        }
