"""Metric primitives: counters, gauges, and log-bucketed streaming histograms.

The registry (`Metrics`) keys every instrument by ``(kind, name, labels)``
where ``labels`` is a sorted tuple of ``(key, value)`` pairs — per-tenant
series are just the same metric name with a ``client=`` / ``job=`` label.
Everything is host-side pure-python bookkeeping: observing a value never
touches a device array, so telemetry cannot introduce device syncs or new
jit traces (the hard constraints in docs/observability.md).

Histograms are streaming and log-2 bucketed: bucket ``i`` holds values in
``(LO * 2**(i-1), LO * 2**i]`` with ``LO = 1e-6`` (1 microsecond), bucket 0
holds everything ``<= LO``.  Percentiles report the upper edge of the bucket
containing the rank — deterministic, O(#buckets) memory, and accurate to
2x which is all a latency SLO needs.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

LabelKey = Tuple[Tuple[str, object], ...]


class Counter:
    """Monotonically increasing count (tokens, admissions, faults)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0

    def inc(self, amount: float = 1) -> None:
        self.value += amount


class Gauge:
    """Point-in-time level (free pages, committed HBM bytes)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0.0

    def set(self, value: float) -> None:
        self.value = value

    def add(self, delta: float) -> None:
        self.value += delta


class Histogram:
    """Log-2 bucketed streaming histogram with exact count/sum/min/max."""

    __slots__ = ("counts", "n", "total", "vmin", "vmax")

    #: lower edge of bucket 0 — 1 microsecond, fine enough for tick phases.
    LO = 1e-6

    def __init__(self) -> None:
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        i = 0 if v <= self.LO else int(math.ceil(math.log2(v / self.LO)))
        self.counts[i] = self.counts.get(i, 0) + 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @classmethod
    def upper_edge(cls, bucket: int) -> float:
        return cls.LO * (2.0 ** bucket)

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-th percentile rank.

        Clamped to the exact observed max so p100 is exact.
        """
        if self.n == 0:
            return 0.0
        rank = max(1, int(math.ceil(self.n * p / 100.0)))
        cum = 0
        for i in sorted(self.counts):
            cum += self.counts[i]
            if cum >= rank:
                return min(self.upper_edge(i), self.vmax)
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def merge(self, other: "Histogram") -> "Histogram":
        for i, c in other.counts.items():
            self.counts[i] = self.counts.get(i, 0) + c
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted(labels.items()))


class Metrics:
    """Registry of labeled instruments.

    ``counter/gauge/histogram`` are get-or-create so call sites stay a single
    line; instruments are plain attribute bumps after the dict lookup.
    """

    def __init__(self) -> None:
        self._data: Dict[Tuple[str, str, LabelKey], object] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict[str, object]):
        key = (kind, name, _label_key(labels))
        inst = self._data.get(key)
        if inst is None:
            inst = self._data[key] = cls()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    def merged_histogram(self, name: str) -> Histogram:
        """One histogram folding together every label set under ``name``."""
        out = Histogram()
        for (kind, n, _), inst in self._data.items():
            if kind == "histogram" and n == name:
                out.merge(inst)
        return out

    def samples(self) -> List[dict]:
        """Flat, JSON-ready dump of every instrument (sorted, deterministic)."""
        rows: List[dict] = []
        for (kind, name, labels) in sorted(self._data, key=lambda k: (k[1], k[0], k[2])):
            inst = self._data[(kind, name, labels)]
            row = {"metric": name, "type": kind, "labels": {k: v for k, v in labels}}
            if kind == "histogram":
                h: Histogram = inst  # type: ignore[assignment]
                row.update(
                    count=h.n,
                    sum=h.total,
                    min=(None if h.n == 0 else h.vmin),
                    max=(None if h.n == 0 else h.vmax),
                    buckets={str(i): h.counts[i] for i in sorted(h.counts)},
                    p50=h.percentile(50),
                    p99=h.percentile(99),
                )
            else:
                row["value"] = inst.value  # type: ignore[union-attr]
            rows.append(row)
        return rows
