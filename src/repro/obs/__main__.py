"""CLI for the telemetry subsystem (docs/observability.md).

Validate telemetry files (exit 1 on malformed/partial input — CI runs this
against the bench-smoke artifacts)::

    PYTHONPATH=src python -m repro.obs --check BENCH_obs.jsonl BENCH_obs.prom

Produce a small self-contained telemetry sample (tiny obs-enabled serving
run including one injected request-stream fault, so events cover the
backoff/retry path)::

    PYTHONPATH=src python -m repro.obs --demo --out obs_demo
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List


def _demo(out_dir: str) -> List[str]:
    import warnings

    import jax
    import numpy as np

    from repro.config import DENSE, AdapterConfig, ModelConfig, ServeConfig
    from repro.core import symbiosis
    from repro.faults.plan import FaultyRequestStream
    from repro.obs import Obs, export
    from repro.serving.engine import Request, ServingEngine

    cfg = ModelConfig(name="tiny-obs", arch=DENSE, n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype="float32", param_dtype="float32")
    acfg = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
    n_clients = 2
    scfg = ServeConfig(n_clients=n_clients, max_seq=32, page_block=8,
                       pool_pages=8)
    base, bank, _ = symbiosis.init_system(cfg, acfg, n_clients,
                                          jax.random.PRNGKey(0))
    obs = Obs()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = ServingEngine(cfg, acfg, scfg, base, bank,
                            max_batch_per_client=2, obs=obs)
    rng = np.random.default_rng(0)
    for c in range(n_clients):
        p = rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)
        eng.submit(Request(client_id=c, prompt=p, max_new_tokens=4))
    # one stream-backed request whose first fetch faults, so the demo
    # telemetry exercises the backoff/retry event path
    p = rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)
    eng.submit(Request(client_id=0, prompt=None, max_new_tokens=4,
                       prompt_stream=FaultyRequestStream(
                           p, {0: "stream_error"})))
    eng.run()
    return [export.write_jsonl(os.path.join(out_dir, "telemetry.jsonl"), obs),
            export.write_prometheus(os.path.join(out_dir, "metrics.prom"),
                                    obs)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="validate / demo repro telemetry files")
    ap.add_argument("--check", nargs="+", metavar="FILE", default=None,
                    help="validate telemetry files (.jsonl / .prom); "
                         "exits non-zero on malformed or partial input")
    ap.add_argument("--demo", action="store_true",
                    help="run a tiny obs-enabled serving workload and "
                         "write sample telemetry")
    ap.add_argument("--out", default="obs_demo", metavar="DIR",
                    help="output directory for --demo (default: obs_demo)")
    args = ap.parse_args(argv)
    if not args.check and not args.demo:
        ap.error("nothing to do: pass --check FILE... and/or --demo")
    rc = 0
    if args.demo:
        for p in _demo(args.out):
            print(f"wrote {p}")
    if args.check:
        from repro.obs.export import check_file
        problems: List[str] = []
        for p in args.check:
            problems += check_file(p)
        for msg in problems:
            print(f"CHECK FAIL: {msg}", file=sys.stderr)
        if problems:
            rc = 1
        else:
            print(f"ok: {len(args.check)} telemetry file(s) valid")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
