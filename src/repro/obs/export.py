"""Telemetry sinks: JSONL records and Prometheus text exposition.

Both formats carry explicit end-of-stream framing so a truncated or
partially-written file is detectable: the JSONL stream is
``header`` record → payload records → ``footer`` record (the footer carries
the payload count), and the Prometheus text ends with a ``# EOF`` line
(OpenMetrics convention).  ``check_file`` / the ``python -m repro.obs
--check`` CLI validate the framing and per-record schema and report every
problem found — CI runs it against the bench-smoke artifacts.
"""
from __future__ import annotations

import json
import os
import re
from typing import List

from repro.obs.metrics import Histogram

SCHEMA_VERSION = 1

_PROM_SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? [^ ]+$'
)


def _sanitize(value: object) -> str:
    return str(value).replace("\\", "\\\\").replace('"', '\\"').replace("\n", " ")


def jsonl_records(obs) -> List[dict]:
    """Full snapshot of an Obs as framed JSONL-ready records."""
    sync = getattr(obs, "sync_stats", None)
    if sync is not None:
        sync()  # fold the engines' stats dicts in as engine_stat gauges
    payload: List[dict] = []
    for row in obs.metrics.samples():
        payload.append({"record": "metric", **row})
    for ev in obs.events.peek():
        payload.append({"record": "event", **ev.asdict()})
    header = {"record": "header", "kind": "repro-obs", "schema": SCHEMA_VERSION}
    footer = {"record": "footer", "n": len(payload),
              "dropped_events": obs.events.dropped}
    return [header, *payload, {**footer}]


def write_jsonl(path: str, obs) -> str:
    recs = jsonl_records(obs)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        for rec in recs:
            f.write(json.dumps(rec, sort_keys=True, default=str) + "\n")
    return path


def prometheus_text(obs) -> str:
    """Prometheus/OpenMetrics-style text exposition of the metric registry.

    Histograms are rendered with cumulative ``_bucket{le=...}`` series plus
    ``_sum`` / ``_count``; the stream is terminated by ``# EOF``.
    """
    sync = getattr(obs, "sync_stats", None)
    if sync is not None:
        sync()
    lines: List[str] = []
    seen_type = set()

    def labelstr(labels: dict, extra: dict = ()) -> str:
        items = {**labels, **dict(extra)}
        if not items:
            return ""
        body = ",".join(f'{k}="{_sanitize(v)}"' for k, v in sorted(items.items()))
        return "{" + body + "}"

    for row in obs.metrics.samples():
        name, kind, labels = row["metric"], row["type"], row["labels"]
        if name not in seen_type:
            seen_type.add(name)
            prom_kind = {"counter": "counter", "gauge": "gauge",
                         "histogram": "histogram"}[kind]
            lines.append(f"# TYPE {name} {prom_kind}")
        if kind == "histogram":
            cum = 0
            for b in sorted(int(i) for i in row["buckets"]):
                cum += row["buckets"][str(b)]
                le = Histogram.upper_edge(b)
                lines.append(
                    f"{name}_bucket{labelstr(labels, {'le': repr(le)})} {cum}")
            lines.append(f"{name}_bucket{labelstr(labels, {'le': '+Inf'})} {row['count']}")
            lines.append(f"{name}_sum{labelstr(labels)} {row['sum']!r}")
            lines.append(f"{name}_count{labelstr(labels)} {row['count']}")
        else:
            lines.append(f"{name}{labelstr(labels)} {row['value']!r}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, obs) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        f.write(prometheus_text(obs))
    return path


def check_jsonl(path: str) -> List[str]:
    """Validate a JSONL telemetry file; returns a list of problems ([] = ok)."""
    errors: List[str] = []
    try:
        with open(path) as f:
            raw = f.read()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    lines = [ln for ln in raw.split("\n") if ln.strip()]
    if not lines:
        return [f"{path}: empty"]
    recs = []
    for i, ln in enumerate(lines):
        try:
            recs.append(json.loads(ln))
        except ValueError:
            errors.append(f"{path}:{i + 1}: not valid JSON (truncated write?)")
            return errors
    if recs[0].get("record") != "header" or recs[0].get("kind") != "repro-obs":
        errors.append(f"{path}: missing repro-obs header record")
    if recs[-1].get("record") != "footer":
        errors.append(f"{path}: missing footer record (partial file)")
    else:
        n = recs[-1].get("n")
        if n != len(recs) - 2:
            errors.append(
                f"{path}: footer count {n} != {len(recs) - 2} payload records")
    required = {"metric": ("metric", "type", "labels"),
                "event": ("seq", "kind", "tick")}
    for i, rec in enumerate(recs[1:-1], start=2):
        kind = rec.get("record")
        if kind not in required:
            errors.append(f"{path}:{i}: unknown record type {kind!r}")
            continue
        missing = [k for k in required[kind] if k not in rec]
        if missing:
            errors.append(f"{path}:{i}: {kind} record missing {missing}")
    return errors


def check_prometheus(path: str) -> List[str]:
    errors: List[str] = []
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    lines = text.split("\n")
    while lines and not lines[-1].strip():
        lines.pop()
    if not lines:
        return [f"{path}: empty"]
    if lines[-1].strip() != "# EOF":
        errors.append(f"{path}: missing terminal '# EOF' (partial file)")
    for i, ln in enumerate(lines[:-1], start=1):
        if not ln or ln.startswith("#"):
            continue
        if not _PROM_SAMPLE_RE.match(ln):
            errors.append(f"{path}:{i}: malformed sample line {ln!r}")
    return errors


def check_file(path: str) -> List[str]:
    if path.endswith(".jsonl") or path.endswith(".json"):
        return check_jsonl(path)
    if path.endswith(".prom") or path.endswith(".txt"):
        return check_prometheus(path)
    return [f"{path}: unknown telemetry extension (want .jsonl or .prom)"]
