"""Pytree utilities."""
import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape")
    )


def tree_count(tree) -> int:
    """Total number of scalar parameters."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
