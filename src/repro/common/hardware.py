"""Hardware constants for roofline analysis (TPU v5e, the target platform)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float      # FLOP/s
    hbm_bandwidth: float        # bytes/s
    ici_link_bandwidth: float   # bytes/s per link (one direction)
    ici_links: int              # usable ICI links per chip (2D torus on v5e)
    hbm_bytes: float            # HBM capacity per chip
    vmem_bytes: float           # VMEM per core
    dcn_bandwidth: float        # bytes/s per host for cross-pod traffic
    pcie_bandwidth: float       # bytes/s host<->device (for heterogeneous model)
    host_flops: float           # rough CPU FLOP/s per host (heterogeneous model)
    host_mem_bandwidth: float = 100e9   # bytes/s host DRAM (heterogeneous model)


V5E = Chip(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,
    hbm_bytes=16e9,
    vmem_bytes=128 * 1024 * 1024,
    dcn_bandwidth=25e9,
    pcie_bandwidth=32e9,
    host_flops=3e12,
)
