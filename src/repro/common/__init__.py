from repro.common.hardware import V5E
from repro.common.tree import tree_bytes, tree_count, cast_tree
