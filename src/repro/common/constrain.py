"""Soft sharding constraints: no-ops without an ambient mesh.

Model code stays mesh-agnostic — constraints only bind when the launcher
established a mesh via ``jax.set_mesh`` (the dry-run / production path); CPU
unit tests and single-device runs are untouched.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    if mesh is None or not getattr(mesh, "axis_names", None):
        return ()
    return tuple(mesh.axis_names)


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) iff every named axis in spec
    exists in the ambient mesh; otherwise identity."""
    axes = _ambient_axes()
    if not axes:
        return x
    for s in spec:
        for name in ((s,) if isinstance(s, str) else (s or ())):
            if name not in axes:
                return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
