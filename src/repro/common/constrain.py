"""Soft sharding constraints: no-ops without an ambient mesh.

Model code stays mesh-agnostic — constraints only bind when the launcher
established a mesh via ``launch.mesh.mesh_context`` (the dry-run /
production / sharded-engine path); CPU unit tests and single-device runs
are untouched.

Two ambient-mesh mechanisms exist across jax versions: the abstract mesh
set by ``jax.set_mesh`` (newer releases) and the legacy resource env bound
by the ``Mesh`` object's own context manager (this tree's pinned jax).
``_ambient_mesh`` reads whichever is active, so ``constrain`` binds under
both.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    """The active mesh (abstract or legacy resource-env), or None."""
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        try:
            mesh = get_am()
        except Exception:
            mesh = None
        if mesh is not None and getattr(mesh, "axis_names", None):
            return mesh
    try:        # legacy ambient mesh: ``with mesh:`` binds the resource env
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _ambient_axes():
    mesh = _ambient_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) iff every named axis in spec
    exists in the ambient mesh; otherwise identity."""
    axes = _ambient_axes()
    if not axes:
        return x
    for s in spec:
        for name in ((s,) if isinstance(s, str) else (s or ())):
            if name not in axes:
                return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def constrain_batch(x, ax: int = 0):
    """Constrain axis ``ax`` of ``x`` over the ambient BATCH axes — the
    (pod, data) subset of the active mesh. The gather/scatter boundaries of
    the compacted steps use this: compacted rows, per-row state and logits
    partition over the batch axes while the frozen base stays on its own
    tensor/FSDP plan. Identity when no mesh is ambient, when the mesh has
    no batch axis, or when the axis length doesn't divide."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    baxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if not baxes:
        return x
    size = 1
    for a in baxes:
        size *= dict(mesh.shape)[a]
    if size <= 1 or x.ndim <= ax or x.shape[ax] % size:
        return x
    spec = [None] * x.ndim
    spec[ax] = baxes if len(baxes) > 1 else baxes[0]
    return constrain(x, *spec)
