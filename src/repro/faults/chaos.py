"""Seeded chaos sweep: fault containment under load (docs/robustness.md).

Drives real engines — fine-tuning, serving, and the symbiotic interleave —
against a ``FaultPlan`` adversary and machine-checks the three robustness
contracts on every scenario:

* **Containment** — the engine never crashes; every survivor's committed
  state (token streams, adapter params, optimizer state, loss history) is
  BYTE-identical to a clean run of the same workload, and every victim's
  committed prefix is byte-identical up to its last clean tick.
* **Conservation** — after the dust settles, free + allocated pages equal
  the pool, slot maps invert exactly, and the router's live counters equal
  its initial capacities minus outstanding placements
  (``faults.audit.check_conservation``).
* **Recovery** — kill → restore from the newest VALID whole-engine
  checkpoint resumes every tenant bitwise; corrupted checkpoint files
  (bit-flip, truncation) are rejected by CRC and restore falls back to
  the last good one.

Run it::

    PYTHONPATH=src python -m repro.faults.chaos [--seed N] [--report out.json]

or via the ``chaos``-marked tests (``pytest -m chaos``). CI runs it as the
``tier2-chaos`` job and uploads the JSON report artifact.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import numpy as np


def _tiny_cfg():
    # the tier-1 test shape: the bank-size-invariance contract (vmapped
    # buckets == the unbatched R=1 program, bitwise) is pinned by the
    # tier-1 suite at THIS shape — the chaos oracle comparisons lean on it
    from repro.config import ModelConfig, DENSE
    return ModelConfig(name="tiny-chaos", arch=DENSE, n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=128, dtype="float32", param_dtype="float32")


def _lora():
    from repro.config import AdapterConfig
    return AdapterConfig(method="lora", rank=4, alpha=8.0,
                         targets=("q", "v"))


def _trees_equal(a, b) -> bool:
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _check(errors: List[str], ok: bool, msg: str):
    if not ok:
        errors.append(msg)


# ---------------------------------------------------------------------------
# fine-tuning scenario
# ---------------------------------------------------------------------------

def _make_jobs(cfg, n_jobs: int, steps: int, schedules: Dict[int, Dict]):
    """Every job gets a FaultyStream (survivors with empty schedules) so
    the stacked batch trees agree across the bank."""
    from repro.faults.plan import FaultyStream
    from repro.training.job import FinetuneJob, make_job_stream
    jobs = []
    for i in range(n_jobs):
        stream = FaultyStream(make_job_stream(cfg, 2, 16, seed=i),
                              schedules.get(i, {}))
        jobs.append(FinetuneJob(acfg=_lora(), data=stream, batch_size=2,
                                seq_len=16, steps=steps, name=f"job{i}",
                                seed=i))
    return jobs


def _run_finetune(cfg, base, jobs, *, fault_hook=None, debug=True):
    from repro.config import FinetuneConfig
    from repro.training.engine import FinetuneEngine
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = FinetuneEngine(cfg, base, fcfg=FinetuneConfig(max_jobs=8),
                             debug=debug, fault_hook=fault_hook)
    for j in jobs:
        eng.submit(j)
    done = eng.run()
    return eng, done


def finetune_scenario(seed: int, *, n_jobs: int = 6, steps: int = 8) -> dict:
    """Stream faults (NaN batches, transient errors, exhaustion) plus
    injected admission allocation failures against a bank of jobs."""
    import jax
    from repro.core import symbiosis
    from repro.faults.audit import check_conservation
    from repro.faults.plan import AllocHook, FaultPlan

    errors: List[str] = []
    # kinds weighted toward transients: a fatal fault ends its victim's
    # stream, so an all-fatal plan fires only a fraction of its events
    plan = FaultPlan(seed, n_tenants=n_jobs, n_faults=5 * n_jobs,
                     kinds=("stream_error", "stream_error", "nan_batch",
                            "stream_error", "stream_end"),
                     window=(0, steps - 1))
    alloc_at = {1, 3, 5}                    # admission attempts that fault
    base = symbiosis.init_system(cfg := _tiny_cfg(), _lora(), 1,
                                 jax.random.PRNGKey(seed))[0]

    clean_jobs = _make_jobs(cfg, n_jobs, steps, {})
    _, clean_done = _run_finetune(cfg, base, clean_jobs)
    clean = {j.name: j for j in clean_done}

    schedules = {t: plan.stream_schedule(t) for t in range(n_jobs)}
    hook = AllocHook(alloc_at)
    jobs = _make_jobs(cfg, n_jobs, steps, schedules)
    eng, done = _run_finetune(cfg, base, jobs, fault_hook=hook)

    _check(errors, len(done) == n_jobs,
           f"finetune: {len(done)}/{n_jobs} jobs retired")
    for j in done:
        ref = clean[j.name]
        if j.status == "finished":
            _check(errors, j.losses == ref.losses,
                   f"finetune: {j.name} losses diverged from clean run")
            _check(errors, _trees_equal(j.result.adapter, ref.result.adapter),
                   f"finetune: {j.name} adapter not bitwise clean")
            _check(errors, _trees_equal(j.result.opt, ref.result.opt),
                   f"finetune: {j.name} optimizer state not bitwise clean")
        else:
            # fatal fault / exhausted retries: the committed prefix must
            # still be bitwise clean (quarantine never commits a bad step)
            _check(errors, bool(schedules.get(int(j.name[3:]))),
                   f"finetune: {j.name} ended {j.status} with no fault "
                   "scheduled")
            _check(errors,
                   j.losses == ref.losses[:len(j.losses)],
                   f"finetune: {j.name} committed prefix diverged")
    _check(errors, hook.fired > 0, "finetune: no alloc faults fired")
    cons = check_conservation(eng)
    _check(errors, not cons, f"finetune: conservation: {cons}")

    fired_stream = sum(1 for t, sched in schedules.items()
                       for call in sched
                       if call < jobs[t].data.calls)
    injected = {"stream": fired_stream, "alloc": hook.fired}
    return {"scenario": "finetune", "injected": injected,
            "total": fired_stream + hook.fired,
            "engine_faults": eng.stats["faults"],
            "quarantined": eng.stats["quarantined"],
            "finished_early": eng.stats["finished_early"],
            "errors": errors}


# ---------------------------------------------------------------------------
# serving scenario
# ---------------------------------------------------------------------------

def _poison_client(bank, client: int):
    """NaN out one client's adapter rows (the nan_adapter fault kind)."""
    import jax
    import jax.numpy as jnp

    def leaf(x):
        return x.at[client].set(jnp.nan) if x.shape[0] > client else x

    return jax.tree.map(leaf, bank)


def serving_scenario(seed: int, *, n_clients: int = 4,
                     reqs_per_client: int = 4) -> dict:
    """Poisoned-adapter (non-finite logits) faults, injected admission
    allocation failures, AND request-stream faults (transient hiccup +
    stream exhaustion) against a paged serving bank — with telemetry
    attached, so the quarantine/backoff/retry/reject trail is asserted
    through the client-visible ``drain_events`` feed."""
    import jax
    import warnings
    from repro.config import ServeConfig
    from repro.core import symbiosis
    from repro.faults.audit import check_conservation
    from repro.faults.plan import AllocHook, FaultPlan, FaultyRequestStream
    from repro.obs import Obs
    from repro.serving.engine import Request, ServingEngine

    errors: List[str] = []
    cfg = _tiny_cfg()
    scfg = ServeConfig(n_clients=n_clients, max_seq=32, page_block=8,
                       pool_pages=8)
    base, bank, _ = symbiosis.init_system(cfg, _lora(), n_clients,
                                          jax.random.PRNGKey(seed))
    plan = FaultPlan(seed + 1, n_tenants=n_clients, n_faults=4,
                     kinds=("nan_adapter",))
    # cap the victim set so at least two survivors exercise containment
    victims = set(sorted(plan.victims("nan_adapter"))[:max(1, n_clients - 2)])
    rng = np.random.default_rng(seed)
    prompts = [[rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)
                for _ in range(reqs_per_client)] for _ in range(n_clients)]

    # stream-fault victims: a SURVIVOR takes a transient hiccup (retried
    # after backoff, same prompt — must stay bitwise), and one nan victim's
    # stream runs dry (rejected at admission, never admitted)
    surv = sorted(set(range(n_clients)) - victims)
    s_err = surv[0]
    v_end = sorted(victims)[0]
    err_stream = FaultyRequestStream(prompts[s_err][0], {0: "stream_error"})
    end_stream = FaultyRequestStream(prompts[v_end][0], {0: "stream_end"})

    def submit_all(eng, streams=False):
        for i in range(reqs_per_client):
            for c in range(n_clients):
                stream = None
                if streams and i == 0 and c == s_err:
                    stream = err_stream
                elif streams and i == 0 and c == v_end:
                    stream = end_stream
                if stream is not None:
                    eng.submit(Request(client_id=c, prompt=None,
                                       prompt_stream=stream,
                                       max_new_tokens=4, arrive_tick=0))
                else:
                    eng.submit(Request(client_id=c,
                                       prompt=prompts[c][i].copy(),
                                       max_new_tokens=4, arrive_tick=0))

    def build(bank_tree, hook=None, obs=None):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return ServingEngine(cfg, _lora(), scfg, base, bank_tree,
                                 max_batch_per_client=2, debug=True,
                                 fault_hook=hook, obs=obs)

    clean_eng = build(bank)
    submit_all(clean_eng)
    clean = clean_eng.run()
    # keyed by prompt bytes: a transient admission fault legally delays a
    # retried request by a tick, which can reorder retirement WITHIN a
    # client — the bitwise contract is per-request, not per-position
    clean_of = {}
    for r in clean:
        clean_of.setdefault(r.client_id, {})[r.prompt.tobytes()] = \
            r.generated.copy()

    poisoned = bank
    for v in victims:
        poisoned = _poison_client(poisoned, v)
    hook = AllocHook({1, 4, 7})
    obs = Obs()
    eng = build(poisoned, hook, obs=obs)
    submit_all(eng, streams=True)
    done = eng.run()

    got = {}
    for r in done:
        got.setdefault(r.client_id, []).append(r)
    for c in range(n_clients):
        rs = got.get(c, [])
        _check(errors, len(rs) == reqs_per_client,
               f"serving: client {c} retired {len(rs)}/{reqs_per_client}")
        if c in victims:
            _check(errors, all(r.status in ("quarantined", "rejected")
                               for r in rs),
                   f"serving: victim {c} produced non-quarantined requests")
        else:
            _check(errors, all(r.status == "ok" for r in rs),
                   f"serving: survivor {c} has non-ok requests")
            for r in rs:
                ref = clean_of[c].get(r.prompt.tobytes())
                _check(errors,
                       ref is not None and np.array_equal(r.generated, ref),
                       f"serving: survivor {c} stream diverged")
    _check(errors, hook.fired > 0, "serving: no alloc faults fired")
    _check(errors, err_stream.calls >= 2,
           "serving: stream_error request was never retried")
    _check(errors, end_stream.calls >= 1,
           "serving: stream_end request was never fetched")
    _check(errors,
           all(v in eng._quarantined_clients for v in victims),
           "serving: victims not client-quarantined after repeated faults")
    cons = check_conservation(eng)
    _check(errors, not cons, f"serving: conservation: {cons}")

    # the same containment trail must be observable through the
    # client-visible event feed (docs/observability.md)
    ev = eng.drain_events()
    kinds = {e.kind for e in ev}
    for want in ("backoff", "retry", "quarantine", "reject"):
        _check(errors, want in kinds,
               f"serving: no {want!r} event in the telemetry feed")
    _check(errors,
           any(e.kind == "retry" and e.tenant == s_err for e in ev),
           "serving: stream_error retry not visible as a retry event")

    injected = {"nan_adapter": eng.stats["quarantined_requests"],
                "alloc": hook.fired,
                "stream_error": 1, "stream_end": 1}
    return {"scenario": "serving", "injected": injected,
            "total": sum(injected.values()),
            "engine_faults": eng.stats["faults"],
            "quarantined_clients": sorted(eng._quarantined_clients),
            "errors": errors}


# ---------------------------------------------------------------------------
# symbiotic interleave + kill/restore + checkpoint corruption
# ---------------------------------------------------------------------------

def symbiotic_scenario(seed: int, workdir: str, *, n_jobs: int = 4,
                       n_clients: int = 2, steps: int = 8) -> dict:
    """Faulted fine-tuning interleaved with serving over ONE shared base;
    mid-run whole-engine checkpoint, kill, corrupt the newest checkpoint
    on disk, restore (must fall back CRC-clean), and finish — the resumed
    run must match the uninterrupted one bitwise."""
    import jax
    import warnings
    from repro.config import FinetuneConfig, ServeConfig
    from repro.core import symbiosis
    from repro.checkpoint import load_engine_state
    from repro.faults.audit import check_conservation
    from repro.faults.plan import FaultPlan, corrupt_flip, corrupt_truncate
    from repro.serving.engine import Request, ServingEngine
    from repro.training.engine import FinetuneEngine
    from repro.training.service import SymbiosisEngine

    errors: List[str] = []
    cfg = _tiny_cfg()
    scfg = ServeConfig(n_clients=n_clients, max_seq=32, page_block=8,
                       pool_pages=8)
    base, bank, _ = symbiosis.init_system(cfg, _lora(), n_clients,
                                          jax.random.PRNGKey(seed))
    plan = FaultPlan(seed + 2, n_tenants=n_jobs, n_faults=3 * n_jobs,
                     kinds=("stream_error", "stream_error", "nan_batch"),
                     window=(0, steps - 1))
    schedules = {t: plan.stream_schedule(t) for t in range(n_jobs)}
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab, (1, 6)).astype(np.int32)
               for _ in range(n_clients)]

    def build():
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            serving = ServingEngine(cfg, _lora(), scfg, base, bank,
                                    max_batch_per_client=2, debug=True)
            finetune = FinetuneEngine(cfg, base,
                                      fcfg=FinetuneConfig(max_jobs=4),
                                      debug=True)
        return SymbiosisEngine(serving=serving, finetune=finetune)

    def submit_all(sym):
        for c in range(n_clients):
            sym.submit(Request(client_id=c, prompt=prompts[c].copy(),
                               max_new_tokens=6))
        for j in _make_jobs(cfg, n_jobs, steps, schedules):
            sym.submit(j)

    def finish(sym):
        reqs, jobs = sym.run()
        fired = sum(1 for j in jobs for call in j.data.schedule
                    if call < j.data.calls)
        return ({r.client_id: r.generated.copy() for r in reqs},
                {j.name: (j.status, list(j.losses),
                          None if j.result is None else j.result.adapter)
                 for j in jobs}, fired)

    # uninterrupted faulted run (the resume oracle)
    sym_a = build()
    submit_all(sym_a)
    for _ in range(2):
        sym_a.tick()
    ref_reqs, ref_jobs, fired_stream = finish(sym_a)

    # interrupted twin: same 2 ticks, checkpoint twice, corrupt the newest
    ckdir = os.path.join(workdir, "engine_ckpt")
    sym_b = build()
    submit_all(sym_b)
    sym_b.tick()
    sym_b.checkpoint(ckdir)                          # seq 0 (stale)
    sym_b.tick()
    seq = sym_b.checkpoint(ckdir)                    # seq 1 (resume point)
    del sym_b                                        # "kill"

    # a corrupted LATER checkpoint must be skipped by CRC, falling back to
    # the newest valid one (seq 1)
    import shutil
    victim_new = os.path.join(ckdir, f"engine_{seq + 1:08d}.ckpt")
    shutil.copy(os.path.join(ckdir, f"engine_{seq:08d}.ckpt"), victim_new)
    corrupt_flip(victim_new, seed=seed)
    victim_new2 = os.path.join(ckdir, f"engine_{seq + 2:08d}.ckpt")
    shutil.copy(os.path.join(ckdir, f"engine_{seq:08d}.ckpt"), victim_new2)
    corrupt_truncate(victim_new2)
    got_seq, _ = load_engine_state(ckdir)
    _check(errors, got_seq == seq,
           f"symbiotic: restore picked seq {got_seq}, wanted last-good {seq}")

    sym_c = build()
    restored = sym_c.restore(ckdir)
    _check(errors, restored == seq,
           f"symbiotic: restored seq {restored} != {seq}")
    got_reqs, got_jobs, _ = finish(sym_c)

    _check(errors, set(got_reqs) == set(ref_reqs),
           "symbiotic: restored run finished a different request set")
    for c, gen in ref_reqs.items():
        _check(errors, np.array_equal(got_reqs.get(c), gen),
               f"symbiotic: client {c} stream diverged after restore")
    _check(errors, set(got_jobs) == set(ref_jobs),
           "symbiotic: restored run finished a different job set")
    for name, (status, losses, adapter) in ref_jobs.items():
        g_status, g_losses, g_adapter = got_jobs[name]
        _check(errors, g_status == status and g_losses == losses,
               f"symbiotic: job {name} trajectory diverged after restore")
        if adapter is not None:
            _check(errors, _trees_equal(g_adapter, adapter),
                   f"symbiotic: job {name} adapter not bitwise after restore")
    for eng in (sym_c.serving, sym_c.finetune):
        cons = check_conservation(eng)
        _check(errors, not cons, f"symbiotic: conservation: {cons}")

    injected = {"stream": fired_stream, "ckpt_corrupt": 2}
    return {"scenario": "symbiotic", "injected": injected,
            "total": fired_stream + 2,
            "restored_seq": restored, "errors": errors}


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def run_sweep(seed: int = 0, workdir: Optional[str] = None,
              min_faults: int = 30, min_kinds: int = 4) -> dict:
    """Run every scenario and return the containment report (never raises
    on contract violations — check ``report["ok"]`` / ``report["errors"]``,
    which is what the chaos tests and CI assert on)."""
    import tempfile
    if workdir is None:
        workdir = tempfile.mkdtemp(prefix="chaos_")
    results = [finetune_scenario(seed),
               serving_scenario(seed),
               symbiotic_scenario(seed, workdir)]
    kinds = set()
    total = 0
    errors: List[str] = []
    for r in results:
        total += r["total"]
        kinds |= {k for k, n in r["injected"].items() if n > 0}
        errors += r["errors"]
    if total < min_faults:
        errors.append(f"only {total} faults fired (need >= {min_faults})")
    if len(kinds) < min_kinds:
        errors.append(f"only {len(kinds)} fault kinds fired "
                      f"(need >= {min_kinds})")
    return {"seed": seed, "total_injected": total, "kinds": sorted(kinds),
            "scenarios": results, "errors": errors, "ok": not errors}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="seeded fault-injection chaos sweep (docs/robustness.md)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", type=str, default=None,
                    help="write the JSON containment report here")
    args = ap.parse_args(argv)
    report = run_sweep(args.seed)
    out = json.dumps(report, indent=2, default=str)
    if args.report:
        with open(args.report, "w") as f:
            f.write(out + "\n")
    print(out)
    if not report["ok"]:
        print("\nchaos sweep FAILED:\n  " + "\n  ".join(report["errors"]))
        return 1
    print(f"\nchaos sweep OK: {report['total_injected']} faults across "
          f"{len(report['kinds'])} kinds, all contained")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
