"""Fault containment for multi-tenant engines (docs/robustness.md):
per-tenant health state machine, deterministic fault injection, and
conservation audits. The chaos sweep lives in ``repro.faults.chaos``
(``python -m repro.faults.chaos``)."""
from repro.faults.health import (FatalFault, HealthPolicy, HealthRecord,
                                 HealthState, TransientFault, classify)
from repro.faults.plan import (KINDS, AllocHook, AllocationFault, FaultEvent,
                               FaultPlan, FaultyStream, NonFiniteFault,
                               StreamError, StreamExhausted, corrupt_flip,
                               corrupt_truncate)
from repro.faults.audit import (check_conservation, finetune_conservation,
                                serving_conservation)
