"""Per-tenant health state machine (docs/robustness.md).

Every tenant (a serving client or a fine-tuning job) carries a
``HealthRecord`` walking::

    HEALTHY --fault--> SUSPECT --retries left--> RESUMED (-> HEALTHY)
                           |
                           +--fatal / retries exhausted--> QUARANTINED
                                                               |
                                                               v
                                                           RETIRED

Transient faults (a stream hiccup, a failed checkpoint write) earn a
bounded exponential backoff measured in ENGINE TICKS — deterministic, no
wall clock — and the tenant retries from its last clean state. Fatal
faults (non-finite loss/grads/logits, stream exhaustion mid-budget,
retries exhausted) quarantine the tenant: its state is checkpointed via
the existing job-checkpoint path where applicable, then it is retired and
every router charge / pool page it held is released. The containment
contract is that survivors never observe any of this: their committed
state is byte-identical to a run where the faulty tenant was never
admitted after its last clean tick (machine-tested in
``tests/test_faults.py`` / the chaos sweep).

Health transitions are also telemetry sources: when an engine runs with
``obs=`` attached, every trip/quarantine/retire call site emits a
structured event (``backoff`` / ``retry`` / ``quarantine`` / ``health``)
into the client-visible event log, and the ``history`` trajectory is
surfaced per tenant as ``fault_history`` — see docs/observability.md.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class HealthState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"          # transient fault, backing off before retry
    QUARANTINED = "quarantined"  # fatal: checkpointed + retired, charges freed
    RETIRED = "retired"          # left the engine (clean completion included)
    RESUMED = "resumed"          # recovered from SUSPECT; HEALTHY on next clean tick


class TransientFault(Exception):
    """Marker base for injected/classified faults that are worth retrying
    (the tenant's state is still clean — the fault hit before commit)."""


class FatalFault(Exception):
    """Marker base for faults that immediately quarantine the tenant."""


def classify(exc: BaseException) -> str:
    """'transient' or 'fatal'. IO-shaped errors (stream hiccups, filesystem
    races) are worth retrying; everything else — including programming
    errors — quarantines rather than loops."""
    if isinstance(exc, TransientFault):
        return "transient"
    if isinstance(exc, FatalFault):
        return "fatal"
    if isinstance(exc, (OSError, IOError, TimeoutError, ConnectionError)):
        return "transient"
    return "fatal"


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Retry/backoff/quarantine knobs (defaults used by both engines)."""
    max_retries: int = 3          # consecutive transient faults before fatal
    backoff_base: int = 1         # ticks of backoff after the 1st fault
    max_backoff: int = 8          # backoff ceiling (ticks)
    client_quarantine_after: int = 2   # serving: faulty REQUESTS before the
    #                                    whole client is refused admission

    def backoff(self, failures: int) -> int:
        """Deterministic exponential backoff: 1, 2, 4, ... capped."""
        return min(self.backoff_base * (2 ** max(failures - 1, 0)),
                   self.max_backoff)


@dataclasses.dataclass
class HealthRecord:
    """One tenant's health trajectory. Pure host state — picklable, part of
    the engine checkpoint."""
    state: HealthState = HealthState.HEALTHY
    failures: int = 0             # consecutive transient faults
    total_faults: int = 0         # lifetime count (report/telemetry)
    next_eligible_tick: int = 0   # SUSPECT tenants skip ticks before this
    history: List[Tuple[int, str, str]] = dataclasses.field(
        default_factory=list)     # (tick, state, reason)

    def _log(self, tick: int, reason: str):
        self.history.append((tick, self.state.value, reason))

    @property
    def active(self) -> bool:
        return self.state not in (HealthState.QUARANTINED, HealthState.RETIRED)

    def eligible(self, tick: int) -> bool:
        """May this tenant run work at ``tick``? (backoff gate)"""
        return self.active and tick >= self.next_eligible_tick

    def last_transition(self) -> Optional[Tuple[int, str, str]]:
        """Newest ``(tick, state, reason)`` history entry — the payload the
        engines attach to health events (docs/observability.md)."""
        return self.history[-1] if self.history else None

    def ok(self, tick: int):
        """A clean committed tick: clears SUSPECT/RESUMED back to HEALTHY."""
        if self.state is HealthState.SUSPECT:
            self.state = HealthState.RESUMED
            self._log(tick, "recovered")
        elif self.state is HealthState.RESUMED:
            self.state = HealthState.HEALTHY
            self._log(tick, "clean")
        self.failures = 0

    def trip(self, tick: int, reason: str, policy: HealthPolicy) -> str:
        """Record a fault at ``tick``; returns the verdict: 'retry' (tenant
        goes SUSPECT with backoff) or 'quarantine' (caller must checkpoint +
        retire + release)."""
        self.total_faults += 1
        self.failures += 1
        if self.failures > policy.max_retries:
            self.state = HealthState.QUARANTINED
            self._log(tick, f"retries exhausted: {reason}")
            return "quarantine"
        self.state = HealthState.SUSPECT
        self.next_eligible_tick = tick + policy.backoff(self.failures)
        self._log(tick, reason)
        return "retry"

    def quarantine(self, tick: int, reason: str):
        self.total_faults += 1
        self.state = HealthState.QUARANTINED
        self._log(tick, reason)

    def retire(self, tick: int, reason: str = "done"):
        if self.state is not HealthState.QUARANTINED:
            self.state = HealthState.RETIRED
        self._log(tick, reason)
