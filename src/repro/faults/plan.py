"""Deterministic fault injection (docs/robustness.md).

``FaultPlan`` turns one integer seed into a reproducible schedule of
injected faults — which tenant, which kind, when — so the chaos sweep
(``repro.faults.chaos``) and the tier-1 fault tests assert containment
against a bit-reproducible adversary. The fault kinds mirror what a real
multi-tenant service sees:

* ``nan_batch``      — a training batch whose loss mask is NaN: the row's
                       loss AND every grad leaf go non-finite (the injected
                       twin of a diverged tenant). Caught by the in-step
                       finite probe; the row's commit is dropped.
* ``nan_adapter``    — a serving client's adapter rows poisoned with NaN
                       (applied by the driver, not the stream): its logits
                       go non-finite; probe catches, request quarantined.
* ``stream_error``   — a transient exception out of ``data.batch`` (an IO
                       hiccup): retried with backoff from clean state.
* ``stream_end``     — the stream runs dry mid-budget: the job completes
                       as ``finished_early``.
* ``alloc_fail``     — an allocation failure mid-admission (transient):
                       the admission rolls back atomically and retries.
* ``ckpt_corrupt``   — a checkpoint file bit-flipped or truncated on disk:
                       CRC validation rejects it; restore falls back.
* ``ckpt_write``     — the checkpoint WRITE itself fails (ENOSPC/EIO or a
                       crash mid-write, injected via ``CkptWriteHook``):
                       no valid new snapshot lands; the previous one stays
                       newest-valid (last-good wins), and a quarantine
                       checkpoint failure never blocks retirement.

``FaultyStream`` wraps a job's data stream and keys its schedule by CALL
COUNT, not step: a retried step (same ``step`` value, next call) draws a
clean batch — which is exactly what makes transient-fault recovery bitwise
(the underlying stream is deterministic in ``step``). Clean calls emit a
loss mask of 1.0, which is bit-identical to running with no mask at all
(``models.losses.lm_loss`` fills ``mask=None`` with ones), so a wrapped
survivor's trajectory equals its unwrapped oracle. Wrap EVERY job in a
bank (survivors get empty schedules) so the stacked batch trees agree.
"""
from __future__ import annotations

import dataclasses
import os
from collections import Counter
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.faults.health import FatalFault, TransientFault

KINDS = ("nan_batch", "nan_adapter", "stream_error", "stream_end",
         "alloc_fail", "ckpt_corrupt", "ckpt_write")
_STREAM_KINDS = ("nan_batch", "stream_error", "stream_end")
# request-stream kinds: prompts can't carry a NaN loss mask, so only the
# delivery faults apply to serving request streams
_REQUEST_KINDS = ("stream_error", "stream_end")


class StreamError(TransientFault):
    """Injected transient data-stream exception (IO hiccup shape)."""


class StreamExhausted(Exception):
    """The data stream ran dry before the job's step budget. Not a fault
    classification — engines catch it explicitly and complete the job as
    ``finished_early`` (checkpointed, charges released)."""


class AllocationFault(TransientFault):
    """Injected allocation failure mid-admission (pool/arena exhaustion
    shape). Transient: the admission rolls back and the tenant retries."""


class CkptWriteFault(TransientFault):
    """Injected checkpoint-write IO error (ENOSPC / EIO / crash-mid-write
    shape). Transient from the engine's point of view: the snapshot that
    failed to land is simply absent — the previous one stays the newest
    valid blob on disk, so a later restore falls back to it (last-good
    wins), and best-effort writers (quarantine checkpoints) swallow it."""


class NonFiniteFault(FatalFault):
    """A tenant's per-row loss/grads/logits went non-finite (the in-step
    probe tripped). Fatal: the state that produced it is suspect."""


class FaultyStream:
    """Wrap a job data stream with a call-count-keyed fault schedule.

    ``schedule`` maps call index -> kind ('nan_batch' | 'stream_error' |
    'stream_end'). Picklable (part of the engine checkpoint): the call
    counter rides along, so a restored engine replays the same schedule
    position."""

    def __init__(self, inner, schedule: Optional[Dict[int, str]] = None):
        self.inner = inner
        self.schedule = dict(schedule or {})
        self.calls = 0

    def batch(self, step: int):
        import jax.numpy as jnp

        call = self.calls
        self.calls += 1
        kind = self.schedule.get(call)
        if kind == "stream_error":
            raise StreamError(f"injected stream error (call {call})")
        if kind == "stream_end":
            raise StreamExhausted(f"injected stream end (call {call})")
        b = dict(self.inner.batch(step))
        fill = np.nan if kind == "nan_batch" else 1.0
        b["mask"] = jnp.full(b["labels"].shape, fill, jnp.float32)
        return b


class FaultyRequestStream:
    """Serving twin of ``FaultyStream``: wraps a REQUEST's prompt delivery.

    A ``Request`` submitted with ``prompt=None, prompt_stream=...`` has its
    prompt resolved by the engine via ``fetch()`` at admission time — the
    serving-side injection point for stream faults (docs/robustness.md).
    The schedule is keyed by CALL COUNT: ``stream_error`` raises a
    transient ``StreamError`` (the client backs off and the fetch is
    retried; the retry draws the SAME prompt, so the finished stream is
    bitwise identical to an unfaulted run), ``stream_end`` raises
    ``StreamExhausted`` (the request is rejected, visible as a ``reject``
    event and an entry in ``Request.fault_history``). Picklable — the call
    counter rides along in engine checkpoints."""

    def __init__(self, prompt, schedule: Optional[Dict[int, str]] = None):
        self.prompt = np.asarray(prompt, np.int32)
        self.schedule = dict(schedule or {})
        self.calls = 0

    def fetch(self):
        call = self.calls
        self.calls += 1
        kind = self.schedule.get(call)
        if kind == "stream_error":
            raise StreamError(f"injected request-stream error (call {call})")
        if kind == "stream_end":
            raise StreamExhausted(f"injected request-stream end (call {call})")
        return self.prompt


class AllocHook:
    """Admission fault hook: raises ``AllocationFault`` on scheduled
    admission-attempt indices. Install as ``engine.fault_hook``; the engine
    calls it once per admission attempt BEFORE any state mutates beyond
    the (rolled-back) router charge."""

    def __init__(self, at: Iterable[int] = ()):
        self.at = set(at)
        self.calls = 0
        self.fired = 0

    def __call__(self, point: str, tenant) -> None:
        call = self.calls
        self.calls += 1
        if call in self.at:
            self.fired += 1
            raise AllocationFault(
                f"injected allocation failure ({point}, attempt {call})")


class CkptWriteHook:
    """Checkpoint-write fault hook, installed via
    ``checkpoint.set_write_fault_hook`` and consulted by every checkpoint
    writer BEFORE its payload reaches a final filename. Keyed by WRITE
    call index (like ``AllocHook`` is keyed by admission attempt). Two
    failure shapes:

    * ``mode="io_error"`` — raise before any byte lands: the atomic
      temp-file staging in ``save_engine_state`` / the manifest-last
      protocol in ``save_checkpoint`` mean NO new snapshot appears.
    * ``mode="torn"`` — a torn write: leave a truncated frame AT the
      final engine-blob path (the non-atomic-writer / power-cut shape),
      then raise. Restore must reject the torn frame and fall back to
      the last good blob. Leaf-file checkpoints (``frame is None``)
      degrade to ``io_error`` — their manifest-last protocol already
      makes a torn write invisible.
    """

    def __init__(self, at: Iterable[int] = (), mode: str = "io_error"):
        if mode not in ("io_error", "torn"):
            raise ValueError(f"unknown ckpt_write mode {mode!r}")
        self.at = set(at)
        self.mode = mode
        self.calls = 0
        self.fired = 0

    def __call__(self, point: str, path: str, frame) -> None:
        call = self.calls
        self.calls += 1
        if call not in self.at:
            return
        self.fired += 1
        if self.mode == "torn" and frame is not None:
            with open(path, "wb") as f:
                f.write(bytes(frame[: max(1, len(frame) // 2)]))
        raise CkptWriteFault(
            f"injected checkpoint-write fault ({self.mode}, {point}, "
            f"write {call}): {path}")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str       # one of KINDS
    tenant: int     # scenario-local victim index
    at: int         # stream call index / attempt index / tick it fires at


class FaultPlan:
    """Seeded, reproducible fault schedule over ``n_tenants`` tenants.

    Kinds round-robin through ``kinds`` (guaranteed coverage of every
    requested kind); victims and firing times are drawn from
    ``np.random.default_rng(seed)``. The same (seed, n_tenants, n_faults,
    kinds, window) always yields the same events."""

    def __init__(self, seed: int, *, n_tenants: int, n_faults: int,
                 kinds: Sequence[str] = KINDS,
                 window: Tuple[int, int] = (1, 6)):
        for k in kinds:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        events = []
        for i in range(n_faults):
            events.append(FaultEvent(
                kind=kinds[i % len(kinds)],
                tenant=int(rng.integers(n_tenants)),
                at=int(rng.integers(window[0], window[1]))))
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self.seed = seed
        self.n_tenants = n_tenants

    def of_kind(self, *kinds: str) -> List[FaultEvent]:
        return [e for e in self.events if e.kind in kinds]

    def counts(self) -> Dict[str, int]:
        return dict(Counter(e.kind for e in self.events))

    def victims(self, *kinds: str) -> set:
        return {e.tenant for e in (self.of_kind(*kinds) if kinds
                                   else self.events)}

    def stream_schedule(self, tenant: int) -> Dict[int, str]:
        """Call-index -> kind map for ``FaultyStream`` (stream kinds only;
        first event wins a contested call index)."""
        sched: Dict[int, str] = {}
        for e in self.events:
            if e.tenant == tenant and e.kind in _STREAM_KINDS:
                sched.setdefault(e.at, e.kind)
        return sched

    def request_schedule(self, tenant: int) -> Dict[int, str]:
        """Call-index -> kind map for ``FaultyRequestStream`` (delivery
        kinds only; first event wins a contested call index)."""
        sched: Dict[int, str] = {}
        for e in self.events:
            if e.tenant == tenant and e.kind in _REQUEST_KINDS:
                sched.setdefault(e.at, e.kind)
        return sched

    def alloc_schedule(self) -> set:
        """Admission-attempt indices at which ``AllocHook`` fires."""
        return {e.at for e in self.of_kind("alloc_fail")}

    def ckpt_write_schedule(self) -> set:
        """Checkpoint-write call indices at which ``CkptWriteHook`` fires."""
        return {e.at for e in self.of_kind("ckpt_write")}


# ---------------------------------------------------------------------------
# on-disk corruption (for the ckpt_corrupt kind and the corruption tests)

def corrupt_flip(path: str, *, seed: int = 0) -> int:
    """XOR one seeded byte of ``path`` with 0xFF (always a real change).
    Returns the flipped offset."""
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if not data:
        raise ValueError(f"{path} is empty")
    off = int(np.random.default_rng(seed).integers(len(data)))
    data[off] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))
    return off


def corrupt_truncate(path: str, keep: Optional[int] = None) -> int:
    """Truncate ``path`` (default: to half its size). Returns kept bytes."""
    size = os.path.getsize(path)
    keep = size // 2 if keep is None else keep
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep
