"""Post-tick conservation audit (docs/robustness.md).

Every resource the engines hand out — pool pages, cache slots, router HBM
charges, bank slots — is conserved: what's free plus what's allocated must
equal what existed, and the router's live counters must equal its initial
capacities minus its outstanding placements. The audits here recompute
those identities from scratch (no trust in the incremental counters) and
return human-readable error strings; empty list == conserved.

Run automatically after every tick when an engine is constructed with
``debug=True``, in the fault/chaos tests, and callable any time via
``check_conservation(engine)``.
"""
from __future__ import annotations

from typing import List


def serving_conservation(eng) -> List[str]:
    """ServingEngine: page-pool partition, reservation accounting, slot
    ownership and activity-state consistency, router ledger."""
    errs: List[str] = []
    if getattr(eng, "_paged", False):
        P = eng._pool_pages
        # shared-prefix pages (docs/prefix_cache.md): a live ref-held page
        # appears in the content index (with refs >= 1) and in one or more
        # slots' _slot_shared lists, but in NO free list and NO exclusive
        # list — it joins the partition identity exactly once, attributed
        # to the client range it was popped from
        index = getattr(eng, "_prefix_index", None)
        page_refs = index.page_refs() if index is not None else {}
        for c in range(eng.n_clients):
            assigned = [p for (cc, s), pages in eng._slot_pages.items()
                        if cc == c for p in pages]
            shared_live = [p for p in page_refs if c * P <= p < (c + 1) * P]
            have = sorted(eng._free_pages[c] + assigned + shared_live)
            own = list(range(c * P, (c + 1) * P))
            if have != own:
                lost = set(own) - set(have)
                dup = [p for p in have if have.count(p) > 1]
                errs.append(f"client {c}: page pool not conserved "
                            f"(lost={sorted(lost)}, duplicated={sorted(set(dup))})")
            if eng._reserved[c] < 0:
                errs.append(f"client {c}: negative reservation "
                            f"{eng._reserved[c]}")
            if eng._reserved[c] > len(eng._free_pages[c]):
                errs.append(f"client {c}: reserved {eng._reserved[c]} > "
                            f"{len(eng._free_pages[c])} free pages (a running "
                            "sequence could starve)")
        if sum(eng._resv_of.values()) != sum(eng._reserved):
            errs.append(f"reservation ledger {sum(eng._resv_of.values())} != "
                        f"per-client reserved {sum(eng._reserved)}")
        # refcount identity: the index's total references == the total
        # _slot_shared memberships (every holder counted once, no leaked or
        # phantom refs), and every held page is actually published
        slot_shared = getattr(eng, "_slot_shared", {})
        held = [p for pages in slot_shared.values() for p in pages]
        if sum(page_refs.values()) != len(held):
            errs.append(f"prefix index refs {sum(page_refs.values())} != "
                        f"slot_shared memberships {len(held)} "
                        "(leaked or phantom reference)")
        for p in held:
            if p not in page_refs:
                errs.append(f"slot_shared holds page {p} that the prefix "
                            "index no longer publishes (use-after-free)")
    # slot ownership <-> per-request slot lists are inverse maps
    owned = {}
    for c in range(eng.n_clients):
        for s in range(eng.max_b):
            owner = eng._slot_owner[c][s]
            if owner is not None:
                owned.setdefault(id(owner), []).append((c, s))
                if s not in eng._slots_of.get(id(owner), []):
                    errs.append(f"slot ({c},{s}) owned by a request that "
                                "doesn't list it in _slots_of")
    for rid, slots in eng._slots_of.items():
        if sorted(s for _, s in owned.get(rid, [])) != sorted(slots):
            errs.append(f"request {rid}: _slots_of {slots} != owned slots "
                        f"{owned.get(rid)}")
    # activity state matches slot lists
    for c in range(eng.n_clients):
        mask_slots = sorted(int(s) for s in range(eng.max_b)
                            if eng._active_mask[c, s])
        if mask_slots != sorted(eng._active_slots[c]):
            errs.append(f"client {c}: _active_mask {mask_slots} != "
                        f"_active_slots {sorted(eng._active_slots[c])}")
    # every in-flight request holds exactly one placement entry (may be None)
    for r in eng._inflight:
        if id(r) not in eng._placement:
            errs.append(f"in-flight request of client {r.client_id} has no "
                        "placement entry")
    if eng.router is not None:
        errs.extend(eng.router.conservation_errors())
    return errs


def finetune_conservation(eng) -> List[str]:
    """FinetuneEngine: bank-slot <-> job map inversion, step bookkeeping,
    per-job placement entries, router ledger."""
    errs: List[str] = []
    seen = {}
    for key, bank in eng._banks.items():
        for s, job in enumerate(bank.slots):
            if job is None:
                continue
            seen[id(job)] = (key, s)
            if eng._slot_of.get(id(job)) != (key, s):
                errs.append(f"job {job.name or id(job)}: bank slot ({key}, "
                            f"{s}) != _slot_of {eng._slot_of.get(id(job))}")
            if id(job) not in eng._step_of:
                errs.append(f"job {job.name or id(job)}: active without a "
                            "step counter")
    for jid, where in eng._slot_of.items():
        if seen.get(jid) != where:
            errs.append(f"_slot_of entry {where} has no backing bank slot")
        if jid not in eng._placement:
            errs.append(f"active job {jid} has no placement entry")
    for jid in eng._placement:
        if jid not in eng._slot_of:
            errs.append(f"placement held for a job that is not active "
                        f"(leaked charge): {jid}")
    if eng.router is not None:
        errs.extend(eng.router.conservation_errors())
    return errs


def check_conservation(engine) -> List[str]:
    """Dispatch on engine type; accepts a SymbiosisEngine too (audits both
    halves plus their shared router once)."""
    from repro.serving.engine import ServingEngine
    from repro.training.engine import FinetuneEngine

    if isinstance(engine, ServingEngine):
        return serving_conservation(engine)
    if isinstance(engine, FinetuneEngine):
        return finetune_conservation(engine)
    errs = []
    serving = getattr(engine, "serving", None)
    finetune = getattr(engine, "finetune", None)
    if serving is not None:
        errs.extend(f"serving: {e}" for e in serving_conservation(serving))
    if finetune is not None:
        errs.extend(f"finetune: {e}" for e in finetune_conservation(finetune))
    return errs
