from repro.checkpoint.ckpt import (save_checkpoint, restore_checkpoint,
                                   save_job_state, restore_job_state,
                                   latest_step)
