from repro.checkpoint.ckpt import (CheckpointCorruptError, save_checkpoint,
                                   restore_checkpoint, save_job_state,
                                   restore_job_state, latest_step,
                                   save_engine_state, load_engine_state,
                                   set_write_fault_hook)
