"""Sharding-aware checkpointing (pure numpy + json manifest, no extra deps).

Layout:  <dir>/step_<N>/
           manifest.json   — tree structure, shapes, dtypes, per-leaf CRC32s
           arr_<i>.npy     — one file per leaf

The Symbiosis split shows up here too: the *base* checkpoint is written once
and shared; each client's adapter + optimizer state is a separate (tiny)
checkpoint, so clients save/restore independently — the as-a-service
persistence story (clients own their state, the provider owns the base).

Restore accepts an optional sharding tree: leaves are device_put with their
target sharding so a restored state is immediately usable under pjit.

Integrity (docs/robustness.md): every leaf's CRC32 is recorded in the
manifest at save time and re-verified at restore — a truncated or bit-
flipped array file raises ``CheckpointCorruptError`` instead of silently
deserializing garbage into a tenant's optimizer state. Manifests are
written via temp-file + atomic rename, and written LAST, so a crashed save
never leaves a manifest pointing at half-written arrays.

``save_engine_state`` / ``load_engine_state`` carry whole-ENGINE snapshots
(serving bookkeeping, allocator state, train jobs — see
``ServingEngine.engine_state``) as a single CRC-framed pickle blob per
sequence number; ``load_engine_state`` scans newest → oldest and falls back
to the last checkpoint whose frame validates.
"""
from __future__ import annotations

import json
import os
import pickle
import re
import struct
import zlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity validation (CRC mismatch, truncated
    file, or unreadable frame) — never silently deserialized."""


# checkpoint-write fault injection (docs/robustness.md): the ``ckpt_write``
# fault kind installs a hook here (``repro.faults.plan.CkptWriteHook``)
# that every writer consults BEFORE its payload reaches a final name. A
# raising hook models an IO error (ENOSPC/EIO) or a crash mid-write; the
# atomic temp-file staging below means a failed write leaves the previous
# snapshot as the newest valid one — last-good wins on restore.
_WRITE_FAULT_HOOK = None


def set_write_fault_hook(hook):
    """Install (or clear, with ``None``) the checkpoint-write fault hook.
    Called as ``hook(point, path, frame)`` where ``point`` names the writer
    (``"engine_state"`` | ``"checkpoint"``) and ``frame`` is the serialized
    blob for engine snapshots (``None`` for leaf-file checkpoints). Returns
    the previously installed hook so tests can restore it."""
    global _WRITE_FAULT_HOOK
    prev = _WRITE_FAULT_HOOK
    _WRITE_FAULT_HOOK = hook
    return prev


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).tobytes())


def save_checkpoint(directory: str, step: int, tree: Any, *, name: str = "state"):
    """Write one pytree. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}", name)
    os.makedirs(path, exist_ok=True)
    if _WRITE_FAULT_HOOK is not None:
        _WRITE_FAULT_HOOK("checkpoint", path, None)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"paths": paths, "dtypes": [], "shapes": [], "crcs": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["dtypes"].append(str(arr.dtype))
        manifest["shapes"].append(list(arr.shape))
        manifest["crcs"].append(_leaf_crc(arr))
        np.save(os.path.join(path, f"arr_{i}.npy"), arr)
    # manifest last + atomic rename: a crash mid-save leaves arrays without
    # a manifest (an incomplete dir restore never trusts), never a manifest
    # pointing at half-written arrays
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, "manifest.json"))
    return path


def restore_checkpoint(directory: str, step: int, like: Any, *, name: str = "state",
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated,
    per-leaf CRCs verified — corruption raises ``CheckpointCorruptError``)."""
    path = os.path.join(directory, f"step_{step:08d}", name)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    if paths != manifest["paths"]:
        raise ValueError(f"checkpoint tree mismatch:\n got {manifest['paths'][:5]}...\n"
                         f" want {paths[:5]}...")
    crcs = manifest.get("crcs")           # pre-CRC checkpoints stay readable
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (leaf, shd) in enumerate(zip(leaves, shard_leaves)):
        fname = os.path.join(path, f"arr_{i}.npy")
        try:
            arr = np.load(fname)
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorruptError(
                f"leaf {paths[i]}: unreadable/truncated {fname}: {e}") from e
        if crcs is not None and _leaf_crc(arr) != crcs[i]:
            raise CheckpointCorruptError(
                f"leaf {paths[i]}: CRC mismatch in {fname} — checkpoint is "
                "corrupt (bit flip or partial write)")
        want_shape = tuple(leaf.shape)
        if arr.shape != want_shape:
            raise ValueError(f"leaf {paths[i]}: shape {arr.shape} != {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def save_job_state(directory: str, step: int, adapter: Any, opt: Any, *,
                   name: str = "job") -> str:
    """Persist one fine-tuning JOB's client-side state — adapter params +
    AdamW state — as a single checkpoint (the as-a-service persistence
    unit: a retired job carries this out, a resumed job carries it back in
    via ``FinetuneJob(init_adapter=..., init_opt=..., start_step=step)``).
    The roundtrip is exact (float arrays stored verbatim by np.save), which
    is what makes resume-after-retire bitwise."""
    return save_checkpoint(directory, step, {"adapter": adapter, "opt": opt},
                           name=name)


def restore_job_state(directory: str, step: int, like_adapter: Any,
                      like_opt: Any, *, name: str = "job"):
    """Inverse of ``save_job_state``: returns ``(adapter, opt)`` restored
    into the structures of the given exemplars."""
    out = restore_checkpoint(directory, step,
                             {"adapter": like_adapter, "opt": like_opt},
                             name=name)
    return out["adapter"], out["opt"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


# ---------------------------------------------------------------------------
# whole-engine snapshots: CRC-framed pickle blobs, newest-valid-wins restore

_ENGINE_MAGIC = b"SYMB"
_ENGINE_RE = re.compile(r"engine_(\d+)\.ckpt$")


def save_engine_state(directory: str, state: Any, *, seq: Optional[int] = None) -> str:
    """Write one whole-engine snapshot as ``engine_<seq:08d>.ckpt``.

    Frame: 4-byte magic | u64 payload length | u32 CRC32 | pickle payload,
    written to a temp file and ``os.replace``d into place — a crash mid-
    write leaves only the previous snapshot visible. Returns the path."""
    os.makedirs(directory, exist_ok=True)
    if seq is None:
        seqs = [int(m.group(1)) for d in os.listdir(directory)
                if (m := _ENGINE_RE.match(d))]
        seq = (max(seqs) + 1) if seqs else 0
    payload = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
    frame = (_ENGINE_MAGIC + struct.pack("<QI", len(payload),
                                         zlib.crc32(payload)) + payload)
    path = os.path.join(directory, f"engine_{seq:08d}.ckpt")
    if _WRITE_FAULT_HOOK is not None:
        _WRITE_FAULT_HOOK("engine_state", path, frame)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(frame)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def _read_engine_frame(path: str) -> Any:
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) < 16 or blob[:4] != _ENGINE_MAGIC:
        raise CheckpointCorruptError(f"{path}: bad magic / truncated header")
    length, crc = struct.unpack("<QI", blob[4:16])
    payload = blob[16:]
    if len(payload) != length:
        raise CheckpointCorruptError(
            f"{path}: truncated payload ({len(payload)} != {length} bytes)")
    if zlib.crc32(payload) != crc:
        raise CheckpointCorruptError(f"{path}: CRC mismatch — corrupt blob")
    return pickle.loads(payload)


def load_engine_state(directory: str, *, seq: Optional[int] = None) -> Tuple[int, Any]:
    """Load the newest VALID engine snapshot (or the given ``seq``).

    Returns ``(seq, state)``. Corrupt snapshots (bad magic, truncation,
    CRC mismatch, unpicklable payload) are skipped with a fallback to the
    next-newest — the last-good-wins contract; raises
    ``CheckpointCorruptError`` only when no snapshot validates, and
    ``FileNotFoundError`` when none exists at all."""
    if not os.path.isdir(directory):
        raise FileNotFoundError(f"no engine checkpoints under {directory}")
    seqs = sorted((int(m.group(1)) for d in os.listdir(directory)
                   if (m := _ENGINE_RE.match(d))), reverse=True)
    if seq is not None:
        seqs = [s for s in seqs if s == seq]
    if not seqs:
        raise FileNotFoundError(f"no engine checkpoints under {directory}")
    errors = []
    for s in seqs:
        path = os.path.join(directory, f"engine_{s:08d}.ckpt")
        try:
            return s, _read_engine_frame(path)
        except (CheckpointCorruptError, pickle.UnpicklingError, EOFError,
                AttributeError, ImportError) as e:
            errors.append(f"{path}: {e}")
    raise CheckpointCorruptError(
        "all engine checkpoints failed validation:\n  " + "\n  ".join(errors))
