"""Sharding-aware checkpointing (pure numpy + json manifest, no extra deps).

Layout:  <dir>/step_<N>/
           manifest.json   — tree structure, shapes, dtypes
           arr_<i>.npy     — one file per leaf

The Symbiosis split shows up here too: the *base* checkpoint is written once
and shared; each client's adapter + optimizer state is a separate (tiny)
checkpoint, so clients save/restore independently — the as-a-service
persistence story (clients own their state, the provider owns the base).

Restore accepts an optional sharding tree: leaves are device_put with their
target sharding so a restored state is immediately usable under pjit.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any, *, name: str = "state"):
    """Write one pytree. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}", name)
    os.makedirs(path, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"paths": paths, "dtypes": [], "shapes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["dtypes"].append(str(arr.dtype))
        manifest["shapes"].append(list(arr.shape))
        np.save(os.path.join(path, f"arr_{i}.npy"), arr)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return path


def restore_checkpoint(directory: str, step: int, like: Any, *, name: str = "state",
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    path = os.path.join(directory, f"step_{step:08d}", name)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    if paths != manifest["paths"]:
        raise ValueError(f"checkpoint tree mismatch:\n got {manifest['paths'][:5]}...\n"
                         f" want {paths[:5]}...")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (leaf, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        want_shape = tuple(leaf.shape)
        if arr.shape != want_shape:
            raise ValueError(f"leaf {paths[i]}: shape {arr.shape} != {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def save_job_state(directory: str, step: int, adapter: Any, opt: Any, *,
                   name: str = "job") -> str:
    """Persist one fine-tuning JOB's client-side state — adapter params +
    AdamW state — as a single checkpoint (the as-a-service persistence
    unit: a retired job carries this out, a resumed job carries it back in
    via ``FinetuneJob(init_adapter=..., init_opt=..., start_step=step)``).
    The roundtrip is exact (float arrays stored verbatim by np.save), which
    is what makes resume-after-retire bitwise."""
    return save_checkpoint(directory, step, {"adapter": adapter, "opt": opt},
                           name=name)


def restore_job_state(directory: str, step: int, like_adapter: Any,
                      like_opt: Any, *, name: str = "job"):
    """Inverse of ``save_job_state``: returns ``(adapter, opt)`` restored
    into the structures of the given exemplars."""
    out = restore_checkpoint(directory, step,
                             {"adapter": like_adapter, "opt": like_opt},
                             name=name)
    return out["adapter"], out["opt"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None
