"""Sharding plans: path/shape rules -> PartitionSpec trees (DESIGN.md §5).

Base params:   tensor-parallel over ``model`` (column-parallel up/qkv,
               row-parallel down/o — with the GQA kv-replication caveat:
               q/o shard only when H % model == 0, kv only when
               K % model == 0, else replicated), expert-parallel MoE
               (experts over ``model``), replicated over data/pod.
Client state:  leading client axis over (pod, data); KV-cache T axis over
               ``model`` (flash-decode style cross-chip cache split);
               RWKV wkv-state heads / Mamba expanded-dim over ``model``.
Batches:       leading client axis over (pod, data).

Every rule checks divisibility and falls back to replication — the plan is
total over any architecture in the registry.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.launch.mesh import batch_axes, batch_size, model_size

# Leaf names (last path component) -> role.
_COL = {"gate", "up", "fc1", "cm_k", "in_proj", "dt_proj", "wr", "wg",
        "embed", "enc_pos", "dec_pos"}
_ROW = {"wo", "down", "fc2", "cm_v", "out_proj"}
_KV = {"wk", "wv"}
# KV-cache leaf names whose T axis (ndim-3) shards over model.
_KVCACHE = {"k", "v", "self_k", "self_v"}


def _path_names(path) -> list:
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _canon_specs(mesh, spec_tree):
    """Drop size-1 mesh axes from every PartitionSpec entry.

    XLA canonicalizes shardings: a jit OUTPUT partitioned over a trivial
    axis comes back as replicated (``P()``), and trailing ``None`` entries
    are dropped — so a ``device_put`` input spec that still carries them
    would differ from the output spec of the previous tick, a signature
    flip that recompiles the donated-state hot loop on every call.
    Canonicalizing here keeps placements and constraints in the same
    normal form on any mesh (host (1,1) included)."""
    def entry(e):
        if e is None:
            return None
        names = e if isinstance(e, tuple) else (e,)
        names = tuple(n for n in names if mesh.shape[n] > 1)
        return None if not names else (names if len(names) > 1 else names[0])

    def canon(p):
        es = [entry(e) for e in p]
        while es and es[-1] is None:
            es.pop()
        return P(*es)

    return jax.tree.map(canon, spec_tree, is_leaf=lambda x: isinstance(x, P))


# A frozen base leaf whose model-sharded size still exceeds this gets an
# additional data-axis shard (the paper's FSDP-sharded base executor mode —
# frozen weights are all-gathered per layer, never gradient-synced).
_FSDP_THRESHOLD_BYTES = 4e9


def base_param_specs(cfg: ModelConfig, mesh, params_shape) -> object:
    """PartitionSpec tree for the frozen base parameter tree.

    ``params_shape``: tree of ShapeDtypeStruct (from jax.eval_shape)."""
    import numpy as np
    msize = model_size(mesh)
    baxes = batch_axes(mesh)
    H, K = cfg.hp, cfg.n_kv_heads

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        spec = [None] * nd

        def set_axis(ax, ok=True):
            if ok and _div(leaf.shape[ax], msize):
                spec[ax] = "model"

        def maybe_fsdp():
            """Shard one more dim over (pod, data) if the leaf is huge."""
            import jax.numpy as jnp_
            itemsize = jnp_.dtype(leaf.dtype).itemsize
            n = int(np.prod(leaf.shape)) * itemsize
            shards = msize if "model" in spec else 1
            if n / shards <= _FSDP_THRESHOLD_BYTES:
                return
            dsize = batch_size(mesh)
            for ax in range(nd - 1, -1, -1):
                if spec[ax] is None and _div(leaf.shape[ax], dsize):
                    spec[ax] = baxes if len(baxes) > 1 else baxes[0]
                    return

        if "experts" in names and nd >= 3:
            # [.., E, din, dout] -> expert-parallel over E
            set_axis(nd - 3, ok=_div(leaf.shape[nd - 3], msize))
            maybe_fsdp()
        elif name in _COL and nd >= 2:
            set_axis(nd - 1)
        elif name == "wq" and nd >= 2:
            set_axis(nd - 1, ok=_div(H, msize))
        elif name in _KV and nd >= 2:
            # rwkv uses wk/wv as [d,d] channel projections: always shardable;
            # attention K/V projections only when K % model == 0.
            is_square = leaf.shape[nd - 1] == cfg.d_model
            set_axis(nd - 1, ok=is_square or _div(K, msize))
        elif name in _ROW and nd >= 2:
            ok = True
            if name == "wo":
                ok = _div(H, msize)
            set_axis(nd - 2, ok=ok)
        elif name == "lm_head" and nd >= 2:
            if _div(leaf.shape[nd - 1], msize):
                spec[nd - 1] = "model"          # vocab-parallel
            elif _div(leaf.shape[nd - 2], msize):
                spec[nd - 2] = "model"          # row-parallel (odd vocab)
        return P(*spec)

    return _canon_specs(mesh, jax.tree_util.tree_map_with_path(
        rule, params_shape))


def client_state_specs(cfg: ModelConfig, mesh, tree_shape,
                       *, client_axis: bool = True,
                       full_mesh: bool = False) -> object:
    """Spec tree for client banks / optimizer state / caches / batches.

    Leading client axis shards over (pod, data) when divisible. KV caches
    additionally shard their T axis over ``model``; when the client axis
    cannot shard (e.g. long_500k C=1) the T axis takes (pod, data, model) —
    sequence-parallel decode across the whole mesh.

    full_mesh=True (replicated-base client-parallel): the client axis
    spreads over EVERY mesh axis (pod, data, model) and nothing shards over
    model separately."""
    baxes = batch_axes(mesh)
    bsize = batch_size(mesh)
    msize = model_size(mesh)
    if full_mesh:
        baxes = baxes + ("model",)
        bsize *= msize
        msize = 1

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        spec = [None] * nd
        c_sharded = False
        if client_axis and nd >= 1 and _div(leaf.shape[0], bsize):
            spec[0] = baxes if len(baxes) > 1 else baxes[0]
            c_sharded = True
        if name in _KVCACHE and nd >= 4:
            t_ax = nd - 3
            if t_ax > 0:
                if c_sharded or not client_axis:
                    if _div(leaf.shape[t_ax], msize):
                        spec[t_ax] = "model"
                else:
                    # client axis unshardable: spread T over the whole mesh
                    full = bsize * msize
                    if _div(leaf.shape[t_ax], full):
                        spec[t_ax] = baxes + ("model",)
                    elif _div(leaf.shape[t_ax], msize):
                        spec[t_ax] = "model"
        elif name in ("cross_k", "cross_v") and nd >= 4:
            if _div(leaf.shape[nd - 3], msize):
                spec[nd - 3] = "model"
        elif name == "wkv" and nd >= 4:
            if _div(leaf.shape[nd - 3], msize):
                spec[nd - 3] = "model"          # heads of the wkv state
        elif name == "h" and nd >= 2:
            if _div(leaf.shape[nd - 2], msize):
                spec[nd - 2] = "model"          # mamba expanded dim
        elif name == "conv" and nd >= 2:
            if _div(leaf.shape[nd - 1], msize):
                spec[nd - 1] = "model"
        return P(*spec)

    return _canon_specs(mesh, jax.tree_util.tree_map_with_path(
        rule, tree_shape))


def attach(mesh, shape_tree, spec_tree):
    """ShapeDtypeStructs with NamedShardings attached (dry-run stand-ins)."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shape_tree, spec_tree)


# ---------------------------------------------------------------------------
# Engine-state placement (the sharded symbiotic engines — EngineSpec.mesh)
# ---------------------------------------------------------------------------

def put_tree(mesh, tree, spec_tree):
    """``device_put`` a concrete tree onto the mesh per its spec tree.

    Idempotent AND identity-preserving: a leaf already committed with the
    target sharding is returned as-is (same array object) — which is what
    lets ``SymbiosisEngine.from_spec`` shard the base ONCE and have both
    engines' constructors re-run this as a no-op, keeping the leaf-identity
    shared-base check intact."""
    def put(x, p):
        ns = NamedSharding(mesh, p)
        if getattr(x, "sharding", None) == ns:
            return x
        return jax.device_put(x, ns)

    return jax.tree.map(put, tree, spec_tree)


def shard_base_params(cfg: ModelConfig, mesh, params, *,
                      replicate: bool = False):
    """Place the frozen base onto the mesh: ``base_param_specs`` (tensor-
    parallel + FSDP fallback) or fully replicated (``replicate=True`` —
    bitwise-safe pure batch partitioning for models that fit per-chip)."""
    shape = jax.eval_shape(lambda: params)
    specs = (jax.tree.map(lambda s: P(), shape) if replicate
             else base_param_specs(cfg, mesh, shape))
    return put_tree(mesh, params, specs)


def serving_cache_specs(cfg: ModelConfig, scfg, mesh, caches) -> object:
    """Spec tree for a ServingEngine cache tree (concrete OR traced).

    Per-client leaves (positions, block tables, dense KV rows, recurrent
    state) shard their leading client axis over (pod, data); the GLOBAL
    flat page pools have no client axis and shard their PAGE axis over the
    same — client c owns pages [c*P, (c+1)*P), so the page partition IS the
    client partition. Anything indivisible replicates."""
    from repro.core import symbiosis

    cache_kw = symbiosis.serve_cache_kwargs(cfg, scfg, pool_pages=1)
    baxes = batch_axes(mesh)
    bsize = batch_size(mesh)
    name = baxes if len(baxes) > 1 else baxes[0]

    flat_c, treedef = jax.tree_util.tree_flatten(caches)
    if "page_block" in cache_kw:
        page_axes = symbiosis.cache_page_axes(cfg, scfg.max_seq, **cache_kw)
        flat_p = jax.tree_util.tree_flatten(
            page_axes, is_leaf=lambda x: x is None)[0]
    else:
        flat_p = [None] * len(flat_c)

    def rule(x, pax):
        ax = 0 if pax is None else pax
        spec = [None] * x.ndim
        if x.ndim > ax and _div(x.shape[ax], bsize):
            spec[ax] = name
        return P(*spec)

    return _canon_specs(mesh, jax.tree_util.tree_unflatten(
        treedef, [rule(x, pax) for x, pax in zip(flat_c, flat_p)]))


def bank_state_specs(cfg: ModelConfig, mesh, tree, *,
                     replicated: bool = False) -> object:
    """Spec tree for adapter banks / stacked optimizer state: the leading
    client (bank-slot) axis over (pod, data) — or fully replicated (the
    ``BankSpec.placement == "replicated"`` hint)."""
    if replicated:
        return jax.tree.map(lambda x: P(), tree)
    return client_state_specs(cfg, mesh, tree)


def _constrain_tree(mesh, tree, spec_tree):
    return jax.tree.map(
        lambda x, p: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, p)),
        tree, spec_tree)


def serving_cache_constrain(cfg: ModelConfig, scfg, mesh, caches):
    """``with_sharding_constraint`` the cache tree to its canonical specs —
    the in/out pin the engines wrap around their jitted steps so donated
    cache state keeps ONE placement across ticks (no resharding copies, no
    per-tick executable churn)."""
    return _constrain_tree(mesh, caches,
                           serving_cache_specs(cfg, scfg, mesh, caches))


def bank_state_constrain(cfg: ModelConfig, mesh, tree, *,
                         replicated: bool = False):
    """The training-side twin: pin bank params / optimizer state."""
    return _constrain_tree(
        mesh, tree, bank_state_specs(cfg, mesh, tree, replicated=replicated))
