"""Multi-client fine-tuning driver (end-to-end; deliverable b).

On this CPU container it trains REDUCED variants of any assigned arch for
real steps (loss decreases); on TPU hardware the same driver lowers the
full config onto the production mesh (the mesh/sharding path is proven by
``dryrun.py``).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --clients 4 \
      --steps 50 --seq 128 --batch 2 [--peft lora|ia3|prefix] [--full-size]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config import AdapterConfig, TrainConfig
from repro.configs import ARCHS, get_config
from repro.core import symbiosis
from repro.data import make_client_batches
from repro.checkpoint import save_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-4b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2,
                    help="per-client batch (paper uses 2)")
    ap.add_argument("--peft", default="lora", choices=("lora", "ia3", "prefix"))
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU); default: reduced smoke size")
    ap.add_argument("--no-memory-optimized", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model)
    acfg = AdapterConfig(method=args.peft, rank=args.rank,
                         targets=("q", "k", "v", "o"))
    tcfg = TrainConfig(n_clients=args.clients, lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       memory_optimized_backward=not args.no_memory_optimized)

    key = jax.random.PRNGKey(tcfg.seed)
    base, bank, opt = symbiosis.init_system(cfg, acfg, args.clients, key)
    step_fn = jax.jit(symbiosis.make_multi_client_train_step(cfg, acfg, tcfg),
                      donate_argnums=(1, 2))
    stream = make_client_batches(cfg, args.clients, args.batch, args.seq)

    print(f"[train] {cfg.name} | {args.clients} clients × {args.peft} "
          f"(rank {args.rank}) | seq {args.seq} batch {args.batch}")
    hist = []
    t0 = time.time()
    for step in range(args.steps):
        batch = stream.batch(step)
        bank, opt, m = step_fn(base, bank, opt, batch, step)
        loss = jax.device_get(m["loss"])
        hist.append(loss.mean().item())
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            tok_s = (args.clients * args.batch * args.seq * (step + 1)
                     / (time.time() - t0))
            print(f"  step {step:4d} loss/client={[round(x,3) for x in loss.tolist()]} "
                  f"({tok_s:,.0f} tok/s)")
    first, last = hist[0], hist[-1]
    print(f"[train] done: mean loss {first:.3f} -> {last:.3f} "
          f"({100*(first-last)/first:.0f}% drop) in {time.time()-t0:.1f}s")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, bank, name="bank")
        save_checkpoint(args.ckpt_dir, args.steps, jax.tree.map(lambda x: x, opt),
                        name="opt")
        print(f"[train] checkpoint -> {args.ckpt_dir}/step_{args.steps:08d}")
    return first, last


if __name__ == "__main__":
    main()
