"""Multi-job fine-tuning driver — a thin wrapper over the FinetuneEngine
(fine-tuning as a service; deliverable b).

On this CPU container it trains REDUCED variants of any assigned arch for
real steps (loss decreases); on TPU hardware the same driver lowers the
full config onto the production mesh (the mesh/sharding path is proven by
``dryrun.py``). ``--peft mixed`` cycles LoRA / IA3 / prefix across jobs —
heterogeneous banks sharing one engine and one base.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --clients 4 \
      --steps 50 --seq 128 --batch 2 [--peft lora|ia3|prefix|mixed] \
      [--full-size] [--ckpt-dir DIR]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import AdapterConfig, FinetuneConfig
from repro.configs import ARCHS, get_config
from repro.checkpoint import save_job_state
from repro.core.adapters import DEFAULT_TARGETS
from repro.core.engine_spec import EngineSpec
from repro.models import get_model
from repro.training import FinetuneEngine, FinetuneJob, make_job_stream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-4b")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent fine-tuning jobs")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=2,
                    help="per-job batch (paper uses 2)")
    ap.add_argument("--peft", default="lora",
                    choices=("lora", "ia3", "prefix", "mixed"))
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU); default: reduced smoke size")
    ap.add_argument("--no-memory-optimized", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--mesh", nargs=2, type=int, default=None,
                    metavar=("DATA", "MODEL"),
                    help="place the engine on a (data, model) device mesh "
                         "(replicated base, job rows partitioned)")
    ap.add_argument("--obs", default=None, metavar="DIR",
                    help="attach telemetry (docs/observability.md) and write "
                         "telemetry.jsonl + metrics.prom into DIR at exit")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model)

    key = jax.random.PRNGKey(0)
    base = get_model(cfg).init_params(key)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import _make_mesh
        mesh = _make_mesh(tuple(args.mesh), ("data", "model"))
    fcfg = FinetuneConfig(max_jobs=args.clients,
                          memory_optimized=not args.no_memory_optimized)
    spec = EngineSpec(cfg=cfg, finetune=fcfg, mesh=mesh,
                      replicate_base=mesh is not None)
    obs = None
    if args.obs is not None:
        from repro.obs import Obs
        obs = Obs()
    engine = FinetuneEngine(spec, base, obs=obs)

    methods = (("lora", "ia3", "prefix") if args.peft == "mixed"
               else (args.peft,))
    jobs = []
    for c in range(args.clients):
        method = methods[c % len(methods)]
        acfg = AdapterConfig(method=method, rank=args.rank,
                             targets=DEFAULT_TARGETS[method])
        jobs.append(FinetuneJob(
            acfg=acfg, data=make_job_stream(cfg, args.batch, args.seq, seed=c),
            batch_size=args.batch, seq_len=args.seq, steps=args.steps,
            lr=args.lr, warmup_steps=max(1, args.steps // 10),
            microbatch=args.microbatch, seed=c, name=f"{method}-{c}"))
        engine.submit(jobs[-1])

    print(f"[train] {cfg.name} | {args.clients} jobs x {args.peft} "
          f"(rank {args.rank}) | seq {args.seq} batch {args.batch}")
    t0 = time.time()
    tick = 0
    while engine.pending():
        engine.train_tick()
        tick += 1
        if tick % max(1, args.steps // 10) == 0 or not engine.pending():
            losses = [round(j.losses[-1], 3) for j in jobs if j.losses]
            tok_s = engine.stats["train_tokens"] / (time.time() - t0)
            print(f"  tick {tick:4d} loss/job={losses} ({tok_s:,.0f} tok/s)")
    first = float(np.mean([j.result.losses[0] for j in jobs]))
    last = float(np.mean([j.result.losses[-1] for j in jobs]))
    print(f"[train] done: mean loss {first:.3f} -> {last:.3f} "
          f"({100 * (first - last) / first:.0f}% drop) in {time.time() - t0:.1f}s"
          f" | banks={len(engine._banks)} steps={engine.stats['train_steps']}")
    if args.ckpt_dir:
        for j in jobs:
            save_job_state(args.ckpt_dir, j.result.step, j.result.adapter,
                           j.result.opt, name=j.name)
        print(f"[train] per-job checkpoints -> "
              f"{args.ckpt_dir}/step_{jobs[0].result.step:08d}")
    if obs is not None:
        import os
        from repro.obs import export
        os.makedirs(args.obs, exist_ok=True)
        jl = os.path.join(args.obs, "telemetry.jsonl")
        pm = os.path.join(args.obs, "metrics.prom")
        export.write_jsonl(jl, obs)
        export.write_prometheus(pm, obs)
        print(f"[train] telemetry written to {jl} and {pm}")
    return first, last


if __name__ == "__main__":
    main()
