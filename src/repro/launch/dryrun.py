import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape), lower + compile the production step
under the single-pod (16×16) and multi-pod (2×16×16) meshes, print
``memory_analysis()`` (proves the program fits per-chip HBM) and
``cost_analysis()`` (FLOPs/bytes for §Roofline), and append a JSON record
(including collective-traffic accounting parsed from the partitioned HLO)
to ``experiments/dryrun/``.

The two lines above MUST stay the first statements in this module: jax
fixes the device count at first initialization, and only the dry-run wants
512 placeholder CPU devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quiet]
"""
import argparse
import json
import time
import traceback

import jax

from repro.config import SHAPES
from repro.configs import ARCHS, ASSIGNED, get_config
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.common.tree import tree_bytes


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            quiet: bool = False, out_dir: str = "experiments/dryrun",
            memory_optimized: bool = True, remat: bool = True,
            tag: str = "", **spec_kw) -> dict:
    """Lower + compile one combination; returns the result record."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag,
           "memory_optimized": memory_optimized, "ok": False}
    ok, note = specs.is_applicable(arch, shape)
    if not ok:
        rec.update(skipped=True, reason=note)
        if not quiet:
            print(f"[dryrun] {arch} × {shape} × {mesh_name}: SKIP ({note})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    bundle = specs.input_specs(arch, shape, mesh,
                               memory_optimized=memory_optimized, remat=remat,
                               **spec_kw)
    # Donate the mutable state: caches for serve steps, bank+opt for train —
    # decode must update its KV cache in place or HBM doubles.
    donate = (1, 2) if shape == "train_4k" else (2,)
    # The ambient mesh makes the soft sharding constraints in model
    # code (repro.common.constrain) bind to the production mesh.
    with mesh_context(mesh):
        lowered = jax.jit(bundle.fn, donate_argnums=donate).lower(*bundle.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---- memory analysis (proves it fits) ----------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                mem[f] = int(v)
    except Exception as e:                      # CPU backend gaps
        mem["error"] = str(e)
    # Always include the analytic per-device argument footprint.
    arg_bytes_global = sum(tree_bytes(a) for a in bundle.args)
    mem["args_global_bytes"] = int(arg_bytes_global)

    # ---- cost analysis ------------------------------------------------
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals") or "bytes" in k)}
    except Exception as e:
        cost["error"] = str(e)

    # ---- loop-aware analysis from partitioned HLO ---------------------
    # (XLA-CPU cost_analysis counts while bodies once — see hlo_analysis;
    # the walker multiplies scan bodies by their trip counts.)
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    walker = hlo_analysis.analyze_module(hlo)

    # ---- base-collective audit (docs/invariants.md pass 4) ------------
    # Per-layer frozen-weight all-gathers are the FSDP executor mode;
    # a reduce-type collective at an exact base-leaf shape is an error.
    from repro.analysis.collectives import audit_collectives
    audit = audit_collectives(
        hlo, bundle.args[0], target=f"{arch}x{shape}x{mesh_name}",
        allow_kinds=("all-gather", "all-gather-start"))
    rec["base_collective_audit"] = audit.to_dict()

    flops = walker["flops"]
    hbm_bytes = walker["hbm_bytes"]
    rl = hlo_analysis.Roofline(flops=flops, hbm_bytes=hbm_bytes,
                               coll_bytes=float(walker["coll_bytes"]))

    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_tokens = sh.global_batch * (sh.seq_len if sh.kind in ("train", "prefill") else 1)
    mf = hlo_analysis.model_flops(cfg, n_tokens, train=(sh.kind == "train"))
    flops_global = flops * n_dev
    rec.update(
        ok=True, n_devices=n_dev,
        n_clients=bundle.n_clients, batch_per_client=bundle.batch_per_client,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem, cost=cost, collectives=coll,
        walker={k: float(v) for k, v in walker.items()},
        roofline=rl.as_dict(),
        model_flops=mf,
        useful_flops_ratio=(mf / flops_global) if flops_global else None,
        meta=bundle.meta,
    )
    if not quiet:
        print(f"[dryrun] {arch} × {shape} × {mesh_name}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/dev={flops:.3e} bytes/dev={hbm_bytes:.3e}")
        print(f"  collectives: {coll}")
        print(f"  roofline: compute={rl.compute_s:.4f}s memory={rl.memory_s:.4f}s "
              f"collective={rl.collective_s:.4f}s dominant={rl.dominant}")
        if not audit.ok:
            for v in audit.violations:
                print(f"  base-collective audit: {v}")
        print(f"  MODEL_FLOPS/HLO_FLOPS = {rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir, f"{arch}_{shape}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_service(arch: str, *, n_jobs: int = 20, seq_len: int = 4096,
                batch: int = 1, multi_pod: bool = False, quiet: bool = False,
                out_dir: str = "experiments/dryrun", tag: str = "",
                replicate_base: bool = False) -> dict:
    """The promoted service case (paper Table 3: ``n_jobs`` fine-tuning
    adapters time-sharing ONE frozen base): compile the FinetuneEngine's
    compact train step at bank scale under the production mesh and audit
    the partitioned HLO for base-shaped collectives. The CI tier2-sharded
    job runs this on gemma2-27b and uploads ``base_collective_audit``."""
    from repro.analysis.collectives import audit_collectives

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    label = f"service{n_jobs}"
    rec = {"arch": arch, "shape": label, "mesh": mesh_name, "tag": tag,
           "n_jobs": n_jobs, "ok": False}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = specs.service_specs(arch, mesh, n_jobs=n_jobs, batch=batch,
                                 seq_len=seq_len,
                                 replicate_base=replicate_base)
    with mesh_context(mesh):
        lowered = jax.jit(bundle.fn, donate_argnums=(1, 2)).lower(*bundle.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {"args_global_bytes": int(sum(tree_bytes(a) for a in bundle.args))}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                mem[f] = int(v)
    except Exception as e:                      # CPU backend gaps
        mem["error"] = str(e)

    hlo = compiled.as_text()
    audit = audit_collectives(
        hlo, bundle.args[0], target=f"{arch}x{label}x{mesh_name}",
        allow_kinds=("all-gather", "all-gather-start"))
    rec.update(
        ok=True, n_devices=mesh.devices.size,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem, collectives=hlo_analysis.collective_bytes(hlo),
        base_collective_audit=audit.to_dict(), meta=bundle.meta)
    if not quiet:
        print(f"[dryrun] {arch} × {label} × {mesh_name}: "
              f"{'OK' if audit.ok else 'AUDIT FAIL'} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        for v in audit.violations:
            print(f"  base-collective audit: {v}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir, f"{arch}_{label}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if not audit.ok:
        raise SystemExit(f"{arch} {label}: base-collective audit failed")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    # ARCHS (not just ASSIGNED): the service case targets the paper's own
    # eval models — gemma2-27b foremost.
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-memory-optimized", action="store_true",
                    help="paper baseline without §3.6 backward")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode shapes (§Perf it13)")
    ap.add_argument("--replicate-base", action="store_true",
                    help="client-parallel with replicated base (§Perf it12)")
    ap.add_argument("--microbatch-rows", type=int, default=4)
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--service-jobs", type=int, default=None, metavar="N",
                    help="run the N-jobs-one-base service case (compact "
                         "train step at bank scale) instead of the shape "
                         "sweep; default arch gemma2-27b")
    ap.add_argument("--service-seq", type=int, default=4096,
                    help="sequence length for --service-jobs")
    ap.add_argument("--service-batch", type=int, default=1,
                    help="per-job batch for --service-jobs")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.service_jobs:
        run_service(args.arch or "gemma2-27b", n_jobs=args.service_jobs,
                    seq_len=args.service_seq, batch=args.service_batch,
                    multi_pod=args.multi_pod, quiet=args.quiet,
                    out_dir=args.out, tag=args.tag,
                    replicate_base=args.replicate_base)
        return

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, multi_pod=mp, quiet=args.quiet,
                            out_dir=args.out, tag=args.tag,
                            memory_optimized=not args.no_memory_optimized,
                            kv_quant=args.kv_quant,
                            replicate_base=args.replicate_base,
                            microbatch_rows=args.microbatch_rows,
                            capacity_factor=args.capacity_factor)
                except Exception:
                    n_fail += 1
                    print(f"[dryrun] {arch} × {shape} × multi_pod={mp}: FAIL")
                    traceback.print_exc()
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run failures")


if __name__ == "__main__":
    main()
