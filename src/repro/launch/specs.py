"""Per-(arch × shape × mesh) step functions + ShapeDtypeStruct input specs.

The dry-run contract (deliverable e): for every assigned architecture and
input shape, produce the step function that production would run and a tree
of sharded ShapeDtypeStruct stand-ins — weak-type-correct, shardable, zero
allocation — so ``jit(fn).lower(*specs).compile()`` proves the distribution
config is coherent.

Shape kinds map to steps (DESIGN.md §6):
  train_4k      -> multi-client fine-tuning step (C clients × B batch)
  prefill_32k   -> multi-client prefill (forward + cache fill)
  decode_32k    -> multi-client serve_step: ONE token vs seq_len-deep cache
  long_500k     -> serve_step; sub-quadratic archs only (rwkv/jamba native
                   state; llava via its Mistral sliding-window ring cache)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import (AdapterConfig, ModelConfig, ServeConfig, ShapeConfig,
                          TrainConfig, SHAPES, ENCDEC, VLM, RWKV, HYBRID)
from repro.configs import get_config
from repro.core import symbiosis
from repro.launch import shardings
from repro.launch.mesh import batch_size
from jax.sharding import NamedSharding, PartitionSpec as P

# Paper Table 2 "LoRA 3": rank 8 on [q,k,v,o] — the adapter used throughout
# the paper's evaluation (and our dry-runs).
DEFAULT_ADAPTER = AdapterConfig(method="lora", rank=8, targets=("q", "k", "v", "o"))

# long_500k applicability (DESIGN.md §6).
_LONG_OK = {
    "rwkv6-7b": "O(1) recurrent state",
    "jamba-v0.1-52b": "hybrid: KV only on 4 attention layers",
    "llava-next-mistral-7b": "Mistral sliding-window (4096) ring cache",
}
_DECODELESS: set = set()   # all assigned archs have a decode path


@dataclasses.dataclass
class SpecBundle:
    arch: str
    shape: str
    fn: Callable            # the step to lower
    args: tuple             # ShapeDtypeStruct trees (sharded)
    n_clients: int
    batch_per_client: int
    meta: dict


def is_applicable(arch_id: str, shape_name: str) -> tuple:
    if shape_name == "long_500k" and arch_id not in _LONG_OK:
        return False, "full attention, no sub-quadratic variant (DESIGN.md §6)"
    if shape_name in ("decode_32k", "long_500k") and arch_id in _DECODELESS:
        return False, "encoder-only arch has no decode step"
    return True, _LONG_OK.get(arch_id, "")


def _client_split(global_batch: int, mesh, *, full_mesh: bool = False) -> tuple:
    """(n_clients, batch_per_client): client axis fills the (pod,data) mesh —
    or the ENTIRE mesh when full_mesh (replicated-base client-parallel)."""
    bsize = batch_size(mesh)
    if full_mesh:
        from repro.launch.mesh import model_size
        bsize *= model_size(mesh)
    C = min(bsize, global_batch)
    while global_batch % C:
        C -= 1
    return C, global_batch // C


def _frontend_struct(cfg: ModelConfig, C: int, B: int):
    if cfg.arch == ENCDEC:
        return {"frames": jax.ShapeDtypeStruct(
            (C, B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))}
    if cfg.arch == VLM:
        return {"img_embed": jax.ShapeDtypeStruct(
            (C, B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))}
    return {}


def _scalar(mesh, dtype=jnp.int32):
    return jax.ShapeDtypeStruct((), dtype, sharding=NamedSharding(mesh, P()))


def input_specs(arch_id: str, shape_name: str, mesh, *,
                acfg: AdapterConfig = DEFAULT_ADAPTER,
                memory_optimized: bool = True,
                remat: bool = True,
                moe_dispatch: str = "scatter",
                replicate_base: bool = False,
                kv_quant: bool = False,
                microbatch_rows: int = 4,
                capacity_factor: float = 1.25) -> SpecBundle:
    """Build (step_fn, sharded arg specs) for one dry-run combination.

    replicate_base (beyond-paper hillclimb knob): replicate frozen base
    weights over the whole mesh and spread the CLIENT axis over every mesh
    axis — zero tensor-parallel collectives for models that fit per-chip."""
    ok, note = is_applicable(arch_id, shape_name)
    if not ok:
        raise ValueError(f"{arch_id} × {shape_name} skipped: {note}")
    cfg = get_config(arch_id)
    shape: ShapeConfig = SHAPES[shape_name]
    C, B = _client_split(shape.global_batch, mesh, full_mesh=replicate_base)

    # --- state trees (shape-only) ------------------------------------
    sys_shape = jax.eval_shape(
        lambda: symbiosis.init_system(cfg, acfg, C, jax.random.PRNGKey(0)))
    base_s, bank_s, opt_s = sys_shape
    if replicate_base:
        from jax.sharding import PartitionSpec as P_
        base_spec = jax.tree.map(lambda s: P_(), base_s)
        cs = lambda t: shardings.client_state_specs(cfg, mesh, t,
                                                    full_mesh=True)
    else:
        base_spec = shardings.base_param_specs(cfg, mesh, base_s)
        cs = lambda t: shardings.client_state_specs(cfg, mesh, t)
    base = shardings.attach(mesh, base_s, base_spec)
    bank = shardings.attach(mesh, bank_s, cs(bank_s))
    opt = shardings.attach(mesh, opt_s, cs(opt_s))

    meta = {"n_clients": C, "batch_per_client": B, "note": note,
            "seq_len": shape.seq_len, "kind": shape.kind}

    if shape.kind == "train":
        # Microbatch so each accumulation step sees <= microbatch_rows rows
        # per client: activation temps stay inside HBM at 4k sequence
        # length. Fewer microbatches = fewer FSDP weight re-gathers (§Perf).
        nmb = max(1, B // microbatch_rows)
        tcfg = TrainConfig(n_clients=C, remat=remat, microbatch=nmb,
                           memory_optimized_backward=memory_optimized)
        meta["microbatch"] = nmb
        fn = symbiosis.make_multi_client_train_step(
            cfg, acfg, tcfg, moe_dispatch=moe_dispatch,
            capacity_factor=capacity_factor)
        batch_struct = {
            "tokens": jax.ShapeDtypeStruct((C, B, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((C, B, shape.seq_len), jnp.int32),
        }
        batch_struct.update(_frontend_struct(cfg, C, B))
        batch = shardings.attach(mesh, batch_struct, cs(batch_struct))
        args = (base, bank, opt, batch, _scalar(mesh))
        return SpecBundle(arch_id, shape_name, fn, args, C, B, meta)

    # VLM prefill writes image-prefix + text positions into the cache.
    max_seq = shape.seq_len + (cfg.n_frontend_tokens if cfg.arch == VLM else 0)
    scfg = ServeConfig(n_clients=C, max_seq=max_seq)
    ring = (shape_name == "long_500k" and cfg.arch not in (RWKV, HYBRID)
            and cfg.sliding_window > 0)
    window = cfg.sliding_window if ring else 0
    quant = kv_quant and cfg.arch not in (RWKV, HYBRID) and shape.kind == "decode"
    cache_s = jax.eval_shape(
        lambda: symbiosis.init_client_caches(cfg, C, B, max_seq,
                                             window=window, quant=quant))
    caches = shardings.attach(mesh, cache_s, cs(cache_s))
    meta["ring"] = ring
    meta["kv_quant"] = quant

    if shape.kind == "prefill":
        fn = symbiosis.make_multi_client_prefill(
            cfg, acfg, scfg, memory_optimized=memory_optimized)
        batch_struct = {
            "tokens": jax.ShapeDtypeStruct((C, B, shape.seq_len), jnp.int32)}
        batch_struct.update(_frontend_struct(cfg, C, B))
        batch = shardings.attach(mesh, batch_struct, cs(batch_struct))
        args = (base, bank, caches, batch)
        return SpecBundle(arch_id, shape_name, fn, args, C, B, meta)

    # decode kinds
    fn = symbiosis.make_multi_client_decode_step(
        cfg, acfg, scfg, ring=ring, memory_optimized=memory_optimized)
    tok_struct = {"tokens": jax.ShapeDtypeStruct((C, B), jnp.int32)}
    tokens = shardings.attach(mesh, tok_struct, cs(tok_struct))["tokens"]
    args = (base, bank, caches, tokens)
    return SpecBundle(arch_id, shape_name, fn, args, C, B, meta)


def service_specs(arch_id: str, mesh, *, n_jobs: int = 20,
                  capacity: int = 32, batch: int = 1, seq_len: int = 4096,
                  acfg: AdapterConfig = DEFAULT_ADAPTER,
                  memory_optimized: bool = True, remat: bool = True,
                  microbatch: int = 0,
                  replicate_base: bool = False) -> SpecBundle:
    """The paper's headline service case: ``n_jobs`` fine-tuning adapters
    time-sharing ONE frozen base (Table 3's 20 × Gemma2-27B demo) — the
    FinetuneEngine's compact train step at bank scale, as sharded
    ShapeDtypeStruct stand-ins for the dry-run collective audit.

    The compacted row count is the engine's row bucket
    (``min(next_pow2(n_jobs), capacity)``), so the audited program is
    byte-for-byte the executable the service would compile."""
    cfg = get_config(arch_id)
    R = 1
    while R < n_jobs:
        R *= 2
    R = min(R, capacity)                    # FinetuneEngine._row_bucket

    sys_shape = jax.eval_shape(
        lambda: symbiosis.init_system(cfg, acfg, capacity,
                                      jax.random.PRNGKey(0)))
    base_s, bank_s, opt_s = sys_shape
    if replicate_base:
        base_spec = jax.tree.map(lambda s: P(), base_s)
        cs = lambda t: shardings.client_state_specs(cfg, mesh, t,
                                                    full_mesh=True)
    else:
        base_spec = shardings.base_param_specs(cfg, mesh, base_s)
        cs = lambda t: shardings.client_state_specs(cfg, mesh, t)
    base = shardings.attach(mesh, base_s, base_spec)
    bank = shardings.attach(mesh, bank_s, cs(bank_s))
    opt = shardings.attach(mesh, opt_s, cs(opt_s))

    batch_struct = {
        "tokens": jax.ShapeDtypeStruct((R, batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((R, batch, seq_len), jnp.int32),
    }
    batch_t = shardings.attach(mesh, batch_struct, cs(batch_struct))
    row = lambda dt: jax.ShapeDtypeStruct((R,), dt)
    ctrl_s = {"slots": row(jnp.int32), "mask": row(jnp.bool_)}
    hyper_s = {"step": row(jnp.int32)}
    hyper_s.update({k: row(jnp.float32)
                    for k in ("lr", "warmup", "total", "wd", "gnorm")})
    ctrl = shardings.attach(mesh, ctrl_s, cs(ctrl_s))
    hyper = shardings.attach(mesh, hyper_s, cs(hyper_s))

    fn = symbiosis.make_compact_train_step(
        cfg, acfg, microbatch=microbatch, remat=remat,
        memory_optimized=memory_optimized)
    args = (base, bank, opt, batch_t, ctrl["slots"], ctrl["mask"], hyper)
    meta = {"n_jobs": n_jobs, "capacity": capacity, "row_bucket": R,
            "batch_per_job": batch, "seq_len": seq_len,
            "replicate_base": replicate_base, "kind": "service"}
    return SpecBundle(arch_id, f"service{n_jobs}", fn, args, n_jobs, batch,
                      meta)
