"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(n):
    return f"{n/1e9:.2f}GB" if n >= 1e8 else f"{n/1e6:.1f}MB"


def roofline_table(recs, mesh="pod16x16", tag=""):
    rows = []
    hdr = ("| arch | shape | C×B | compute s | memory s | collective s | "
           "bound | HBM/dev args+temp | MODEL/HLO flops |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for r in recs:
        if not r.get("ok") or r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['n_clients']}×{r['batch_per_client']} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | **{rl['dominant']}** "
            f"| {hbm/1e9:.1f}GB | {ratio:.2f} |")
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | lower s | compile s | devices | "
            "collective bytes/dev | per-dev args |",
            "|" + "---|" * 8]
    for r in recs:
        if not r.get("ok"):
            continue
        mem = r.get("memory", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['lower_s']} "
            f"| {r['compile_s']} | {r['n_devices']} "
            f"| {fmt_bytes(r['walker']['coll_bytes'])} "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes', 0))} |")
    return "\n".join(rows)


def summarize(recs):
    ok = [r for r in recs if r.get("ok")]
    by_mesh = {}
    for r in ok:
        by_mesh.setdefault(r["mesh"], []).append(r)
    lines = [f"total runs: {len(ok)}"]
    for m, rs in sorted(by_mesh.items()):
        doms = {}
        for r in rs:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
        lines.append(f"  {m}: {len(rs)} ok; dominant terms: {doms}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--table", choices=("roofline", "dryrun", "summary"),
                    default="summary")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.table == "roofline":
        print(roofline_table(recs, mesh=args.mesh))
    elif args.table == "dryrun":
        print(dryrun_table(recs))
    else:
        print(summarize(recs))


if __name__ == "__main__":
    main()
