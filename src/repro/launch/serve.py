"""Multi-tenant serving driver (deliverable b).

Serves a bank of adapter clients against one shared base with the
ServingEngine (opportunistic batching). Reduced configs run real tokens on
CPU; full configs target the production mesh (proven by dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --clients 4 --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import AdapterConfig, ServeConfig
from repro.configs import ARCHS, get_config
from repro.core import symbiosis
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.serving.engine import ServingEngine, Request


def _mesh_from(dims):
    if dims is None:
        return None
    from repro.launch.mesh import _make_mesh
    return _make_mesh(tuple(dims), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="granite-3-8b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--policy", default="opportunistic",
                    choices=("lockstep", "nolockstep", "opportunistic"))
    ap.add_argument("--stagger", type=int, default=0,
                    help="ticks between request arrivals (mid-stream joins)")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--privacy", action="store_true")
    ap.add_argument("--page-block", type=int, default=0,
                    help="page the KV cache in blocks of this many tokens "
                         "(0 = dense max_seq-deep slot rows)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="pages per client pool (0 = full provisioning)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache entries + per-head f32 scales")
    ap.add_argument("--mesh", nargs=2, type=int, default=None,
                    metavar=("DATA", "MODEL"),
                    help="place the engine on a (data, model) device mesh "
                         "(replicated base, client axes partitioned)")
    ap.add_argument("--obs", default=None, metavar="DIR",
                    help="attach telemetry (docs/observability.md) and write "
                         "telemetry.jsonl + metrics.prom into DIR at exit")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    acfg = AdapterConfig(method="lora", rank=8, targets=("q", "v"))
    scfg = ServeConfig(n_clients=args.clients, policy=args.policy,
                       max_seq=args.prompt_len + args.max_new + 8,
                       page_block=args.page_block, pool_pages=args.pool_pages,
                       kv_quant=args.kv_quant)

    key = jax.random.PRNGKey(scfg.seed)
    base, bank, _ = symbiosis.init_system(cfg, acfg, args.clients, key)
    spec = EngineSpec(cfg=cfg,
                      banks=(BankSpec("tenants", acfg,
                                      capacity=args.clients),),
                      serve=scfg, mesh=_mesh_from(args.mesh),
                      replicate_base=args.mesh is not None,
                      max_batch_per_client=args.batch)
    obs = None
    if args.obs is not None:
        from repro.obs import Obs
        obs = Obs()
    eng = ServingEngine(spec, base, [bank], obs=obs)

    rng = np.random.default_rng(0)
    reqs = [Request(client_id=i % args.clients,
                    prompt=rng.integers(0, cfg.vocab,
                                        (args.batch, args.prompt_len)).astype(np.int32),
                    max_new_tokens=args.max_new,
                    arrive_tick=i * args.stagger)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    # report from engine state, not the raw args: serve_cache_kwargs drops
    # knobs a family can't honor (no KV to page on rwkv, no pure-KV cache
    # to quantize on hybrid/encdec)
    layout = (f"paged(block={scfg.page_block}, pool={eng._pool_pages})"
              if eng._paged else "dense")
    if eng._quant:
        layout += "+int8"
    print(f"[serve] {cfg.name} | {args.clients} clients | {args.requests} requests "
          f"| policy={args.policy} | kv={layout}")
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(r.generated.size for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:,.0f} tok/s) | engine stats: {eng.stats}")
    sim = eng.simulate_policy(done)
    print(f"[serve] policy timeline ({args.policy}): {sim.summary()}")
    if obs is not None:
        import os
        from repro.obs import export
        os.makedirs(args.obs, exist_ok=True)
        jl = os.path.join(args.obs, "telemetry.jsonl")
        pm = os.path.join(args.obs, "metrics.prom")
        export.write_jsonl(jl, obs)
        export.write_prometheus(pm, obs)
        print(f"[serve] telemetry written to {jl} and {pm}")
    return done


if __name__ == "__main__":
    main()
