"""HLO-text analysis: collective-traffic accounting + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic —
we recover it by scanning the (post-SPMD-partitioning) HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and summing their operand sizes (per instructions in the brief).
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Dict

from repro.common.hardware import V5E, Chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,1024,512]{2,1,0}   or   f32[] (scalar)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?|pred)\[([0-9,]*)\]")

_warned_dtypes: set = set()


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES and dtype not in _warned_dtypes:
        _warned_dtypes.add(dtype)
        warnings.warn(
            f"hlo_analysis: unknown HLO element type {dtype!r}; assuming "
            "4 bytes/element — add it to _DTYPE_BYTES for exact accounting",
            stacklevel=3)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _collective_result_bytes(result_str: str, *, async_start: bool) -> int:
    """Traffic bytes of one collective's result string.

    Sync collectives (and variadic tuple results) sum every tuple element.
    An async ``-start`` returns a tuple carrying BOTH the operand alias and
    the destination buffer (plus context scalars on some backends); summing
    it would double-count the pair, so only the largest element — the
    destination a device receives — is charged, and the matching ``-done``
    (a read of that same buffer) is charged nothing by the callers.
    """
    sizes = [_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_str)]
    if not sizes:
        return 0
    return max(sizes) if async_start and len(sizes) > 1 else sum(sizes)


_OP_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*(?:->.*)?\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """computation name -> list of instruction lines."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and ("{" in line):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps


def _trip_count(cond_lines: list) -> int:
    """Heuristic trip count of a while condition: the largest integer
    constant compared against (lax.scan conditions are `i < constant(T)`)."""
    best = 1
    for line in cond_lines:
        if "compare(" in line or "constant(" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind traffic bytes, *loop-aware*.

    Post-SPMD CPU HLO prints operands by name only, so each collective is
    accounted by its result shape(s) (all-reduce: result == operand;
    all-gather: the full gathered tensor a device receives). Collectives
    inside while bodies (lax.scan over layers/chunks) are multiplied by the
    loop trip count, recursively — a flat text scan would undercount a
    40-layer scan by 40x. ``-done`` halves of async pairs are skipped.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = next(iter(comps))

    out = {k: 0 for k in _COLLECTIVES}
    out["n_ops"] = 0

    seen = set()

    def walk(comp: str, mult: int):
        if comp not in comps or (comp, mult) in seen:
            return
        seen.add((comp, mult))
        for line in comps[comp]:
            m = _OP_RE.search(line)
            if m and m.group(3) != "-done":
                kind = m.group(2)
                total = _collective_result_bytes(
                    m.group(1), async_start=m.group(3) == "-start")
                out[kind] += total * mult
                out["n_ops"] += mult
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * trips)
            elif "fusion(" in line or "call(" in line or "custom-call(" in line:
                for callee in _CALL_RE.findall(line):
                    walk(callee, mult)

    if entry:
        walk(entry, 1)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class CollectiveOp:
    """One collective instruction found in a module, loop-aware.

    ``shapes`` lists every (dtype, dims) element of the result (async
    ``-start`` tuples carry both the operand alias and the destination);
    ``bytes`` is the de-duplicated traffic charge of the op."""
    kind: str
    bytes: int
    shapes: list
    mult: int
    computation: str
    line: str


def find_collectives(hlo_text: str) -> list:
    """Structured listing of every collective (``-done`` halves skipped),
    with while-loop multipliers — the walk ``collective_bytes`` totals."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = next(iter(comps))

    found: list = []
    seen = set()

    def walk(comp: str, mult: int):
        if comp not in comps or (comp, mult) in seen:
            return
        seen.add((comp, mult))
        for line in comps[comp]:
            m = _OP_RE.search(line)
            if m and m.group(3) != "-done":
                shapes = [(d, tuple(int(x) for x in dims.split(",") if x))
                          for d, dims in _SHAPE_RE.findall(m.group(1))]
                found.append(CollectiveOp(
                    kind=m.group(2),
                    bytes=_collective_result_bytes(
                        m.group(1), async_start=m.group(3) == "-start"),
                    shapes=shapes, mult=mult, computation=comp,
                    line=line.strip()))
            wm = _WHILE_RE.search(line)
            if wm:
                trips = _trip_count(comps.get(wm.group(1), []))
                walk(wm.group(2), mult * trips)
            elif "fusion(" in line or "call(" in line or "custom-call(" in line:
                for callee in _CALL_RE.findall(line):
                    walk(callee, mult)

    if entry:
        walk(entry, 1)
    return found


# ---------------------------------------------------------------------------
# Loop-aware full analysis (flops / HBM bytes / collectives)
#
# XLA-CPU's HloCostAnalysis counts while bodies ONCE (verified empirically:
# flops are independent of lax.scan length), which under-counts scan-over-
# layers programs by the trip count. We therefore walk the partitioned HLO
# ourselves, multiplying by while trip counts:
#   * flops: dot ops (2 * numel(result) * prod(contracting dims)) — matmuls
#     dominate every workload here.
#   * hbm bytes: per top-level instruction, result + operand bytes at fusion
#     granularity (fusion internals live in registers/cache, like XLA's own
#     bytes-accessed model).
#   * collectives: result-shape bytes per kind.
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota"}
# Ops that touch only O(result) bytes of their (possibly huge) operands —
# counting the full operand would charge a 500MB buffer to every 2MB slice.
_SLICE_OPS = {"dynamic-slice", "slice", "gather", "dynamic-update-slice",
              "scatter", "pad", "reshape", "broadcast", "transpose", "copy",
              "convert", "reduce"}


def _parse_dims(dims: str):
    return [int(d) for d in dims.split(",")] if dims else []


def _result_bytes(result_str: str) -> int:
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_str))


def analyze_module(hlo_text: str) -> Dict[str, float]:
    """Loop-aware {flops, hbm_bytes, coll_*} for one partitioned module."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = next(iter(comps))

    # Symbol tables: comp -> {instr name -> result string}
    symtab: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        tab = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        symtab[cname] = tab

    out = {"flops": 0.0, "hbm_bytes": 0.0, "n_dots": 0}
    for k in _COLLECTIVES:
        out[k] = 0
    out["coll_ops"] = 0

    def dot_flops(cname, line, result_str):
        mo = _INSTR_RE.match(line)
        ops = line[mo.end():]
        names = _OPERAND_RE.findall(ops[:ops.find(")")])
        lhs_shape = None
        if names:
            lhs_str = symtab[cname].get(names[0], "")
            shapes = _SHAPE_RE.findall(lhs_str)
            if shapes:
                lhs_shape = _parse_dims(shapes[0][1])
        cm = _LHS_C_RE.search(line)
        cdims = _parse_dims(cm.group(1)) if cm else []
        contracted = 1
        for d in cdims:
            if lhs_shape and d < len(lhs_shape):
                contracted *= lhs_shape[d]
        numel = 1
        shapes = _SHAPE_RE.findall(result_str)
        if shapes:
            for d in _parse_dims(shapes[0][1]):
                numel *= d
        return 2.0 * numel * contracted

    def walk(cname: str, mult: float, *, bytes_level: bool):
        for line in comps.get(cname, []):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, result_str, opcode = m.groups()
            base_op = opcode.replace("-start", "").replace("-done", "")
            if base_op in _COLLECTIVES:
                if not opcode.endswith("-done"):
                    out[base_op] += _collective_result_bytes(
                        result_str,
                        async_start=opcode.endswith("-start")) * mult
                    out["coll_ops"] += mult
                continue
            if opcode == "dot":
                out["flops"] += dot_flops(cname, line, result_str) * mult
                out["n_dots"] += mult
            if opcode == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    trips = _trip_count(comps.get(wm.group(1), []))
                    walk(wm.group(2), mult * trips, bytes_level=bytes_level)
                continue
            if opcode in ("fusion", "call", "conditional", "custom-call",
                          "async-start"):
                for callee in _CALL_RE.findall(line):
                    # fusions: walk for dots/collectives only (their internal
                    # traffic is on-chip); calls: walk fully.
                    walk(callee, mult,
                         bytes_level=(bytes_level and opcode != "fusion"))
                if opcode != "fusion":
                    continue   # call results counted inside the callee
            if bytes_level and opcode not in _SKIP_BYTES_OPS:
                b = _result_bytes(result_str)
                if opcode in ("dynamic-update-slice", "scatter"):
                    # in-place update: touched bytes ~ 2 x the (small) update
                    ops_str = line[m.end():]
                    names = _OPERAND_RE.findall(ops_str[:max(ops_str.find(")"), 0)])
                    op_bytes = [_result_bytes(symtab[cname].get(nm, ""))
                                for nm in names[1:]]
                    op_bytes = [x for x in op_bytes if x > 0]
                    b = 2 * min(op_bytes) if op_bytes else b
                elif opcode == "fusion":
                    # loop-carried in-place fusions (cache writes): an operand
                    # with the result's exact shape aliases it — charge the
                    # update slice (smallest operand), not the full buffer.
                    ops_str = line[m.end():]
                    names = _OPERAND_RE.findall(ops_str[:max(ops_str.find(")"), 0)])
                    shapes = [symtab[cname].get(nm, "") for nm in names]
                    op_bytes = [_result_bytes(s) for s in shapes if s]
                    if any(s.split("{")[0] == result_str.split("{")[0]
                           for s in shapes if s):
                        small = [x for x in op_bytes
                                 if 0 < x < _result_bytes(result_str)]
                        b = 2 * max(small) if small else b
                    else:
                        # fused dynamic-slices read O(result) of big operands:
                        # cap each operand's charge at 4x the result size.
                        cap = 4 * _result_bytes(result_str)
                        b += sum(min(x, cap) for x in op_bytes)
                elif opcode in _SLICE_OPS:
                    b *= 2          # read slice + write result
                else:
                    ops_str = line[m.end():]
                    names = _OPERAND_RE.findall(ops_str[:max(ops_str.find(")"), 0)])
                    for nm in names:
                        src = symtab[cname].get(nm)
                        if src:
                            b += _result_bytes(src)
                out["hbm_bytes"] += b * mult

    if entry:
        walk(entry, 1.0, bytes_level=True)
    out["coll_bytes"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one compiled (arch × shape × mesh) program.

    cost_analysis() describes the per-device partitioned module, so each
    term is seconds-per-chip — identical to the brief's
    HLO_total / (chips × peak) since HLO_total = chips × HLO_per_device.
    """
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    chip: Chip = V5E

    @property
    def compute_s(self) -> float:
        return self.flops / self.chip.peak_flops_bf16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.chip.hbm_bandwidth

    @property
    def collective_s(self) -> float:
        # v5e 2D torus: traffic spreads over the chip's usable ICI links.
        return self.coll_bytes / (self.chip.ici_link_bandwidth * self.chip.ici_links)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def model_flops(cfg, n_tokens: int, *, train: bool = False) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per the brief.

    N counts *active* parameters (MoE: shared + top_k routed experts only);
    forward-only workloads use 2·N·D."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.hd
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
        + (cfg.n_heads * hd) * d
    if cfg.arch == "rwkv":
        layer = 5 * d * d + 2 * d * cfg.d_ff + d * d        # time+channel mix
    else:
        fe = cfg.ffn_hidden
        if cfg.n_experts:
            routed = cfg.top_k * 3 * d * fe
            shared = cfg.n_shared_experts * 3 * d * fe
            dense_res = 3 * d * cfg.d_ff if cfg.dense_residual else 0
            ffn = routed + shared + dense_res
        else:
            ffn = 3 * d * cfg.d_ff
        if cfg.arch == "hybrid":
            ed = cfg.mamba_expand * d
            frac_attn = 1.0 / cfg.attn_every
            mamba = 2 * d * ed + ed * d  # in/out projections
            layer = frac_attn * attn + (1 - frac_attn) * mamba + ffn
        else:
            layer = attn + ffn
    n_active = L * layer + 2 * d * cfg.vocab
    if cfg.arch == "encdec":
        n_active += cfg.n_enc_layers * (attn + 3 * d * cfg.d_ff)
    mult = 6.0 if train else 2.0
    return mult * n_active * n_tokens
