"""Production meshes (DESIGN.md §5).

Single pod: (data=16, model=16) = 256 v5e chips. Multi-pod adds a leading
DCN-connected ``pod`` axis: (pod=2, data=16, model=16) = 512 chips.
Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions: ``axis_types`` (and the
    ``AxisType`` enum itself) only exist in newer releases; older ones
    default every axis to Auto, which is exactly what we want."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """1-chip mesh with the production axis names (CPU smoke tests)."""
    return _make_mesh((1, 1), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` across jax versions: releases without it fall
    back to the ``Mesh`` object's own context manager (the legacy ambient
    mesh), which is what ``with_sharding_constraint`` binds to there."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def batch_axes(mesh) -> tuple:
    """The axes a leading batch/client dimension shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_size(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
