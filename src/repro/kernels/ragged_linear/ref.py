"""Pure-jnp oracle for the token-packed frozen base linear."""
from __future__ import annotations

import jax.numpy as jnp


def ragged_linear_ref(buf, w, b, n_live):
    """y = buf @ w (+ b) with rows >= n_live zeroed.

    buf [budget, din]; w [din, dout]; b [dout] or None; n_live scalar int32.
    The zeroing reproduces the packed-buffer contract: dead slots hold
    garbage and must not leak into unpacked outputs.
    """
    y = jnp.einsum("ti,io->to", buf.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    live = (jnp.arange(buf.shape[0]) < n_live)[:, None]
    return jnp.where(live, y, 0.0).astype(buf.dtype)
