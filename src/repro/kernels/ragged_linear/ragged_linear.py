"""Token-packed frozen base linear — Pallas TPU kernel.

The in-graph form of the paper's §3.7 "flatten batch×seq into a 1-D token
stream, no padding" base-executor execution: the packed buffer has a static
token *budget* but only ``n_live`` slots are real. The kernel tiles
[budget, din] @ [din, dout] for the MXU and uses the scalar-prefetched live
count to SKIP whole token blocks past the live watermark (``pl.when``) — the
TPU analogue of not spending FLOPs on padding.

Grid (nt, nd, nk): token tiles × dout tiles × din tiles, din innermost for
fp32 accumulation in a VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rl_kernel(n_live,                 # scalar-prefetch [1] int32
               x_ref,                  # [bt, bk]
               w_ref,                  # [bk, bd]
               b_ref,                  # [1, bd] (zeros when no bias)
               y_ref,                  # [bt, bd]
               acc_ref,                # scratch [bt, bd] f32
               *, block_t: int, n_k: int):
    i = pl.program_id(0)
    k = pl.program_id(2)
    live = i * block_t < n_live[0]     # any live token in this tile?

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                                w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        out = acc_ref[...] + b_ref[0].astype(jnp.float32)
        # mask the intra-tile tail so dead slots emit exact zeros
        t0 = i * block_t
        row = t0 + jax.lax.broadcasted_iota(jnp.int32, out.shape, 0)
        out = jnp.where(row < n_live[0], out, 0.0)
        y_ref[...] = out.astype(y_ref.dtype)


def ragged_linear_pallas(buf, w, b, n_live, *, block_t: int = 256,
                         block_d: int = 512, block_k: int = 512,
                         interpret: bool = False):
    """buf [budget, din] @ w [din, dout] + b, rows >= n_live zeroed.
    budget % block_t == 0, dout % block_d == 0, din % block_k == 0."""
    budget, din = buf.shape
    dout = w.shape[-1]
    nt, nd, nk = budget // block_t, dout // block_d, din // block_k
    if b is None:
        b = jnp.zeros((dout,), buf.dtype)
    n_live = jnp.asarray(n_live, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, nd, nk),
        in_specs=[
            pl.BlockSpec((block_t, block_k), lambda i, j, k, nl: (i, k)),
            pl.BlockSpec((block_k, block_d), lambda i, j, k, nl: (k, j)),
            pl.BlockSpec((1, block_d), lambda i, j, k, nl: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_d), lambda i, j, k, nl: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_t, block_d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_rl_kernel, block_t=block_t, n_k=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((budget, dout), buf.dtype),
        interpret=interpret,
    )(n_live, buf, w, b.reshape(1, dout))
