"""Jit'd public wrapper for the ragged (token-packed) base linear."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ragged_linear.ref import ragged_linear_ref
from repro.kernels.ragged_linear.ragged_linear import ragged_linear_pallas


def _pad_to(x, axis, multiple):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "block_t", "block_d", "block_k"))
def ragged_linear(buf, w, b=None, n_live=None, *, use_kernel: bool = True,
                  interpret: bool = True, block_t: int = 256,
                  block_d: int = 512, block_k: int = 512):
    """Packed-buffer frozen linear: buf [budget, din] @ w [din, dout] (+ b),
    slots >= n_live zeroed. Arbitrary shapes (auto-padded to tiles)."""
    budget, din = buf.shape
    dout = w.shape[-1]
    if n_live is None:
        n_live = budget
    if not use_kernel:
        return ragged_linear_ref(buf, w, b, n_live)

    bt = min(block_t, max(8, budget))
    bd = min(block_d, max(128, dout))
    bk = min(block_k, max(128, din))
    bufp = _pad_to(_pad_to(buf, 0, bt), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bd)
    bp = _pad_to(b, 0, bd) if b is not None else None
    y = ragged_linear_pallas(bufp, wp, bp, n_live, block_t=bt, block_d=bd,
                             block_k=bk, interpret=interpret)
    return y[:budget, :dout]
