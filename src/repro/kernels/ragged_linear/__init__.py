from repro.kernels.ragged_linear.ops import ragged_linear
from repro.kernels.ragged_linear.ref import ragged_linear_ref
