"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §7).

sgmv          — multi-adapter LoRA gather-matmul over packed tokens
ragged_linear — token-packed frozen base linear (no-padding batching, §3.7)
decode_attn   — blocked GQA decode attention (online softmax, KV streaming)
flash_attn    — causal GQA flash attention fwd (prefill/train hot path; the
                VMEM-resident-carry fix for the roofline's memory term)

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper: padding/dispatch/fallback), ref.py (pure-jnp oracle).
"""
from repro.kernels.sgmv import sgmv, sgmv_ref
from repro.kernels.ragged_linear import ragged_linear, ragged_linear_ref
from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.flash_attn import flash_attn, flash_attn_ref
