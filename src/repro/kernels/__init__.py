"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §7).

sgmv          — multi-adapter LoRA gather-matmul over packed tokens
                (block_t=1 degenerates to one-adapter-per-row: the serving
                engine's compacted decode tick)
ragged_linear — token-packed frozen base linear (no-padding batching, §3.7)
decode_attn   — blocked GQA decode attention (online softmax, KV streaming).
                Two layouts: dense [B,T,K,hd] caches, and the TABLE-AWARE
                PAGED layout — K/V live in a page pool shared by many
                sequence slots, each row's block table is scalar-prefetched
                and the kernel's index_map reads pages in place from the
                pool (no dense view is ever gathered; the gather survives
                only as the test oracle). int8 pools with per-head f32
                scales are dequantized while streaming.
flash_attn    — causal GQA flash attention fwd (prefill/train hot path; the
                VMEM-resident-carry fix for the roofline's memory term)

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper: padding/dispatch/fallback), ref.py (pure-jnp oracle).

Dispatch convention: ``interpret=None`` auto-selects by backend — compiled
Pallas on TPU; elsewhere the kernels' *jnp stream twins* run (the same
blocked math as a lax.scan, byte-identical to the kernels — asserted in
tests — and free of the grid interpreter's per-step overhead). The paged
decode-attn and token-write ops carry custom_vmap rules that flatten a
vmapped client axis into extra pool pages/rows, which is what makes the
bank-wide masked decode and the engine's compacted decode the same
computation.
"""
from repro.kernels.sgmv import sgmv, sgmv_ref
from repro.kernels.ragged_linear import ragged_linear, ragged_linear_ref
from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.flash_attn import flash_attn, flash_attn_ref
