from repro.kernels.flash_attn.ops import flash_attn
from repro.kernels.flash_attn.ref import flash_attn_ref
