"""Jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_attn_pallas
from repro.kernels.flash_attn.ref import flash_attn_ref


def _pad_axis(x, axis, multiple):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "causal",
                                             "window", "use_kernel",
                                             "interpret"))
def flash_attn(q, k, v, *, block_q: int = 256, block_kv: int = 512,
               causal: bool = True, window: int = 0,
               use_kernel: bool = True, interpret: bool = True):
    """Causal GQA flash attention. q [B,S,H,hd]; k/v [B,T,K,hd].
    Arbitrary S/T (auto-padded; padded kv masked by causality iff causal —
    for non-causal inputs T must already divide block_kv)."""
    if not use_kernel:
        return flash_attn_ref(q, k, v, causal=causal, window=window)
    B, S, H, hd = q.shape
    T = k.shape[1]
    bq = min(block_q, max(8, S))
    bkv = min(block_kv, max(8, T))
    qp = _pad_axis(q, 1, bq)
    kp = _pad_axis(k, 1, bkv)
    vp = _pad_axis(v, 1, bkv)
    if not causal and kp.shape[1] != T:
        raise ValueError("non-causal flash_attn requires T % block_kv == 0")
    out = flash_attn_pallas(qp, kp, vp, block_q=bq, block_kv=bkv,
                            causal=causal, window=window, interpret=interpret)
    return out[:, :S]
