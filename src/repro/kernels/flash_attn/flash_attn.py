"""Causal GQA flash attention (prefill/train forward) — Pallas TPU kernel.

The §Perf it1 lesson made concrete: a lax.scan online-softmax pays HBM
loop-carry traffic per KV block; a KERNEL keeps the running (max, denom,
accumulator) in VMEM scratch across the sequential KV grid dimension, so the
only HBM traffic is Q/K/V reads + one output write — the roofline's memory
term drops from O(S·T) score bytes to O(S·hd + T·hd).

Grid (B, H, nq, nkv), nkv innermost (sequential per core on TPU). Causality
prunes whole KV blocks: block j is skipped unless its start <= the q-block's
last position (and, with a sliding window, unless it intersects the window).
GQA: the kv head for q-head h is h // G via the BlockSpec index_map — no
KV replication materializes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fa_kernel(q_ref,                  # [1, bq, 1, hd]
               k_ref, v_ref,           # [1, bkv, 1, hd]
               o_ref,                  # [1, bq, 1, hd]
               m_ref, l_ref, acc_ref,  # scratch [bq,128],[bq,128],[bq,hd]
               *, block_q: int, block_kv: int, n_kv: int, window: int,
               causal: bool):
    i = pl.program_id(2)               # q block
    j = pl.program_id(3)               # kv block
    q0 = i * block_q
    t0 = j * block_kv

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block pruning: kv block must start at/before the q block's end;
    # with a window it must also reach past the q block's trailing edge.
    live = True
    if causal:
        live = t0 <= q0 + block_q - 1
        if window:
            live &= (t0 + block_kv) > (q0 - window + 1)

    @pl.when(live if causal else True)
    def _():
        q = q_ref[0, :, 0].astype(jnp.float32)               # [bq, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [bkv, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        if causal:
            qp = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kp = t0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = qp >= kp
            if window:
                mask &= (qp - kp) < window
            s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[:, :1] = l_ref[:, :1] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(j == n_kv - 1)
    def _():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, :, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attn_pallas(q, k, v, *, block_q: int = 256, block_kv: int = 512,
                      causal: bool = True, window: int = 0,
                      interpret: bool = False):
    """q [B,S,H,hd]; k/v [B,T,K,hd], H % K == 0, S % block_q == 0,
    T % block_kv == 0."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    nq, nkv = S // block_q, T // block_kv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fa_kernel, block_q=block_q, block_kv=block_kv,
                          n_kv=nkv, window=window, causal=causal),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
