"""Pure-jnp oracle for causal GQA flash attention (prefill/train forward)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attn_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,S,H,hd]; k/v [B,T,K,hd] (H % K == 0). Self-attention positions
    are the natural ranges (prefill: q position i attends kv <= i).
    Returns [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    kr = jnp.repeat(k, G, axis=2) if G > 1 else k
    vr = jnp.repeat(v, G, axis=2) if G > 1 else v
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(T)[None, :]
        m = qp >= kp
        if window:
            m &= (qp - kp) < window
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
