"""Pure-jnp oracle for blocked GQA decode attention (dense or paged cache)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_view(pool, tbl):
    """Gather a dense per-slot cache view from a page pool — the canonical
    block-table gather. Since the table-aware kernel landed, nothing on the
    serving decode path materializes this view any more; it survives as the
    TEST ORACLE (``decode_attn(via_gather=True)``) and for host-side
    debugging.

    pool [P, block, ...]; tbl [B, n_blocks] int32 page ids.
    Returns [B, n_blocks * block, ...]. Lanes reached through unallocated
    table entries hold unrelated (but finite) data — callers must mask by
    position validity, exactly as with an uninitialized dense cache."""
    P, blk = pool.shape[:2]
    B, n_blocks = tbl.shape
    v = pool[jnp.clip(tbl, 0, P - 1)]
    return v.reshape(B, n_blocks * blk, *pool.shape[2:])


def gather_paged_kv(k, v, block_tbl):
    """Materialize dense per-row K and V views from paged pools (test oracle
    for the table-aware kernel — see ``decode_attn(via_gather=True)``)."""
    return paged_view(k, block_tbl), paged_view(v, block_tbl)


def decode_attn_ref(q, k, v, pos, *, window: int = 0, block_tbl=None,
                    k_scale=None, v_scale=None):
    """Single-token GQA attention against a KV cache (full, un-blocked
    softmax — the numerical oracle, not byte-comparable to the kernels).

    q [B, K, G, hd]; k/v [B, T, K, hd]; pos [B] int32 (last valid index).
    Optional sliding window. With ``block_tbl`` [B, n_blocks], k/v (and the
    optional scales) are instead page pools [P, block, K, hd] and each row's
    cache is addressed through its block-table row (paged KV layout; see
    serving/kvcache.py). ``k_scale``/``v_scale`` [.., K, 1] switch to the
    int8-quantized cache semantics (entries are dequantized per head).
    Returns out [B, K, G, hd].
    """
    if block_tbl is not None:
        k, v = gather_paged_kv(k, v, block_tbl)
        if k_scale is not None:
            k_scale = paged_view(k_scale, block_tbl)
            v_scale = paged_view(v_scale, block_tbl)
    hd = q.shape[-1]
    T = k.shape[1]
    s = jnp.einsum("bkgh,btkh->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if k_scale is not None:
        s = s * k_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
    t = jnp.arange(T)[None, :]
    valid = t <= pos[:, None]
    if window:
        valid &= (pos[:, None] - t) < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
