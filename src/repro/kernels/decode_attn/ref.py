"""Pure-jnp oracle for blocked GQA decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attn_ref(q, k, v, pos, *, window: int = 0):
    """Single-token GQA attention against a KV cache.

    q [B, K, G, hd]; k/v [B, T, K, hd]; pos [B] int32 (last valid index).
    Optional sliding window. Returns out [B, K, G, hd].
    """
    hd = q.shape[-1]
    T = k.shape[1]
    s = jnp.einsum("bkgh,btkh->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    t = jnp.arange(T)[None, :]
    valid = t <= pos[:, None]
    if window:
        valid &= (pos[:, None] - t) < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
