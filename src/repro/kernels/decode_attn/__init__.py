from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.decode_attn.ref import decode_attn_ref
