"""Blocked GQA decode attention — Pallas TPU kernels (online softmax).

The client-side hot op for decode_32k / long_500k: one query token attends
to a seq_len-deep KV cache. The cache never fits VMEM, so it is streamed
HBM→VMEM in ``block_kv`` chunks while a running (max, denominator, weighted
accumulator) triple lives in VMEM scratch — flash-decoding restructured for
the TPU: the KV axis is the *innermost sequential grid dimension* (Pallas
TPU grids iterate sequentially per core, so the scratch carries state), and
the G query heads of one KV group form the MXU's M dimension.

Two layouts:

* **Dense** (``_da_kernel`` / ``decode_attn_pallas``): k/v are contiguous
  [B, T, K, hd] caches; grid (B, K, T/block_kv). The per-batch valid length
  is scalar-prefetched so fully-masked chunks are skipped (long_500k with
  short live prefixes pays only for live cache).
* **Paged / table-aware** (``_paged_kernel`` / ``paged_decode_attn_pallas``):
  k/v are page *pools* [P, page_block, K, hd] shared by many sequence slots;
  each row's block table is scalar-prefetched and the kernel's ``index_map``
  reads ``tbl[b, c]`` to DMA page ``c`` of row ``b`` straight out of the
  pool — the dense view is NEVER gathered (the PR-2 wrapper materialized it
  with ``gather_paged_kv`` before the kernel ran; that gather now survives
  only as the test oracle). Grid (B, n_blocks) with the K and G head axes
  vectorized inside the block, block_kv == page_block so pads never
  materialize. Quantized pools (int8 entries + f32 per-head scales) get the
  same treatment in ``paged_decode_attn_quant_pallas``.

Every paged kernel has a jnp twin (``paged_decode_attn_stream`` /
``paged_decode_attn_quant_stream``): the *same* blocked math — one
``lax.scan`` step per page, each step gathering exactly the pages the table
names — executed without the Pallas grid interpreter. The twins are
byte-identical to the kernels (asserted in tests/test_kernels.py) and are
what non-TPU backends run: interpret mode emulates each grid step with a
dynamic-slice round-trip whose per-step overhead dwarfs the math at decode
shapes, while the stream form vectorizes across rows. On TPU the pallas
kernels run compiled.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


_NEG = -1e30


def _da_kernel(pos,                    # scalar-prefetch [B] int32
               q_ref,                  # [1, 1, G, hd]
               k_ref,                  # [1, bkv, 1, hd]
               v_ref,                  # [1, bkv, 1, hd]
               o_ref,                  # [1, 1, G, hd]
               m_ref, l_ref, acc_ref,  # scratch [G,128],[G,128],[G,hd] f32
               *, block_kv: int, n_kv: int, window: int):
    b = pl.program_id(0)
    c = pl.program_id(2)
    t0 = c * block_kv
    p = pos[b]

    @pl.when(c == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # chunk live iff it intersects [max(0, p-window+1), p]
    lo = (p - window + 1) if window else 0
    live = (t0 <= p) & (t0 + block_kv > lo)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [bkv, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)               # [bkv, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [G, bkv]
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        t = t0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = t <= p
        if window:
            mask &= (p - t) < window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, :1]                                # [G,1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        ps = jnp.exp(s - m_new)                              # [G, bkv]
        l_ref[:, :1] = l_ref[:, :1] * alpha + ps.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            ps, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(c == n_kv - 1)
    def _():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attn_pallas(q, k, v, pos, *, block_kv: int = 512, window: int = 0,
                       interpret: bool = False):
    """q [B, K, G, hd]; k/v [B, T, K, hd]; pos [B]. T % block_kv == 0."""
    B, K, G, hd = q.shape
    T = k.shape[1]
    n_kv = T // block_kv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, c, pos: (b, c, h, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, c, pos: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, c, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_da_kernel, block_kv=block_kv, n_kv=n_kv, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)


# ---------------------------------------------------------------------------
# Table-aware paged kernels
# ---------------------------------------------------------------------------
#
# Shared blocked-update helper: one page's contribution to the running
# online-softmax state. Written once so the pallas kernels and their jnp
# stream twins execute the *same ops in the same order* — the byte-identity
# contract between the two execution paths (and, through it, between the
# masked bank-wide decode and the compacted decode) rests on this sharing.

def _page_update(q, k, v, ks, vs, t0, p, m, l, acc, *, window: int):
    """q [K,G,hd] f32; k/v [blk,K,hd] f32; ks/vs [blk,K] f32 scales or None;
    m/l [K,G,1]; acc [K,G,hd]. Returns updated (m, l, acc)."""
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)   # [K, G, blk]
    if ks is not None:
        s = s * ks.T[:, None, :]                 # per-entry k scale [K,1,blk]
    s = s * (1.0 / math.sqrt(q.shape[-1]))
    t = t0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    mask = t <= p
    if window:
        mask &= (p - t) < window
    s = jnp.where(mask, s, _NEG)
    m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m - m_new)
    ps = jnp.exp(s - m_new)                                       # [K, G, blk]
    # the softmax denominator accumulates the RAW exponentials; the v scales
    # only weight the numerator (p * vs) @ v, matching the dense quant math
    l = l * alpha + ps.sum(-1, keepdims=True)
    if vs is not None:
        ps = ps * vs.T[:, None, :]               # per-entry v scale
    acc = acc * alpha + jax.lax.dot_general(
        ps, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32)
    return m_new, l, acc


def _paged_kernel(tbl, pos,            # scalar-prefetch [B, nb], [B] int32
                  q_ref,               # [1, K, G, hd]
                  k_ref, v_ref,        # [1, blk, K, hd] — page tbl[b, c]
                  o_ref,               # [1, K, G, hd]
                  m_ref, l_ref, acc_ref,
                  *, blk: int, nb: int, window: int):
    b = pl.program_id(0)
    c = pl.program_id(1)
    t0 = c * blk
    p = pos[b]

    @pl.when(c == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = (p - window + 1) if window else 0
    live = (t0 <= p) & (t0 + blk > lo)

    @pl.when(live)
    def _():
        m, l, acc = _page_update(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            v_ref[0].astype(jnp.float32), None, None, t0, p,
            m_ref[..., :1], l_ref[..., :1], acc_ref[...], window=window)
        m_ref[..., :1] = m
        l_ref[..., :1] = l
        acc_ref[...] = acc

    @pl.when(c == nb - 1)
    def _():
        denom = jnp.maximum(l_ref[..., :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _paged_quant_kernel(tbl, pos, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, blk: int, nb: int,
                        window: int):
    b = pl.program_id(0)
    c = pl.program_id(1)
    t0 = c * blk
    p = pos[b]

    @pl.when(c == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = (p - window + 1) if window else 0
    live = (t0 <= p) & (t0 + blk > lo)

    @pl.when(live)
    def _():
        m, l, acc = _page_update(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            v_ref[0].astype(jnp.float32), ks_ref[0, :, :, 0], vs_ref[0, :, :, 0],
            t0, p, m_ref[..., :1], l_ref[..., :1], acc_ref[...], window=window)
        m_ref[..., :1] = m
        l_ref[..., :1] = l
        acc_ref[...] = acc

    @pl.when(c == nb - 1)
    def _():
        denom = jnp.maximum(l_ref[..., :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _paged_grid_spec(B, K, G, hd, blk, nb, quant: bool):
    qspec = pl.BlockSpec((1, K, G, hd), lambda b, c, tbl, pos: (b, 0, 0, 0))
    kv = pl.BlockSpec((1, blk, K, hd), lambda b, c, tbl, pos: (tbl[b, c], 0, 0, 0))
    sc = pl.BlockSpec((1, blk, K, 1), lambda b, c, tbl, pos: (tbl[b, c], 0, 0, 0))
    in_specs = [qspec, kv, sc, kv, sc] if quant else [qspec, kv, kv]
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, K, G, hd), lambda b, c, tbl, pos: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G, 128), jnp.float32),
            pltpu.VMEM((K, G, 128), jnp.float32),
            pltpu.VMEM((K, G, hd), jnp.float32),
        ],
    )


def paged_decode_attn_pallas(q, pool_k, pool_v, tbl, pos, *, window: int = 0,
                             interpret: bool = False):
    """Table-aware paged decode attention.

    q [B, K, G, hd]; pool_k/v [P, blk, K, hd] page pools; tbl [B, nb] int32
    block table (scalar-prefetched, read by the index_map — page c of row b
    is DMA'd straight from the pool); pos [B] last-valid index.
    """
    B, K, G, hd = q.shape
    blk = pool_k.shape[1]
    nb = tbl.shape[1]
    return pl.pallas_call(
        functools.partial(_paged_kernel, blk=blk, nb=nb, window=window),
        grid_spec=_paged_grid_spec(B, K, G, hd, blk, nb, quant=False),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(tbl.astype(jnp.int32), pos.astype(jnp.int32), q, pool_k, pool_v)


def paged_decode_attn_quant_pallas(q, pool_k, pool_ks, pool_v, pool_vs, tbl,
                                   pos, *, window: int = 0,
                                   interpret: bool = False):
    """Quantized-pool variant: pool_k/v int8 [P, blk, K, hd] with f32
    per-head scales pool_ks/vs [P, blk, K, 1]; same table-aware layout."""
    B, K, G, hd = q.shape
    blk = pool_k.shape[1]
    nb = tbl.shape[1]
    return pl.pallas_call(
        functools.partial(_paged_quant_kernel, blk=blk, nb=nb, window=window),
        grid_spec=_paged_grid_spec(B, K, G, hd, blk, nb, quant=True),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(tbl.astype(jnp.int32), pos.astype(jnp.int32), q, pool_k, pool_ks,
      pool_v, pool_vs)


# ---------------------------------------------------------------------------
# jnp stream twins (byte-identical math, no grid interpreter)
# ---------------------------------------------------------------------------

def _stream(q, pool_k, pool_v, pool_ks, pool_vs, tbl, pos, *, window: int):
    """One lax.scan step per page; each step gathers exactly the pages named
    by the table's column c — pages are read in place from the pool, never
    materialized as a dense per-row view. Vectorized over rows; per-row ops
    match the pallas kernels' per-block ops bit for bit."""
    B, K, G, hd = q.shape
    blk = pool_k.shape[1]
    nb = tbl.shape[1]
    qf = q.astype(jnp.float32)
    pos = pos.astype(jnp.int32)
    m0 = jnp.full((B, K, G, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, K, G, 1), jnp.float32)
    a0 = jnp.zeros((B, K, G, hd), jnp.float32)

    def body(carry, c):
        m, l, acc = carry
        t0 = c * blk
        page = tbl[:, c]
        k = pool_k[page].astype(jnp.float32)              # [B, blk, K, hd]
        v = pool_v[page].astype(jnp.float32)
        ks = pool_ks[page][..., 0] if pool_ks is not None else None
        vs = pool_vs[page][..., 0] if pool_vs is not None else None

        def upd(q1, k1, v1, ks1, vs1, p1, m1, l1, a1):
            return _page_update(q1, k1, v1, ks1, vs1, t0, p1, m1, l1, a1,
                                window=window)

        in_axes = (0, 0, 0, None if ks is None else 0,
                   None if vs is None else 0, 0, 0, 0, 0)
        m_new, l_new, acc_new = jax.vmap(upd, in_axes=in_axes)(
            qf, k, v, ks, vs, pos, m, l, acc)
        lo = (pos - window + 1) if window else jnp.zeros_like(pos)
        live = ((t0 <= pos) & (t0 + blk > lo))[:, None, None, None]
        m = jnp.where(live, m_new, m)
        l = jnp.where(live, l_new, l)
        acc = jnp.where(live, acc_new, acc)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(nb, dtype=jnp.int32))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def paged_decode_attn_stream(q, pool_k, pool_v, tbl, pos, *, window: int = 0):
    """jnp twin of ``paged_decode_attn_pallas`` (byte-identical)."""
    return _stream(q, pool_k, pool_v, None, None, tbl, pos, window=window)


def paged_decode_attn_quant_stream(q, pool_k, pool_ks, pool_v, pool_vs, tbl,
                                   pos, *, window: int = 0):
    """jnp twin of ``paged_decode_attn_quant_pallas`` (byte-identical)."""
    return _stream(q, pool_k, pool_v, pool_ks, pool_vs, tbl, pos,
                   window=window)
