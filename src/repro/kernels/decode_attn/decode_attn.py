"""Blocked GQA decode attention — Pallas TPU kernel (online softmax).

The client-side hot op for decode_32k / long_500k: one query token attends
to a seq_len-deep KV cache. The cache never fits VMEM, so it is streamed
HBM→VMEM in ``block_kv`` chunks while a running (max, denominator, weighted
accumulator) triple lives in VMEM scratch — flash-decoding restructured for
the TPU: the KV axis is the *innermost sequential grid dimension* (Pallas
TPU grids iterate sequentially per core, so the scratch carries state), and
the G query heads of one KV group form the MXU's M dimension.

Grid (B, K, T/block_kv); the per-batch valid length is scalar-prefetched so
fully-masked chunks are skipped (long_500k with short live prefixes pays
only for live cache).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


_NEG = -1e30


def _da_kernel(pos,                    # scalar-prefetch [B] int32
               q_ref,                  # [1, 1, G, hd]
               k_ref,                  # [1, bkv, 1, hd]
               v_ref,                  # [1, bkv, 1, hd]
               o_ref,                  # [1, 1, G, hd]
               m_ref, l_ref, acc_ref,  # scratch [G,128],[G,128],[G,hd] f32
               *, block_kv: int, n_kv: int, window: int):
    b = pl.program_id(0)
    c = pl.program_id(2)
    t0 = c * block_kv
    p = pos[b]

    @pl.when(c == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # chunk live iff it intersects [max(0, p-window+1), p]
    lo = (p - window + 1) if window else 0
    live = (t0 <= p) & (t0 + block_kv > lo)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [bkv, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)               # [bkv, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [G, bkv]
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        t = t0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = t <= p
        if window:
            mask &= (p - t) < window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, :1]                                # [G,1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        ps = jnp.exp(s - m_new)                              # [G, bkv]
        l_ref[:, :1] = l_ref[:, :1] * alpha + ps.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            ps, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(c == n_kv - 1)
    def _():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attn_pallas(q, k, v, pos, *, block_kv: int = 512, window: int = 0,
                       interpret: bool = False):
    """q [B, K, G, hd]; k/v [B, T, K, hd]; pos [B]. T % block_kv == 0."""
    B, K, G, hd = q.shape
    T = k.shape[1]
    n_kv = T // block_kv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, c, pos: (b, c, h, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, h, c, pos: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, c, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_da_kernel, block_kv=block_kv, n_kv=n_kv, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)
