"""Jit'd public wrapper for blocked GQA decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.decode_attn import decode_attn_pallas
from repro.kernels.decode_attn.ref import decode_attn_ref, gather_paged_kv


@functools.partial(jax.jit, static_argnames=("block_kv", "window",
                                             "use_kernel", "interpret"))
def decode_attn(q, k, v, pos, *, block_kv: int = 512, window: int = 0,
                use_kernel: bool = True, interpret: bool = True,
                block_tbl=None):
    """Single-token GQA decode attention. q [B,K,G,hd]; k/v [B,T,K,hd];
    pos [B] int32 last-valid index. Optional sliding window.

    ``block_tbl`` [B, n_blocks] switches to the paged layout: k/v are page
    pools [P, page_block, K, hd] and each row's cache view is gathered
    through its table row before the blocked kernel runs (the gather is the
    reference strategy; a table-aware index_map inside the kernel is the
    on-TPU follow-up)."""
    if block_tbl is not None:
        k, v = gather_paged_kv(k, v, block_tbl)
    if not use_kernel:
        return decode_attn_ref(q, k, v, pos, window=window)
    T = k.shape[1]
    bkv = min(block_kv, T)
    pad = (-T) % bkv
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k, v = zeros(k), zeros(v)
    return decode_attn_pallas(q, k, v, pos, block_kv=bkv, window=window,
                              interpret=interpret)
