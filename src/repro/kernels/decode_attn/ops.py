"""Jit'd public wrapper for blocked GQA decode attention.

Dispatch rules (shared by every kernel wrapper in ``repro.kernels``):

* ``interpret=None`` auto-selects by backend: compiled Pallas on TPU,
  emulation elsewhere. The CPU/GPU emulation of the *paged* path is the
  kernels' jnp stream twin (byte-identical math, see decode_attn.py) rather
  than the Pallas grid interpreter — the interpreter's per-grid-step
  dynamic-slice round-trips dwarf the math at decode shapes, while the twin
  vectorizes across rows and reads only the pages the tables name.
* The paged path (``block_tbl`` given) takes ``block_kv`` from the page
  size, so K/V are never re-padded to a block multiple — pads never
  materialize. The dense path picks the largest divisor of T near the
  requested ``block_kv`` before it falls back to zero-padding.
* ``via_gather=True`` is the TEST ORACLE: it materializes the dense per-row
  view with ``gather_paged_kv`` and runs the same blocked math on it with an
  identity block table — byte-identical to the table-aware read by
  construction, and the only ``gather_paged_kv`` caller left on any decode
  path.

The masked bank-wide decode step vmaps this op over clients; a custom_vmap
rule flattens that client axis away instead of batching the kernel: client
pools concatenate into one bigger pool ([C, P, ...] -> [C*P, ...]) with the
tables offset by ``c * P`` — "a bank of clients" and "one client with more
pages" are the same computation, so the masked decode and the engine's
compacted decode (which performs exactly this flattening to gather active
rows across clients) are byte-identical by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.kernels.decode_attn.decode_attn import (
    decode_attn_pallas,
    paged_decode_attn_pallas,
    paged_decode_attn_quant_pallas,
    paged_decode_attn_stream,
    paged_decode_attn_quant_stream,
)
from repro.kernels.decode_attn.ref import decode_attn_ref, gather_paged_kv, paged_view


def backend_interpret() -> bool:
    """True iff Pallas kernels should be emulated on this backend."""
    return jax.default_backend() != "tpu"


def _flatten_client_axis(axis_size, pool_batched, q, tbl, pos, *pools):
    """custom_vmap helper: fold a leading client axis into rows + pages.

    q [C, B, ...] -> [C*B, ...]; pos likewise. Batched pools [C, P, ...]
    concatenate to [C*P, ...] with tables offset by c*P; an unbatched pool is
    already shared across the axis, so tables pass through untouched."""
    C, B = axis_size, q.shape[1]
    q = q.reshape((C * B,) + q.shape[2:])
    pos = pos.reshape(C * B)
    if pool_batched:
        P = pools[0].shape[1]
        pools = tuple(p.reshape((C * P,) + p.shape[2:]) for p in pools)
        tbl = tbl + (jnp.arange(C, dtype=tbl.dtype) * P)[:, None, None]
    tbl = tbl.reshape(C * B, tbl.shape[-1])
    return q, tbl, pos, pools


@functools.lru_cache(maxsize=None)
def _paged_op(window: int, interpret: bool):
    """custom_vmap'd table-aware paged attention for one (window, backend)."""

    @custom_vmap
    def op(q, pool_k, pool_v, tbl, pos):
        if interpret:
            return paged_decode_attn_stream(q, pool_k, pool_v, tbl, pos,
                                            window=window)
        return paged_decode_attn_pallas(q, pool_k, pool_v, tbl, pos,
                                        window=window, interpret=False)

    @op.def_vmap
    def _rule(axis_size, in_batched, q, pool_k, pool_v, tbl, pos):
        qb, pkb, pvb, tb, pb = in_batched
        assert qb and tb and pb and (pkb == pvb), (
            "paged decode attention: q/tbl/pos must batch together and the "
            "two pools alike")
        q, tbl, pos, (pool_k, pool_v) = _flatten_client_axis(
            axis_size, pkb, q, tbl, pos, pool_k, pool_v)
        out = op(q, pool_k, pool_v, tbl, pos)
        return out.reshape((axis_size, -1) + out.shape[1:]), True

    return op


@functools.lru_cache(maxsize=None)
def _paged_quant_op(window: int, interpret: bool):
    @custom_vmap
    def op(q, pool_k, pool_ks, pool_v, pool_vs, tbl, pos):
        if interpret:
            return paged_decode_attn_quant_stream(q, pool_k, pool_ks, pool_v,
                                                  pool_vs, tbl, pos,
                                                  window=window)
        return paged_decode_attn_quant_pallas(q, pool_k, pool_ks, pool_v,
                                              pool_vs, tbl, pos,
                                              window=window, interpret=False)

    @op.def_vmap
    def _rule(axis_size, in_batched, q, pool_k, pool_ks, pool_v, pool_vs,
              tbl, pos):
        qb, pkb, ksb, pvb, vsb, tb, pb = in_batched
        assert qb and tb and pb and pkb == ksb == pvb == vsb, (
            "paged decode attention: q/tbl/pos must batch together and the "
            "four pools alike")
        q, tbl, pos, pools = _flatten_client_axis(
            axis_size, pkb, q, tbl, pos, pool_k, pool_ks, pool_v, pool_vs)
        out = op(q, *pools, tbl, pos)
        return out.reshape((axis_size, -1) + out.shape[1:]), True

    return op


def _identity_tbl(B: int, nb: int):
    """Block table of a gathered dense view: row b's pages are contiguous."""
    return jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)


def _dense_block_kv(T: int, block_kv: int):
    """Largest divisor of T in (block_kv/2, block_kv] — avoids materializing
    zero-pads for mildly non-dividing depths; degenerate depths keep the old
    pad-to-multiple behaviour."""
    bkv = min(block_kv, T)
    if T % bkv == 0:
        return bkv, 0
    for cand in range(bkv, max(bkv // 2, 1), -1):
        if T % cand == 0:
            return cand, 0
    return bkv, (-T) % bkv


@functools.partial(jax.jit, static_argnames=("block_kv", "window",
                                             "use_kernel", "interpret",
                                             "via_gather"))
def decode_attn(q, k, v, pos, *, block_kv: int = 512, window: int = 0,
                use_kernel: bool = True, interpret: bool = None,
                block_tbl=None, k_scale=None, v_scale=None,
                via_gather: bool = False):
    """Single-token GQA decode attention. q [B,K,G,hd]; k/v [B,T,K,hd];
    pos [B] int32 last-valid index. Optional sliding window.

    ``block_tbl`` [B, n_blocks] switches to the PAGED layout: k/v are page
    pools [P, page_block, K, hd] shared across rows, and the kernel reads
    each row's pages in place through its table row (scalar-prefetched into
    the index_map — no dense view is gathered). ``k_scale``/``v_scale``
    [.., K, 1] switch to int8-quantized entries with per-head f32 scales.
    ``interpret=None`` auto-selects by backend (compiled on TPU, the
    byte-identical jnp stream twin elsewhere). ``via_gather=True`` is the
    test oracle: gather first, then run the identical blocked math."""
    if interpret is None:
        interpret = backend_interpret()
    if block_tbl is not None:
        quant = k_scale is not None
        if not use_kernel:
            return decode_attn_ref(q, k, v, pos, window=window,
                                   block_tbl=block_tbl, k_scale=k_scale,
                                   v_scale=v_scale)
        if via_gather:
            # TEST ORACLE: materialize the dense per-row view, then run the
            # same blocked math over it with an identity table. Byte-equal
            # to the in-place table read; never on a serving path.
            B, nb = block_tbl.shape
            blk = k.shape[1]
            k, v = gather_paged_kv(k, v, block_tbl)
            k = k.reshape(B * nb, blk, *k.shape[2:])
            v = v.reshape(B * nb, blk, *v.shape[2:])
            if quant:
                k_scale = paged_view(k_scale, block_tbl).reshape(
                    B * nb, blk, *k_scale.shape[2:])
                v_scale = paged_view(v_scale, block_tbl).reshape(
                    B * nb, blk, *v_scale.shape[2:])
            block_tbl = _identity_tbl(B, nb)
        if quant:
            return _paged_quant_op(window, interpret)(
                q, k, k_scale, v, v_scale, block_tbl, pos.astype(jnp.int32))
        return _paged_op(window, interpret)(q, k, v, block_tbl,
                                            pos.astype(jnp.int32))
    if not use_kernel:
        return decode_attn_ref(q, k, v, pos, window=window)
    T = k.shape[1]
    bkv, pad = _dense_block_kv(T, block_kv)
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k, v = zeros(k), zeros(v)
    return decode_attn_pallas(q, k, v, pos, block_kv=bkv, window=window,
                              interpret=interpret)
