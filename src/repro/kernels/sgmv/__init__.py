from repro.kernels.sgmv.ops import sgmv
from repro.kernels.sgmv.ref import sgmv_ref
