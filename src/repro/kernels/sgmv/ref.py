"""Pure-jnp oracle for the SGMV (segmented gather matrix-vector) LoRA op."""
from __future__ import annotations

import jax.numpy as jnp


def sgmv_ref(x, A, B, block_adapter, *, block_t: int, scale: float = 1.0):
    """Segmented LoRA delta over a token-packed buffer.

    x [T, din] — packed tokens; T % block_t == 0, and every block of
        ``block_t`` tokens belongs to a single adapter (the scheduler pads
        client segments to the tile size, like Punica/S-LoRA).
    A [n_adapters, din, r]; B [n_adapters, r, dout].
    block_adapter [T // block_t] int32 — adapter id per token block
        (negative id = dead block → zero output).
    Returns y [T, dout] = (x @ A[a]) @ B[a] * scale per block.
    """
    T, din = x.shape
    nb = T // block_t
    r = A.shape[-1]
    dout = B.shape[-1]
    xb = x.reshape(nb, block_t, din)
    a = jnp.clip(block_adapter, 0, A.shape[0] - 1)
    Ab = A[a]                                  # [nb, din, r]
    Bb = B[a]                                  # [nb, r, dout]
    h = jnp.einsum("bti,bir->btr", xb.astype(jnp.float32), Ab.astype(jnp.float32))
    y = jnp.einsum("btr,bro->bto", h, Bb.astype(jnp.float32)) * scale
    y = jnp.where((block_adapter >= 0)[:, None, None], y, 0.0)
    return y.reshape(T, dout).astype(x.dtype)
