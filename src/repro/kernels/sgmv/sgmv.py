"""SGMV Pallas TPU kernel: multi-adapter LoRA gather-matmul.

The Punica/S-LoRA op, re-tiled for the TPU memory hierarchy (DESIGN.md §2):
instead of a warp-level gather of adapter weights, the *grid* walks token
blocks and the adapter weights for each block are streamed HBM→VMEM by the
BlockSpec index_map, which reads the block's adapter id from a scalar-
prefetched table (``PrefetchScalarGridSpec``). MXU alignment: token blocks
of 128, dout tiles of 128+; the LoRA rank axis is zero-padded to the fp32
sublane tile (8) by ``ops.sgmv`` so the [bt, r] @ [r, bd] matmul keeps the
MXU fed.

Block i computes  y[i] = (x[i] @ A[id[i]]) @ B[id[i]] * scale  with fp32
accumulation; dead blocks (id < 0) emit zeros via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sgmv_kernel(block_adapter,        # scalar-prefetch [nb] int32
                 x_ref,                # [bt, din]
                 a_ref,                # [1, din, r]
                 b_ref,                # [1, r, bd]
                 y_ref,                # [bt, bd]
                 *, scale: float):
    i = pl.program_id(0)
    live = block_adapter[i] >= 0

    @pl.when(live)
    def _():
        x = x_ref[...].astype(jnp.float32)
        a = a_ref[0].astype(jnp.float32)
        b = b_ref[0].astype(jnp.float32)
        h = jnp.dot(x, a, preferred_element_type=jnp.float32)
        y_ref[...] = (jnp.dot(h, b, preferred_element_type=jnp.float32)
                      * scale).astype(y_ref.dtype)

    @pl.when(jnp.logical_not(live))
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)


def sgmv_pallas(x, A, B, block_adapter, *, block_t: int = 128,
                block_d: int = 512, scale: float = 1.0,
                interpret: bool = False):
    """See ref.sgmv_ref for semantics. Shapes must be pre-padded:
    T % block_t == 0, dout % block_d == 0."""
    T, din = x.shape
    n_adapters, _, r = A.shape
    dout = B.shape[-1]
    nb = T // block_t
    nd = dout // block_d
    clamped = jnp.clip(block_adapter, 0, n_adapters - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nd),
        in_specs=[
            pl.BlockSpec((block_t, din), lambda i, j, ids: (i, 0)),
            pl.BlockSpec((1, din, r), lambda i, j, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, r, block_d), lambda i, j, ids: (ids[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_d), lambda i, j, ids: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_sgmv_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, dout), x.dtype),
        interpret=interpret,
    )(block_adapter, x, A, B)


def sgmv_stream(x, A, B, block_adapter, *, block_t: int, scale: float = 1.0):
    """jnp twin of the SGMV kernel: one lax.scan step per token block, each
    step gathering its block's adapter and running the same two
    ``jnp.dot``s the kernel body runs — byte-identical to the Pallas kernel
    in interpret mode AND to a per-client vmapped LoRA application (the
    shared-weight matmul both lower to), which is what lets the serving
    engine's compacted decode apply per-row adapters through this op while
    staying byte-identical to the masked bank-wide path. Non-TPU backends
    run this twin (the grid interpreter's per-block overhead dwarfs the
    rank-r math); TPU runs the compiled kernel."""
    T, din = x.shape
    nb = T // block_t
    n_adapters = A.shape[0]
    dout = B.shape[-1]
    xb = x.reshape(nb, block_t, din)

    def body(_, inp):
        xi, idx = inp
        safe = jnp.clip(idx, 0, n_adapters - 1)
        a = A[safe].astype(jnp.float32)
        b = B[safe].astype(jnp.float32)
        h = jnp.dot(xi.astype(jnp.float32), a, preferred_element_type=jnp.float32)
        y = jnp.dot(h, b, preferred_element_type=jnp.float32) * scale
        return None, jnp.where(idx >= 0, y, 0.0).astype(x.dtype)

    # no carry -> block steps are independent; unrolling lets XLA overlap
    # the tiny rank-r dots instead of paying loop machinery per block
    _, yb = jax.lax.scan(body, None, (xb, block_adapter),
                         unroll=min(nb, 8))
    return yb.reshape(T, dout)


# NOTE on the index_map trick: clamped ids are NOT what the index_map sees —
# it receives the raw prefetched table, so callers must pass non-negative ids
# there when a block is dead but keep the sign bit in the *kernel* table.
# ``ops.sgmv`` therefore prefetches the raw table (sign used by pl.when) and
# relies on the index_map clamp below.
def sgmv_pallas_safe(x, A, B, block_adapter, **kw):
    """Variant whose index_map clamps dead ids (safe for any input)."""
    n_adapters = A.shape[0]

    def clamp(ids, i):
        return jnp.clip(ids[i], 0, n_adapters - 1)

    T, din = x.shape
    r = A.shape[-1]
    dout = B.shape[-1]
    block_t = kw.get("block_t", 128)
    block_d = kw.get("block_d", 512)
    scale = kw.get("scale", 1.0)
    interpret = kw.get("interpret", False)
    nb = T // block_t
    nd = dout // block_d

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nd),
        in_specs=[
            pl.BlockSpec((block_t, din), lambda i, j, ids: (i, 0)),
            pl.BlockSpec((1, din, r), lambda i, j, ids: (clamp(ids, i), 0, 0)),
            pl.BlockSpec((1, r, block_d), lambda i, j, ids: (clamp(ids, i), 0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_d), lambda i, j, ids: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_sgmv_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, dout), x.dtype),
        interpret=interpret,
    )(block_adapter, x, A, B)
