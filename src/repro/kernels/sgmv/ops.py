"""Jit'd public wrapper for the SGMV kernel (padding + dispatch + fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sgmv.ref import sgmv_ref
from repro.kernels.sgmv.sgmv import sgmv_pallas_safe, sgmv_stream


def _pad_to(x, axis, multiple):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width), n


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "scale",
                                             "use_kernel", "interpret"))
def sgmv(x, A, B, block_adapter, *, block_t: int = 128, block_d: int = 512,
         scale: float = 1.0, use_kernel: bool = True, interpret: bool = None):
    """Multi-adapter LoRA delta over a packed token buffer.

    x [T, din]; A [n, din, r]; B [n, r, dout]; block_adapter [T // block_t]
    (id per token block; negative = dead block). Arbitrary shapes — padding
    to tile multiples is handled here. ``interpret=None`` auto-selects by
    backend: the compiled Pallas kernel on TPU, its byte-identical jnp
    stream twin (``sgmv_stream``) elsewhere — the twin skips the grid
    interpreter whose per-block overhead dwarfs the rank-r math, and is
    also byte-identical to a per-client vmapped LoRA application (the
    compacted-decode exactness contract). ``block_t=1`` degenerates to one
    adapter per row — how the engine's compacted decode tick applies
    per-row client adapters; production TPU callers should sort rows by
    client into MXU-sized blocks instead."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return sgmv_ref(x, A, B, block_adapter, block_t=block_t, scale=scale)

    T0, dout0 = x.shape[0], B.shape[-1]
    x, _ = _pad_to(x, 0, block_t)
    nb = x.shape[0] // block_t
    ids = jnp.full((nb,), -1, jnp.int32).at[:block_adapter.shape[0]].set(block_adapter)
    if interpret:
        return sgmv_stream(x, A, B, ids, block_t=block_t, scale=scale)[:T0]
    # pad rank to the fp32 sublane tile and dout to the lane tile
    A, _ = _pad_to(A, 2, 8)
    B, _ = _pad_to(B, 1, 8)
    bd = min(block_d, max(128, dout0))
    B, _ = _pad_to(B, 2, bd)
    y = sgmv_pallas_safe(x, A, B, ids, block_t=block_t, block_d=bd,
                         scale=scale, interpret=False)
    return y[:T0, :dout0]
