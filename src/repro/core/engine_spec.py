"""Declarative engine construction: ``EngineSpec`` + ``BankSpec``.

One spec describes everything the symbiotic engines need to come up — the
model, the adapter banks (named entries with a PEFT config, a capacity and
a placement hint), the serving/fine-tuning configs, and the device mesh —
and is consumed by ``serving.ServingEngine``, ``training.FinetuneEngine``
and ``training.SymbiosisEngine.from_spec`` alike:

    spec = EngineSpec(
        cfg=model_cfg,
        banks=(BankSpec("lora8", lora_cfg, capacity=4),
               BankSpec("ia3",   ia3_cfg,  capacity=2)),
        serve=ServeConfig(n_clients=6, max_seq=256, page_block=16),
        finetune=FinetuneConfig(max_jobs=8),
        mesh=make_host_mesh(),            # None = single-device (default)
    )
    engine = ServingEngine(spec, base, banks)

This replaces the old parallel-sequence constructor
(``ServingEngine(cfg, acfg=[...], scfg, base, client_bank=[...])``) and
``FinetuneEngine``'s implicit bank grouping; the old signatures remain as
thin shims that emit a ``DeprecationWarning``.

``mesh`` is a ``jax.sharding.Mesh`` (see ``launch.mesh``). When set, the
engines shard their state onto it: the frozen base by
``launch.shardings.base_param_specs`` (tensor-parallel over ``model``,
FSDP fallback for oversized leaves — or fully replicated with
``replicate_base=True``), and the global page pool / adapter banks /
optimizer state with their client/page axes over ``(pod, data)``.
``mesh=None`` keeps today's single-device behavior exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.config import (AdapterConfig, FinetuneConfig, ModelConfig,
                          ServeConfig)

_PLACEMENTS = ("auto", "replicated")


@dataclasses.dataclass(frozen=True)
class BankSpec:
    """One named adapter bank: clients (serving) or job slots (training)
    sharing a PEFT method/rank.

    ``placement`` is the mesh hint for the bank's client axis: ``"auto"``
    shards it over the batch axes when divisible, ``"replicated"`` keeps
    the bank replicated on every device (tiny banks where the gather
    traffic outweighs the memory win)."""

    name: str
    acfg: AdapterConfig
    capacity: int
    placement: str = "auto"

    def __post_init__(self):
        if not self.name:
            raise ValueError("BankSpec needs a name")
        if self.capacity < 1:
            raise ValueError(f"bank {self.name!r}: capacity must be >= 1")
        if self.placement not in _PLACEMENTS:
            raise ValueError(f"bank {self.name!r}: placement "
                             f"{self.placement!r} not in {_PLACEMENTS}")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Declarative description of one symbiotic engine deployment."""

    cfg: ModelConfig
    banks: Tuple[BankSpec, ...] = ()
    serve: Optional[ServeConfig] = None
    finetune: Optional[FinetuneConfig] = None
    mesh: object = None                   # jax.sharding.Mesh | None
    replicate_base: bool = False
    max_batch_per_client: int = 4

    def __post_init__(self):
        object.__setattr__(self, "banks", tuple(self.banks))
        names = [b.name for b in self.banks]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate bank names: {names}")
        if self.max_batch_per_client < 1:
            raise ValueError("max_batch_per_client must be >= 1")
        if self.serve is None and self.finetune is None:
            raise ValueError("EngineSpec needs at least one of serve= / "
                             "finetune=")

    # ------------------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return sum(b.capacity for b in self.banks)

    def bank(self, name: str) -> BankSpec:
        for b in self.banks:
            if b.name == name:
                return b
        raise KeyError(f"no bank named {name!r}; have "
                       f"{[b.name for b in self.banks]}")

    def bank_cfgs(self) -> tuple:
        return tuple(b.acfg for b in self.banks)

    def init_banks(self, key) -> list:
        """Freshly initialized client-stacked adapter trees, one per bank
        (convenience for drivers/tests; production tenants bring their
        own adapter state)."""
        import jax

        from repro.core import adapters as adapters_lib

        return [adapters_lib.init_client_bank(
                    self.cfg, b.acfg, b.capacity, jax.random.fold_in(key, i))
                for i, b in enumerate(self.banks)]
