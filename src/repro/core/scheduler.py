"""Opportunistic batching policies (paper §3.7, Tables 4/5).

Event-driven engine over the base executor. Each client alternates
client-side compute (attention/adapter — duration from its cost model) with
a base-layer request per layer. The base executor serializes batched
executions; the policy decides how long a layer batch may wait:

* ``lockstep``     — a layer executes only when ALL active clients' requests
                     for that layer have arrived (torch autograd semantics;
                     what vLLM-style co-batching imposes).
* ``nolockstep``   — every request executes immediately, batch of 1.
* ``opportunistic``— a request waits at most ``wait_fraction`` × its own
                     iteration cost; whatever accumulated is batched. Large
                     (prefill/fine-tune) requests tolerate longer waits than
                     latency-sensitive decodes — the paper's size-aware rule.

The engine is a simulation *calibrated with measured per-op costs* (see
``base_executor.calibrate_layer_cost``); it optionally executes the real
packed matmuls to validate that batching preserves outputs.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List


class TickPolicy:
    """Projection of the simulation policies onto the REAL engine tick loop
    (serving.engine). The simulation decides *when a layer batch may wait*;
    the live engine quantizes time into decode ticks, so the same three
    policies become rules for admission timing and tick membership:

    * ``lockstep``      — vLLM-style static co-batching: new requests may
                          join only when the in-flight batch has fully
                          drained; every tick batches all active clients.
    * ``nolockstep``    — no cross-client batching: each tick serves one
                          ready client (round-robin), batch of 1.
    * ``opportunistic`` — continuous batching: requests join and leave
                          mid-stream and every tick batches exactly the
                          clients that are ready.

    Outputs are policy-invariant (the paper's exact-output property): the
    policy only chooses WHICH ready clients execute a given tick, never the
    math of any sequence's own token stream — a property the engine tests
    assert byte-for-byte."""

    NAMES = ("lockstep", "nolockstep", "opportunistic")

    def __init__(self, name: str):
        if name not in self.NAMES:
            raise ValueError(f"unknown policy {name!r}; pick from {self.NAMES}")
        self.name = name
        self._rr = 0

    def admit_now(self, n_inflight: int) -> bool:
        """May new requests be admitted while others are in flight?"""
        return n_inflight == 0 if self.name == "lockstep" else True

    def serving_set(self, ready: List[int]) -> List[int]:
        """Which of the ready clients join this decode tick."""
        if not ready:
            return []
        if self.name == "nolockstep":
            pick = sorted(ready)[self._rr % len(ready)]
            self._rr += 1
            return [pick]
        return sorted(ready)


@dataclass
class ClientSpec:
    client_id: int
    n_tokens: int                 # tokens per base-layer request
    client_side_time: float       # seconds of client-side compute per layer
    n_iterations: int = 1         # fine-tune steps or decode tokens to run
    latency_sensitive: bool = False


@dataclass
class SimResult:
    makespan: float
    per_client_latency: Dict[int, float]
    avg_batch_size: float
    total_tokens: int
    throughput: float
    n_executions: int

    def summary(self):
        lat = sum(self.per_client_latency.values()) / max(1, len(self.per_client_latency))
        return {"throughput_tok_s": self.throughput, "mean_latency_s": lat,
                "avg_batch": self.avg_batch_size, "makespan_s": self.makespan}


def simulate(clients: List[ClientSpec], n_layers: int, policy: str,
             exec_overhead: float, per_token_cost: float,
             wait_fraction: float = 0.1, backward: bool = False) -> SimResult:
    """Run the event-driven engine (work-conserving executor).

    A layer batch becomes *ready* per the policy (immediately / when all
    active clients arrived / after a size-aware deadline); the executor,
    when idle, dispatches the oldest ready layer with EVERYTHING pending on
    it — so batches keep accumulating while the executor is busy, like a
    real serving queue.

    backward=True doubles the layer walk (fine-tuning fwd+bwd; the §3.6
    memory-optimized backward lets batches differ between fwd and bwd —
    lockstep mode forbids that, per the paper)."""
    total_layers = n_layers * (2 if backward else 1)

    events = []                      # (time, seq, kind, payload)
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    iters_left = {c.client_id: c.n_iterations for c in clients}
    spec = {c.client_id: c for c in clients}
    start_time = {c.client_id: 0.0 for c in clients}
    latencies: Dict[int, List[float]] = {c.client_id: [] for c in clients}

    pending: Dict[int, List] = {}    # layer -> [(client_id, arrive_t)]
    ready_at: Dict[int, float] = {}  # layer -> time it became ready
    exec_busy = False
    n_exec = 0
    batch_sizes = []

    def exec_cost(tokens):
        return exec_overhead + tokens * per_token_cost

    def mark_ready(layer, t):
        if layer in pending and pending[layer] and layer not in ready_at:
            ready_at[layer] = t

    def try_dispatch(now):
        nonlocal exec_busy, n_exec
        if exec_busy:
            return
        if ready_at:
            layer = min(ready_at, key=ready_at.get)
            del ready_at[layer]
        elif policy == "opportunistic" and pending:
            # work-conserving: an idle executor never waits on a deadline —
            # the wait only lets batches grow while the executor is BUSY.
            layer = min(pending, key=lambda l: pending[l][0][1])
        else:
            return
        if policy == "nolockstep":
            entries = [pending[layer].pop(0)]
            if not pending[layer]:
                del pending[layer]
            else:
                ready_at[layer] = now          # rest remains ready
        else:
            entries = pending.pop(layer)
        tokens = sum(spec[cid].n_tokens for cid, _ in entries)
        exec_busy = True
        n_exec += 1
        batch_sizes.append(len(entries))
        push(now + exec_cost(tokens), "exec_done", (layer, entries))

    active = {c.client_id for c in clients}

    def lockstep_check(now):
        for lay in list(pending):
            if pending[lay] and {e[0] for e in pending[lay]} >= active:
                mark_ready(lay, now)

    for c in clients:
        push(c.client_side_time, "request", (c.client_id, 0))

    now = 0.0
    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == "request":
            cid, layer = payload
            if layer >= total_layers:
                latencies[cid].append(now - start_time[cid])
                iters_left[cid] -= 1
                if iters_left[cid] > 0:
                    start_time[cid] = now
                    push(now + spec[cid].client_side_time, "request", (cid, 0))
                else:
                    active.discard(cid)
                    if policy == "lockstep":
                        lockstep_check(now)
                        try_dispatch(now)
                continue
            pending.setdefault(layer, []).append((cid, now))
            if policy == "nolockstep":
                mark_ready(layer, now)
            elif policy == "lockstep":
                lockstep_check(now)
            else:  # opportunistic: size-aware deadline
                iter_cost = spec[cid].client_side_time + exec_cost(spec[cid].n_tokens)
                wait = (0.0 if spec[cid].latency_sensitive
                        else wait_fraction * iter_cost)
                if wait == 0.0:
                    mark_ready(layer, now)
                else:
                    push(now + wait, "deadline", layer)
            try_dispatch(now)
        elif kind == "deadline":
            mark_ready(payload, now)
            try_dispatch(now)
        elif kind == "exec_done":
            layer, entries = payload
            exec_busy = False
            for cid, _ in entries:
                push(now + spec[cid].client_side_time, "request",
                     (cid, layer + 1))
            try_dispatch(now)

    per_client = {cid: (sum(ls) / len(ls) if ls else 0.0)
                  for cid, ls in latencies.items()}
    makespan = now
    tokens_total = sum(c.n_tokens * c.n_iterations for c in clients)
    return SimResult(
        makespan=makespan,
        per_client_latency=per_client,
        avg_batch_size=(sum(batch_sizes) / len(batch_sizes)) if batch_sizes else 0.0,
        total_tokens=tokens_total,
        throughput=tokens_total / max(makespan, 1e-9),
        n_executions=n_exec,
    )
