"""The paper's primary contribution: Symbiosis split execution in JAX.

frozen_linear — memory-optimized backward for frozen base layers (§3.6)
virtlayer     — client-side splice (VirtLayer analogue, §3.2)
adapters      — LoRA / IA3 / prefix PEFT banks (goal 6)
packing       — token-budget ragged packing (§3.7)
scheduler     — opportunistic batching policies (§3.7)
privacy       — activation-noise protocol (§3.8)
base_executor — host-level packed frozen-layer service (§3.2)
engine_spec   — declarative EngineSpec/BankSpec engine construction
symbiosis     — multi-client train/serve step composition
"""
from repro.core.frozen_linear import frozen_dense, frozen_expert
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.core.virtlayer import make_client_ctx, attach_privacy
from repro.core import adapters, packing, privacy, scheduler, symbiosis
from repro.core.base_executor import BaseExecutor, calibrate_layer_cost
