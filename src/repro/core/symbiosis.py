"""Symbiosis system composition — the paper's contribution as a composable
JAX module.

Builds the multi-client steps in which ONE frozen base-parameter tree serves
a *bank* of clients (fine-tuning trainers and/or inference sessions):

* ``make_multi_client_train_step`` — C clients fine-tune their own adapters
  against the shared base. Client-side state (adapter params, AdamW state,
  per-client batch) carries a leading client axis (vmapped); base matmuls see
  the merged token batch, so cross-client batching happens inside one XLA
  matmul — the in-graph form of the paper's base-executor batching (§3.7).
* ``make_compact_train_step`` — the fine-tuning-as-a-service tick
  (``training.FinetuneEngine``): a job-masked, slot-compacted step over one
  BANK of jobs, each with its own traced hyperparameters/schedule position,
  gathered into a bucketed row batch and scattered back under a row mask.
  Runs the same per-row program as ``make_baseline_train_step``
  (``make_row_grad_fn``), which is what makes a served job's grads/params
  bitwise-equal to its dedicated run.
* ``make_multi_client_decode_step`` / ``prefill`` — inference banks sharing
  the base, one token per step per request against per-client KV caches.
* ``make_mixed_step`` — inference + fine-tuning clients time-share the base
  in one step (paper §4.4). The live-service form is
  ``training.SymbiosisEngine`` interleaving engine ticks.

The torch-like comparison baseline (each job differentiates through a
private base copy, saving activations) is ``make_baseline_train_step``'s
default (``memory_optimized=False``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.constrain import constrain_batch
from repro.config import (AdapterConfig, ModelConfig, TrainConfig, ServeConfig,
                          DENSE, MOE, VLM, HYBRID, ENCDEC)
from repro.core import adapters as adapters_lib
from repro.core.virtlayer import (make_client_ctx, make_compact_ctx,
                                  make_mixed_ctx)
from repro.models import get_model
from repro.models.losses import lm_loss
from repro.optim import adamw_init, adamw_update, adamw_update_hyper
from repro.optim.schedules import warmup_cosine


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def init_system(cfg: ModelConfig, acfg: AdapterConfig, n_clients: int, key,
                adapter_dtype=jnp.float32):
    """Returns (base_params, client_bank_adapters, opt_state_bank)."""
    k_base, k_bank = jax.random.split(key)
    model = get_model(cfg)
    base = model.init_params(k_base)
    bank = adapters_lib.init_client_bank(cfg, acfg, n_clients, k_bank, adapter_dtype)
    opt = jax.vmap(adamw_init)(bank)
    return base, bank, opt


# ---------------------------------------------------------------------------
# Multi-client fine-tuning
# ---------------------------------------------------------------------------

def make_multi_client_train_step(cfg: ModelConfig, acfg: AdapterConfig,
                                 tcfg: TrainConfig, *, moe_dispatch="scatter",
                                 capacity_factor: float = 1.25):
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, memory_optimized=tcfg.memory_optimized_backward)

    def client_loss(adapter, base, batch):
        logits, aux = model.forward(base, batch, ctx, adapter,
                                    remat=tcfg.remat, moe_dispatch=moe_dispatch,
                                    capacity_factor=capacity_factor)
        return lm_loss(logits, batch["labels"], batch.get("mask"), aux)

    grad_fn = jax.value_and_grad(client_loss)

    def _grads(base, bank, batch):
        """(losses [C], grads bank-tree). With tcfg.microbatch > 0 the
        per-client batch axis is split into microbatches accumulated with
        lax.scan — adapter grads are tiny, so accumulation is nearly free
        while activation temps shrink by the microbatch factor."""
        nmb = tcfg.microbatch
        B = batch["tokens"].shape[1]
        if not nmb or nmb <= 1 or B % nmb or B == nmb:
            return jax.vmap(grad_fn, in_axes=(0, None, 0))(bank, base, batch)

        def split(x):   # [C, B, ...] -> [nmb, C, B/nmb, ...]
            return x.reshape(x.shape[0], nmb, B // nmb, *x.shape[2:]).swapaxes(0, 1)

        mb = jax.tree.map(split, batch)
        zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), bank)

        def body(carry, mbatch):
            loss_acc, g_acc = carry
            losses, grads = jax.vmap(grad_fn, in_axes=(0, None, 0))(bank, base, mbatch)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / nmb,
                                 g_acc, grads)
            return (loss_acc + losses / nmb, g_acc), None

        (losses, grads), _ = jax.lax.scan(body, (jnp.zeros((losses_shape(bank),)),
                                                 zero_g), mb)
        return losses, grads

    def losses_shape(bank):
        return jax.tree.leaves(bank)[0].shape[0]

    def train_step(base, bank, opt, batch, step):
        """batch: pytree with leading [C, B, ...] axes; step: scalar int."""
        lr = warmup_cosine(step, tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
        losses, grads = _grads(base, bank, batch)
        new_bank, new_opt, gnorms = jax.vmap(
            lambda p, g, s: adamw_update(p, g, s, lr,
                                         weight_decay=tcfg.weight_decay,
                                         max_grad_norm=tcfg.max_grad_norm)
        )(bank, grads, opt)
        return new_bank, new_opt, {"loss": losses, "gnorm": gnorms, "lr": lr}

    return train_step


def make_row_grad_fn(cfg: ModelConfig, acfg: AdapterConfig, *,
                     remat: bool = True, memory_optimized: bool = True,
                     microbatch: int = 0, moe_dispatch: str = "scatter",
                     capacity_factor=None, differentiate_base: bool = False):
    """One JOB's loss-and-grads closure: ``fn(adapter, base, batch[B, ...])
    -> (loss, adapter_grads)``, with ``microbatch > 1`` accumulating grads
    over a ``lax.scan`` of B/microbatch-sized slices (mean of per-microbatch
    means, f32 accumulators — the same math as the bank-wide step's
    accumulation).

    This single closure is the byte-identity contract of fine-tuning as a
    service: ``make_compact_train_step`` vmaps it over the gathered bank
    rows and ``make_baseline_train_step`` runs it solo, so a job's grads in
    a bank are the SAME program as its dedicated run — equality is by
    construction, not by tolerance. ``differentiate_base=True`` additionally
    differentiates through the base tree (grads discarded), forcing
    activation residuals for every base linear — the torch-like memory
    baseline of Fig 9/10."""
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, memory_optimized=memory_optimized)

    def client_loss(adapter, base, batch):
        logits, aux = model.forward(base, batch, ctx, adapter, remat=remat,
                                    moe_dispatch=moe_dispatch,
                                    capacity_factor=capacity_factor)
        return lm_loss(logits, batch["labels"], batch.get("mask"), aux)

    if differentiate_base:
        def pair_loss(adapter_and_base, batch):
            adapter, base = adapter_and_base
            return client_loss(adapter, base, batch)

        vg = jax.value_and_grad(pair_loss)

        def grad_fn(adapter, base, batch):
            l, (g_adapter, _g_base_discarded) = vg((adapter, base), batch)
            return l, g_adapter
    else:
        grad_fn = jax.value_and_grad(client_loss)

    nmb = microbatch
    if not nmb or nmb <= 1:
        return grad_fn

    def accum_grad_fn(adapter, base, batch):
        B = batch["tokens"].shape[0]
        if B % nmb or B == nmb:
            return grad_fn(adapter, base, batch)

        def split(x):   # [B, ...] -> [nmb, B/nmb, ...]
            return x.reshape(nmb, B // nmb, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), adapter)

        def body(carry, mbatch):
            l_acc, g_acc = carry
            l, g = grad_fn(adapter, base, mbatch)
            g_acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32) / nmb,
                                 g_acc, g)
            return (l_acc + l / nmb, g_acc), None

        (l, g), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), mb)
        return l, g

    return accum_grad_fn


def make_baseline_train_step(cfg: ModelConfig, acfg: AdapterConfig,
                             tcfg: TrainConfig, *,
                             memory_optimized: bool = False,
                             moe_dispatch: str = "scatter",
                             capacity_factor=None):
    """Dedicated single-job trainer — the oracle every FinetuneEngine job is
    compared against, AND (by default) the torch-like memory baseline.

    ``memory_optimized=False`` (default) differentiates through the base
    tree (grads discarded), forcing activation residuals for every base
    linear — the paper's non-memory-optimized baseline for Fig 9/10.
    ``memory_optimized=True`` runs the §3.6 client path, exactly the
    program a bank row executes. Either way the step runs the SAME
    ``make_row_grad_fn`` closure the compact multi-job step vmaps
    (``tcfg.microbatch`` accumulation included), so a job served by the
    engine must reproduce this step's grads and params bit-for-bit."""
    row_grads = make_row_grad_fn(cfg, acfg, remat=tcfg.remat,
                                 memory_optimized=memory_optimized,
                                 microbatch=tcfg.microbatch,
                                 moe_dispatch=moe_dispatch,
                                 capacity_factor=capacity_factor,
                                 differentiate_base=not memory_optimized)

    def train_step(base, adapter, opt, batch, step):
        lr = warmup_cosine(step, tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
        l, grads = row_grads(adapter, base, batch)
        adapter, opt, gnorm = adamw_update(adapter, grads, opt, lr,
                                           weight_decay=tcfg.weight_decay,
                                           max_grad_norm=tcfg.max_grad_norm)
        return adapter, opt, {"loss": l, "gnorm": gnorm, "lr": lr}

    return train_step


def make_compact_train_step(cfg: ModelConfig, acfg: AdapterConfig, *,
                            microbatch: int = 0, remat: bool = True,
                            memory_optimized: bool = True,
                            moe_dispatch: str = "scatter",
                            capacity_factor=None):
    """Job-masked, slot-compacted multi-job train step — the FinetuneEngine's
    tick over ONE bank (jobs sharing an AdapterConfig + batch shape +
    microbatching, each with its OWN AdamW state, schedule position and
    data).

    fn(base, bank, opt, batch, slots, row_mask, hyper)
      -> (new bank, new opt, metrics)

    * ``bank`` / ``opt``  — job-stacked trees with leading [cap] bank-slot
      axis; only the gathered rows' slots are ever rewritten, so slots
      outside this call (retired jobs' leftovers, other jobs between their
      admission and this tick) stay bitwise untouched — the optimizer-state
      isolation guarantee under join/leave churn.
    * ``batch``           — leaves [R, B, ...]: row i is the job in bank
      slot ``slots[i]`` feeding its OWN per-step batch. R is a call-site
      property; the engine buckets the active-job count to a few static
      sizes to bound recompilation (the training analogue of the compacted
      decode tick). ``row_mask`` False marks padding rows: their loss is
      garbage and every write they produce is dropped at the scatter.
    * ``hyper``           — per-row traced hyperparameters, [R] arrays:
      ``step`` (the job's own schedule position), ``lr``, ``warmup``,
      ``total`` (its warmup-cosine schedule), ``wd``, ``gnorm`` (clip
      threshold; inf = no clipping). Heterogeneous jobs ride one vmapped
      step because ``adamw_update_hyper`` is bitwise-equal to the static
      conditional form at every setting.

    Per-row grads come from the same ``make_row_grad_fn`` closure the solo
    ``make_baseline_train_step`` runs, vmapped with the base unbatched —
    the merged token batch hits the shared base matmuls as ONE XLA op
    (§3.7 base-executor batching) while each job's grads and updated
    adapter params stay bit-for-bit equal to its dedicated run.

    HEALTH PROBE (docs/robustness.md): ``metrics["finite"]`` is a per-row
    isfinite reduction over the row's loss and every grad leaf, computed
    inside this jitted step (no extra dispatch, no pool copy), and the
    scatter commits a row only when ``row_mask & finite`` — a row whose
    step produced NaN/Inf keeps its LAST CLEAN adapter + optimizer state
    in the bank, so the engine can retry the same step or quarantine the
    job from a clean snapshot. When every row is finite the committed
    state is bitwise what the ungated scatter produced.
    """
    row_grads = make_row_grad_fn(cfg, acfg, remat=remat,
                                 memory_optimized=memory_optimized,
                                 microbatch=microbatch,
                                 moe_dispatch=moe_dispatch,
                                 capacity_factor=capacity_factor)

    def train_step(base, bank, opt, batch, slots, row_mask, hyper):
        cap = jax.tree.leaves(bank)[0].shape[0]
        slots = slots.astype(jnp.int32)
        # gather boundary: the compacted job rows (and their batches)
        # partition over the mesh batch axes, NOT over the base's
        # tensor-parallel axes — the scatter below returns to the bank's
        # own layout. No-ops without an ambient mesh.
        params = jax.tree.map(constrain_batch, jax.tree.map(
            lambda x: x[slots], bank))
        ostate = jax.tree.map(constrain_batch, jax.tree.map(
            lambda x: x[slots], opt))
        batch = jax.tree.map(constrain_batch, batch)
        R = slots.shape[0]

        def rows_finite(row_losses, row_grads):
            # per-row non-finite probe: loss AND every grad leaf ([R, ...])
            ok = jnp.isfinite(row_losses)
            for g in jax.tree.leaves(row_grads):
                ok = ok & jnp.isfinite(g).reshape(g.shape[0], -1).all(axis=1)
            return ok

        if R == 1:
            # A one-row bucket skips the vmap entirely: vmap-of-1 still
            # traces a BATCHED program, and for MoE layers XLA fuses that
            # batched backward differently from the solo baseline program
            # at some token counts (1-2 ulp drift — see tests/test_moe.py::
            # TestVmapBitwise). Running the single row through the same
            # unbatched program the baseline runs keeps the R=1 bucket on
            # the bitwise contract for every family.
            one = lambda t: jax.tree.map(lambda x: x[0], t)
            lift = lambda t: jax.tree.map(lambda x: x[None], t)
            l1, g1 = row_grads(one(params), base, one(batch))
            lr1 = warmup_cosine(hyper["step"][0], hyper["lr"][0],
                                hyper["warmup"][0], hyper["total"][0])
            p1, o1, gn1 = adamw_update_hyper(one(params), g1, one(ostate),
                                             lr1, hyper["wd"][0],
                                             hyper["gnorm"][0])
            new_p, new_o = lift(p1), lift(o1)
            losses, gnorms, lr = l1[None], gn1[None], lr1[None]
            finite = rows_finite(losses, lift(g1))
        else:
            losses, grads = jax.vmap(row_grads, in_axes=(0, None, 0))(
                params, base, batch)
            lr = warmup_cosine(hyper["step"], hyper["lr"], hyper["warmup"],
                               hyper["total"])
            new_p, new_o, gnorms = jax.vmap(adamw_update_hyper)(
                params, grads, ostate, lr, hyper["wd"], hyper["gnorm"])
            finite = rows_finite(losses, grads)
        # commit only healthy rows: a non-finite row's slot keeps its last
        # clean state (cap is out of bounds -> scatter-drop)
        drop = jnp.where(row_mask & finite, slots, cap)

        def scatter(full, rows):
            return full.at[drop].set(rows.astype(full.dtype), mode="drop")

        new_bank = jax.tree.map(scatter, bank, new_p)
        new_opt = jax.tree.map(scatter, opt, new_o)
        return new_bank, new_opt, {"loss": losses, "gnorm": gnorms, "lr": lr,
                                   "finite": finite}

    return train_step


# ---------------------------------------------------------------------------
# Multi-client inference
# ---------------------------------------------------------------------------

def make_multi_client_prefill(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                              scfg: ServeConfig, **ctx_kw):
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, **ctx_kw)

    def prefill(base, bank, caches, batch):
        """batch tokens [C, B, S]; caches with leading [C]."""
        def one(adapter, cache, b):
            return model.prefill(base, b, cache, ctx, adapter)
        return jax.vmap(one, in_axes=(0, 0, 0))(bank, caches, batch)

    return prefill


def make_multi_client_decode_step(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                                  scfg: ServeConfig, *, ring: bool = False, **ctx_kw):
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, **ctx_kw)
    kw = {"ring": True} if ring else {}

    def decode(base, bank, caches, tokens):
        """tokens [C, B] -> (logits [C, B, V], new caches)."""
        def one(adapter, cache, tok):
            return model.decode_step(base, cache, tok, ctx, adapter, **kw)
        return jax.vmap(one, in_axes=(0, 0, 0))(bank, caches, tokens)

    return decode


def serve_cache_kwargs(cfg: ModelConfig, scfg: ServeConfig, *,
                       pool_pages: Optional[int] = None):
    """Cache-construction kwargs implied by a ServeConfig for this family.

    Paging applies to the attention-bearing families only (recurrent
    families carry O(1) state — nothing to page); int8 KV quantization to
    the pure-KV families (dense/MoE/VLM). ``pool_pages`` overrides the
    pool sizing (the engine passes its allocator's pool size; slot-axis
    derivation passes 1 so pool shapes don't scale with the probe batch)."""
    kw = {}
    if scfg.page_block and cfg.arch in (DENSE, MOE, VLM, HYBRID, ENCDEC):
        kw["page_block"] = scfg.page_block
        if pool_pages is not None:
            kw["pool_pages"] = pool_pages
        elif scfg.pool_pages:
            kw["pool_pages"] = scfg.pool_pages
    if scfg.kv_quant and cfg.arch in (DENSE, MOE, VLM):
        kw["quant"] = True
    return kw


def cache_slot_axes(cfg: ModelConfig, max_seq: int, **cache_kw):
    """Per-leaf *slot axis* map for one client's decode cache.

    Cache trees are family-specific (KV tensors carry the sequence-slot
    [batch] axis at axis 1 under a leading layer/group axis, ``pos`` carries
    it at axis 0, pre-layer KV at axis 0, ...). The engine needs to merge /
    zero individual slots without knowing the family, so we derive the axis
    structurally: build the cache at batch 1 and batch 2 and record, per
    leaf, the axis where the shapes differ. Leaves whose shape does NOT
    depend on the batch — the paged layout's shared page pools — map to
    ``None``: they have no slot axis, are never zeroed per slot, and a
    masked step's pool writes are already gated by the active mask inside
    the model, so merges take the new value wholesale. ``block_tbl`` is
    likewise ``None``: it is engine-managed state that models pass through
    untouched. Returns a pytree of Optional[int] with the cache's
    structure. Shapes only — ``eval_shape`` never allocates the
    (potentially huge) caches."""
    model = get_model(cfg)
    if cache_kw.get("page_block"):
        # pin the pool size so it can't scale with the probe batch (auto
        # sizing is batch * n_blocks, which would masquerade as a slot axis)
        cache_kw = dict(cache_kw, pool_pages=cache_kw.get("pool_pages") or 1)
    a = jax.eval_shape(lambda: model.init_cache(1, max_seq, **cache_kw))
    b = jax.eval_shape(lambda: model.init_cache(2, max_seq, **cache_kw))

    def axis(x, y):
        for i, (m, n) in enumerate(zip(x.shape, y.shape)):
            if m != n:
                return i
        return None                      # batch-independent leaf (page pool)

    axes = jax.tree.map(axis, a, b)
    if isinstance(axes, dict) and "block_tbl" in axes:
        axes["block_tbl"] = None
    return axes


def _slot_mask(mask, ax, ndim):
    """Reshape a [n_slots] mask so it broadcasts along slot axis ``ax`` of an
    ``ndim``-rank cache leaf."""
    shape = [1] * ndim
    shape[ax] = mask.shape[-1]
    return mask.reshape(shape)


def make_client_prefill(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                        scfg: ServeConfig, **ctx_kw):
    """Masked single-client prefill — the engine's admission fast path.

    Unlike ``make_multi_client_prefill`` (which runs the whole bank and
    wastes C× base compute per admitted request), this runs the model ONCE
    for the admitted client and scatters the result into the bank caches:

      fn(base, bank, caches, c, a, tokens, lengths, slot_mask)
        -> (logits [max_b, V], new bank caches)

    * ``c``         — traced client index into the CACHES (one compile
                      serves every client).
    * ``a``         — traced adapter index into ``bank``. A single-bank
                      engine passes ``a == c``; a mixed-method engine
                      passes the client's index WITHIN its own method bank
                      (the caches stay global across banks, the adapter
                      trees do not).
    * ``tokens``    — [max_b, S_pad]; rows being admitted carry the prompt
                      (right-padded to the engine's jit bucket), other rows
                      are dummies.
    * ``lengths``   — [max_b] true prompt lengths; logits are gathered at
                      each row's last *real* position and cache ``pos``
                      starts there. Right-padding is exact for attention
                      families (causal masking + decode's write-before-read
                      overwrites stale pad K/V); recurrent families (hybrid,
                      RWKV) must be called with S_pad == S because pads
                      would pollute the carried state.
    * ``slot_mask`` — [max_b] bool; True rows are (re-)initialized: their
                      state is zeroed before the prefill (so a finished
                      sequence's stale recurrent state never leaks into the
                      slot's next occupant) and only their cache entries are
                      written back — other slots' in-flight state is
                      untouched, which is what makes mid-stream join work.
    """
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, **ctx_kw)
    cache_kw = serve_cache_kwargs(cfg, scfg, pool_pages=1)
    slot_axes = cache_slot_axes(cfg, scfg.max_seq, **cache_kw)
    page_axes = (cache_page_axes(cfg, scfg.max_seq, **cache_kw)
                 if "page_block" in cache_kw
                 else jax.tree.map(lambda ax: None, slot_axes))

    def prefill_one(base, bank, caches, c, a, tokens, lengths, slot_mask):
        adapter = jax.tree.map(lambda x: x[a], bank) if bank is not None else None

        def slice_c(x, ax, pax):
            # global page pools have no client axis; everything else
            # (per-slot leaves, the client's block-table rows) is sliced
            return x if pax is not None else x[c]

        old = jax.tree.map(slice_c, caches, slot_axes, page_axes)

        def zero_slots(x, ax):
            if ax is None:    # shared page pool / block table: no slot rows
                return x      # to zero — stale pages are masked by position
            return jnp.where(_slot_mask(slot_mask, ax, x.ndim),
                             jnp.zeros((), x.dtype), x)

        cleared = jax.tree.map(zero_slots, old, slot_axes)
        logits, new = model.prefill(base, {"tokens": tokens}, cleared, ctx,
                                    adapter, lengths=lengths)

        def merge(o, n, ax):
            if ax is None:    # pool writes were already bounded by lengths
                return n
            return jnp.where(_slot_mask(slot_mask, ax, o.ndim), n, o)

        merged = jax.tree.map(merge, old, new, slot_axes)

        def write_back(full, one, ax, pax):
            if pax is not None:
                return one                     # global pool: already merged
            return full.at[c].set(one)

        new_caches = jax.tree.map(write_back, caches, merged, slot_axes,
                                  page_axes)
        return logits, new_caches

    return prefill_one


def make_masked_decode_step(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                            scfg: ServeConfig, *, ring: bool = False, **ctx_kw):
    """Bank-wide decode tick with per-slot advance control.

    fn(base, bank, caches, tokens, active) -> (logits [C, B, V], new caches)

    ``active`` [C, B] bool marks the sequence slots that are decoding this
    tick; every other slot's cache (including its position counter) is left
    exactly as it was, so clients can run at different rates and sequences
    can join/leave mid-stream. The merge happens inside the jitted step —
    one dispatch per tick instead of a host-side tree traversal.

    Paged caches (scfg.page_block > 0) can't express the merge as a
    per-slot select — the page pool is GLOBAL (one flat pool, clients own
    page ranges; see init_client_caches) — so the active rows are threaded
    INTO the model step instead: inactive slots' pool writes are dropped at
    the scatter (blocks.paged_token_write) and the merge takes pool leaves
    wholesale. The pool rides the client vmap UNBATCHED: the write op and
    the table-aware attention kernel both carry custom_vmap rules that
    flatten the client axis into rows against the shared pool, so this
    bank-wide step lowers to exactly the computation the compacted step
    (make_compact_decode_step) runs on the active rows — byte-identity
    between the two is by construction, not by numerical luck."""
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, **ctx_kw)
    kw = {"ring": True} if ring else {}
    cache_kw = serve_cache_kwargs(cfg, scfg, pool_pages=1)
    paged = "page_block" in cache_kw
    slot_axes = cache_slot_axes(cfg, scfg.max_seq, **cache_kw)
    if paged:
        page_axes = cache_page_axes(cfg, scfg.max_seq, **cache_kw)
        # global pools are shared across the client vmap (in/out axis None)
        cache_axes = jax.tree.map(
            lambda x, pax: None if pax is not None else 0,
            jax.eval_shape(lambda: get_model(cfg).init_cache(
                1, scfg.max_seq, **cache_kw)), page_axes)

    def decode(base, bank, caches, tokens, active):
        if paged:
            def one(adapter, cache, tok, act):
                return model.decode_step(base, cache, tok, ctx, adapter,
                                         active=act, **kw)
            logits, new_caches = jax.vmap(
                one, in_axes=(0, cache_axes, 0, 0),
                out_axes=(0, cache_axes))(bank, caches, tokens, active)
        else:
            def one(adapter, cache, tok):
                return model.decode_step(base, cache, tok, ctx, adapter, **kw)
            logits, new_caches = jax.vmap(one, in_axes=(0, 0, 0))(bank, caches, tokens)

        def merge(o, n, ax):
            if ax is None:    # pool writes already active-gated in the model
                return n
            shape = [1] * o.ndim
            shape[0] = active.shape[0]
            shape[ax + 1] = active.shape[1]
            return jnp.where(active.reshape(shape), n, o)

        return logits, jax.tree.map(merge, caches, new_caches, slot_axes)

    return decode


def cache_page_axes(cfg: ModelConfig, max_seq: int, **cache_kw):
    """Per-leaf *page-pool axis* map for one client's PAGED decode cache.

    The structural twin of ``cache_slot_axes``: build the cache at
    ``pool_pages`` 1 and 2 and record, per leaf, the axis whose extent
    changed — that is the axis page pools stack their pages on (layer-
    stacked pools carry it behind the leading layer/group axis; pre-layer
    pools carry it at axis 0). Per-slot leaves (positions, recurrent state,
    cross-attention caches) and the block table don't scale with the pool
    and map to ``None``. Shapes only — nothing is allocated."""
    assert cache_kw.get("page_block"), "page axes exist only for paged caches"
    model = get_model(cfg)
    a = jax.eval_shape(lambda: model.init_cache(
        2, max_seq, **dict(cache_kw, pool_pages=1)))
    b = jax.eval_shape(lambda: model.init_cache(
        2, max_seq, **dict(cache_kw, pool_pages=2)))

    def axis(x, y):
        for i, (m, n) in enumerate(zip(x.shape, y.shape)):
            if m != n:
                return i
        return None

    return jax.tree.map(axis, a, b)


def _fold_pool_leaf(x, pax):
    """Fold a bank leaf's leading client axis into its page axis:
    [C, .., P@pax+1, ..] -> [.., C*P@pax, ..] (the global-pool layout
    convention — client c owns page range [c*P, (c+1)*P)). ``pax`` is the
    page axis of the PER-CLIENT leaf; None leaves pass through."""
    if pax is None:
        return x
    rest = list(x.shape)
    P = rest.pop(pax + 1)
    C = rest.pop(0)
    y = jnp.moveaxis(x, pax + 1, 1).reshape((C * P,) + tuple(rest))
    return jnp.moveaxis(y, 0, pax)


def stack_client_caches(cfg: ModelConfig, max_seq: int, per_client, **cache_kw):
    """Stack per-client model caches (e.g. after standalone per-client
    prefills on identity tables) into the BANK layout: per-slot leaves gain
    a leading client axis; paged pools fold into the one global flat pool
    (client c's pages land in [c*P, (c+1)*P)) and block tables are offset
    to global page ids. The inverse convention of ``init_client_caches``."""
    caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_client)
    if not cache_kw.get("page_block"):
        return caches
    page_axes = cache_page_axes(cfg, max_seq, **cache_kw)
    C = len(per_client)
    P = max(jax.tree.leaves(jax.tree.map(
        lambda x, pax: None if pax is None else x.shape[pax + 1],
        caches, page_axes)))
    caches = jax.tree.map(_fold_pool_leaf, caches, page_axes)
    caches["block_tbl"] = (caches["block_tbl"]
                           + (jnp.arange(C, dtype=jnp.int32) * P)[:, None, None])
    return caches


def make_compact_decode_step(cfg: ModelConfig, acfg, scfg: ServeConfig,
                             probe: bool = False, **ctx_kw):
    """Compute-proportional decode tick: run ONLY the actively decoding
    sequence slots, gathered across clients into one dense batch.

    Single-method (``acfg`` an AdapterConfig or None):

      fn(base, bank, caches, tokens, clients, slots, row_mask)
        -> (logits [n_rows, V], new bank caches)

    MIXED-METHOD (``acfg`` a tuple/list of AdapterConfigs — the serving
    engine's heterogeneous bank registry):

      fn(base, banks, caches, tokens, clients, slots, methods, locals_,
         row_mask) -> (logits [n_rows, V], new bank caches)

    where ``banks`` is the matching tuple of client-stacked adapter trees,
    ``methods[i]`` names row i's bank and ``locals_[i]`` its client index
    WITHIN that bank (``clients[i]`` stays the GLOBAL cache client index).
    One tick then carries several PEFT methods at once: LoRA rows keep the
    SGMV path (dead ids for other rows), IA3/prefix rows get per-row
    gathers keyed by their method id, and every application is gated by a
    membership select — so each row's math is byte-identical to its solo
    single-method run whatever its neighbours' methods are
    (``virtlayer.make_mixed_ctx`` / ``adapters.compact_mixed_bank``).

    * ``tokens``/``clients``/``slots``/``row_mask`` — [n_rows] arrays; row i
      is sequence slot ``slots[i]`` of client ``clients[i]`` feeding
      ``tokens[i]``. The row count is a call-site property (jax retraces
      per shape; the engine buckets the active count to a few static sizes
      to bound recompilation). ``row_mask`` False marks padding rows: their
      logits are garbage and every write they produce is dropped.
    * Requires the PAGED KV layout (``scfg.page_block > 0``): per-slot
      leaves (positions, recurrent state, cross-attention caches) are
      gathered per row and scattered back under the row mask, while the
      GLOBAL page pools (see ``init_client_caches``) pass through untouched
      — the gathered block-table rows already carry global page ids, so
      attention reads/writes land in the original pool pages through the
      table-aware kernel. The masked bank-wide decode lowers to exactly
      this flattened computation (the kernel's and the token write's
      custom_vmap rules), which makes the two paths byte-identical: the
      policy/occupancy only decides which rows exist, never their math.
    * Per-row client adapters are applied by ``make_compact_ctx`` — LoRA
      through the SGMV kernel (one adapter per row), IA3/prefix by per-row
      gathers. FLOPs and HBM traffic of base matmuls, adapter deltas and
      attention all scale with ``n_rows``, not with the bank size.
    * ``probe=True`` (HEALTH PROBE, docs/robustness.md) additionally
      returns a per-row ``finite`` [n_rows] bool — an isfinite reduction
      over the row's logits, computed inside the same jitted step — as
      ``(logits, finite, new caches)``. The logits and cache math are
      bit-identical to the unprobed step; the serving engine uses the flag
      to quarantine a request whose stream went non-finite without an
      extra device round-trip.
    """
    mixed = isinstance(acfg, (tuple, list))
    acfgs = tuple(acfg) if mixed else None
    model = get_model(cfg)
    cache_kw = serve_cache_kwargs(cfg, scfg, pool_pages=1)
    if "page_block" not in cache_kw:
        raise ValueError(
            "compact decode requires the paged KV layout (ServeConfig."
            "page_block > 0 on an attention-bearing family); the dense "
            "layout keeps the masked bank-wide step")
    slot_axes = cache_slot_axes(cfg, scfg.max_seq, **cache_kw)
    page_axes = cache_page_axes(cfg, scfg.max_seq, **cache_kw)
    # block_tbl is engine-managed: excluded from the generic leaf handling
    slot_axes.pop("block_tbl", None)
    page_axes.pop("block_tbl", None)

    def _rest(x, lifted):
        shape = list(x.shape)
        del shape[lifted], shape[0]
        return tuple(shape)

    def _gather_caches(caches, rows, C, B):
        inner = {k: v for k, v in caches.items() if k != "block_tbl"}

        def gather(x, ax, pax):
            if pax is not None:      # global pool: flat already, zero copies
                return x
            if ax is not None:       # per-slot leaf: [C, .., B@ax, ..] -> rows
                y = jnp.moveaxis(x, ax + 1, 1).reshape((C * B,) + _rest(x, ax + 1))
                # gather boundary: compacted rows partition over the mesh
                # batch axes (never the base's tensor axes) — no-op off-mesh
                return constrain_batch(jnp.moveaxis(y[rows], 0, ax), ax)
            raise ValueError("paged cache leaf with neither slot nor page axis")

        compact_cache = jax.tree.map(gather, inner, slot_axes, page_axes)
        # table rows already hold global page ids (allocator page ranges)
        compact_cache["block_tbl"] = constrain_batch(
            caches["block_tbl"].reshape(C * B, -1)[rows])
        return inner, compact_cache

    def _scatter_caches(inner, new_compact, rows, row_mask, C, B):
        new_compact = {k: v for k, v in new_compact.items() if k != "block_tbl"}
        drop_rows = jnp.where(row_mask, rows, C * B)     # C*B is out of bounds

        def scatter(old, new, ax, pax):
            if pax is not None:
                # pool writes were row-masked inside paged_token_write
                return new
            rest = _rest(old, ax + 1)
            flat = jnp.moveaxis(old, ax + 1, 1).reshape((C * B,) + rest)
            vals = jnp.moveaxis(new, ax, 0)
            flat = flat.at[drop_rows].set(vals.astype(flat.dtype), mode="drop")
            return jnp.moveaxis(flat.reshape((C, B) + rest), 1, ax + 1)

        return jax.tree.map(scatter, inner, new_compact, slot_axes, page_axes)

    def compact(base, bank, caches, tokens, clients, slots, row_mask):
        C, B = caches["pos"].shape
        clients = clients.astype(jnp.int32)
        slots = slots.astype(jnp.int32)
        rows = clients * B + slots
        inner, compact_cache = _gather_caches(caches, rows, C, B)
        ctx = make_client_ctx(cfg, None, **ctx_kw) if bank is None else \
            make_compact_ctx(cfg, acfg, clients, **ctx_kw)
        adapter = adapters_lib.compact_adapter_bank(bank, clients)
        logits, new_compact = model.decode_step(base, compact_cache,
                                                constrain_batch(tokens),
                                                ctx, adapter, active=row_mask)
        new_inner = _scatter_caches(inner, new_compact, rows, row_mask, C, B)
        return _out(constrain_batch(logits),
                    dict(new_inner, block_tbl=caches["block_tbl"]))

    def _out(logits, new_caches):
        if probe:
            return logits, jnp.isfinite(logits).all(axis=-1), new_caches
        return logits, new_caches

    def compact_mixed(base, banks, caches, tokens, clients, slots, methods,
                      locals_, row_mask):
        C, B = caches["pos"].shape
        clients = clients.astype(jnp.int32)
        slots = slots.astype(jnp.int32)
        methods = methods.astype(jnp.int32)
        locals_ = locals_.astype(jnp.int32)
        rows = clients * B + slots
        inner, compact_cache = _gather_caches(caches, rows, C, B)
        ctx = make_mixed_ctx(cfg, acfgs, locals_, methods, **ctx_kw)
        adapter = adapters_lib.compact_mixed_bank(banks, locals_, methods)
        logits, new_compact = model.decode_step(base, compact_cache,
                                                constrain_batch(tokens),
                                                ctx, adapter, active=row_mask)
        new_inner = _scatter_caches(inner, new_compact, rows, row_mask, C, B)
        return _out(constrain_batch(logits),
                    dict(new_inner, block_tbl=caches["block_tbl"]))

    return compact_mixed if mixed else compact


def make_compact_prefill(cfg: ModelConfig, acfg, scfg: ServeConfig,
                         probe: bool = False, ext_blocks: int = 0, **ctx_kw):
    """Cross-client compacted PREFILL: every same-tick admission — across
    clients and, in the mixed registry, across banks — rides ONE ragged
    jit-bucketed batch (the admission analogue of
    ``make_compact_decode_step``).

    Single-method (``acfg`` an AdapterConfig or None):

      fn(base, bank, caches, tokens, lengths, starts, clients, slots,
         row_mask) -> (logits [n_rows, V], new bank caches)

    MIXED-METHOD (``acfg`` a tuple/list of AdapterConfigs):

      fn(base, banks, caches, tokens, lengths, starts, clients, slots,
         methods, locals_, row_mask) -> (logits [n_rows, V], new caches)

    * ``tokens`` [n_rows, S_pad] right-padded prompts; ``lengths`` [n_rows]
      true suffix lengths; ``starts`` [n_rows] tokens ALREADY cached in the
      row's mapped shared-prefix pages (0 = full prefill). Row i is slot
      ``slots[i]`` of client ``clients[i]``; ``row_mask`` False marks
      padding rows (length 0, every write dropped at the scatter).
    * ``ext_blocks`` (static, a jit bucket) is the number of leading
      block-table entries gathered pre-scan as read-only prefix K/V lanes
      (``model.prefill(starts=, ext_blocks=)``); 0 compiles the exact
      full-prefill program. Rows with fewer cached blocks mask unused
      lanes by position — exact-zero softmax weight, so compacted+shared
      output is bitwise the per-client no-sharing prefill
      (docs/prefix_cache.md).
    * Per-row adapters use the same SGMV / per-row-gather machinery as the
      compacted decode (LoRA blocks are S_pad tokens wide here); mixed
      rows gate every application by bank membership.
    * ``probe=True`` returns ``(logits, finite [n_rows] bool, caches)`` —
      the admission health probe, same contract as the decode step.
    * Requires the paged layout on a pure-KV attention family (dense /
      MoE / VLM): recurrent and cross-attention families carry per-slot
      state the cross-client gather cannot zero per row, and stay on the
      per-client admission path."""
    mixed = isinstance(acfg, (tuple, list))
    acfgs = tuple(acfg) if mixed else None
    model = get_model(cfg)
    cache_kw = serve_cache_kwargs(cfg, scfg, pool_pages=1)
    if "page_block" not in cache_kw:
        raise ValueError(
            "compact prefill requires the paged KV layout (ServeConfig."
            "page_block > 0 on an attention-bearing family)")
    if cfg.arch not in (DENSE, MOE, VLM):
        raise ValueError(
            f"compact prefill serves the pure-KV families (dense/MoE/VLM); "
            f"{cfg.arch} admissions stay on the per-client prefill path")
    if ext_blocks and cache_kw.get("quant"):
        raise ValueError("shared-prefix prefill (ext_blocks > 0) requires "
                         "an unquantized KV cache")
    slot_axes = cache_slot_axes(cfg, scfg.max_seq, **cache_kw)
    page_axes = cache_page_axes(cfg, scfg.max_seq, **cache_kw)
    slot_axes.pop("block_tbl", None)
    page_axes.pop("block_tbl", None)

    def _rest(x, lifted):
        shape = list(x.shape)
        del shape[lifted], shape[0]
        return tuple(shape)

    def _gather_caches(caches, rows, C, B):
        inner = {k: v for k, v in caches.items() if k != "block_tbl"}

        def gather(x, ax, pax):
            if pax is not None:      # global pool: flat already, zero copies
                return x
            if ax is not None:
                y = jnp.moveaxis(x, ax + 1, 1).reshape((C * B,) + _rest(x, ax + 1))
                return constrain_batch(jnp.moveaxis(y[rows], 0, ax), ax)
            raise ValueError("paged cache leaf with neither slot nor page axis")

        compact_cache = jax.tree.map(gather, inner, slot_axes, page_axes)
        compact_cache["block_tbl"] = constrain_batch(
            caches["block_tbl"].reshape(C * B, -1)[rows])
        return inner, compact_cache

    def _scatter_caches(inner, new_compact, rows, row_mask, C, B):
        new_compact = {k: v for k, v in new_compact.items() if k != "block_tbl"}
        drop_rows = jnp.where(row_mask, rows, C * B)     # C*B is out of bounds

        def scatter(old, new, ax, pax):
            if pax is not None:
                # pool writes were bounded by each row's true length inside
                # paged_prefill_write (padding rows carry length 0)
                return new
            rest = _rest(old, ax + 1)
            flat = jnp.moveaxis(old, ax + 1, 1).reshape((C * B,) + rest)
            vals = jnp.moveaxis(new, ax, 0)
            flat = flat.at[drop_rows].set(vals.astype(flat.dtype), mode="drop")
            return jnp.moveaxis(flat.reshape((C, B) + rest), 1, ax + 1)

        return jax.tree.map(scatter, inner, new_compact, slot_axes, page_axes)

    def _out(logits, new_caches):
        if probe:
            return logits, jnp.isfinite(logits).all(axis=-1), new_caches
        return logits, new_caches

    def _run(base, caches, tokens, lengths, starts, clients, slots,
             row_mask, ctx, adapter):
        C, B = caches["pos"].shape
        rows = clients.astype(jnp.int32) * B + slots.astype(jnp.int32)
        inner, compact_cache = _gather_caches(caches, rows, C, B)
        logits, new_compact = model.prefill(
            base, {"tokens": constrain_batch(tokens)}, compact_cache, ctx,
            adapter, lengths=lengths,
            starts=starts.astype(jnp.int32), ext_blocks=ext_blocks)
        new_inner = _scatter_caches(inner, new_compact, rows, row_mask, C, B)
        return _out(constrain_batch(logits),
                    dict(new_inner, block_tbl=caches["block_tbl"]))

    def compact(base, bank, caches, tokens, lengths, starts, clients, slots,
                row_mask):
        clients = clients.astype(jnp.int32)
        ctx = make_client_ctx(cfg, None, **ctx_kw) if bank is None else \
            make_compact_ctx(cfg, acfg, clients, **ctx_kw)
        adapter = adapters_lib.compact_adapter_bank(bank, clients)
        return _run(base, caches, tokens, lengths, starts, clients, slots,
                    row_mask, ctx, adapter)

    def compact_mixed(base, banks, caches, tokens, lengths, starts, clients,
                      slots, methods, locals_, row_mask):
        methods = methods.astype(jnp.int32)
        locals_ = locals_.astype(jnp.int32)
        ctx = make_mixed_ctx(cfg, acfgs, locals_, methods, **ctx_kw)
        adapter = adapters_lib.compact_mixed_bank(banks, locals_, methods)
        return _run(base, caches, tokens, lengths, starts,
                    clients.astype(jnp.int32), slots, row_mask, ctx, adapter)

    return compact_mixed if mixed else compact


def make_page_copy(cfg: ModelConfig, scfg: ServeConfig):
    """Copy-on-write primitive: duplicate ONE global pool page in place.

    fn(caches, src, dst) -> caches with page ``dst`` holding a bitwise copy
    of page ``src`` on every pool leaf (the layer axis is explicit on the
    stored leaves, so one dynamic slice/update per leaf copies the page at
    every layer at once). ``src``/``dst`` are traced int32 scalars — one
    compile serves every CoW admission. Non-pool leaves (positions, block
    tables) pass through untouched; the engine jits this with the caches
    donated, so the copy is a page-sized in-place write, never a pool
    materialization (docs/prefix_cache.md)."""
    cache_kw = serve_cache_kwargs(cfg, scfg, pool_pages=1)
    if "page_block" not in cache_kw:
        raise ValueError("page copy exists only for the paged KV layout")
    page_axes = cache_page_axes(cfg, scfg.max_seq, **cache_kw)

    def copy_page(caches, src, dst):
        src = src.astype(jnp.int32)
        dst = dst.astype(jnp.int32)

        def cp(x, pax):
            if pax is None:
                return x
            page = jax.lax.dynamic_slice_in_dim(x, src, 1, axis=pax)
            return jax.lax.dynamic_update_slice_in_dim(x, page, dst, axis=pax)

        return jax.tree.map(cp, caches, page_axes)

    return copy_page


def init_client_caches(cfg: ModelConfig, n_clients: int, batch: int, max_seq: int,
                       dtype=None, *, window: int = 0, quant: bool = False,
                       page_block: int = 0, pool_pages: int = 0):
    """Bank caches: per-slot leaves carry a leading client axis; PAGED pools
    are stored GLOBALLY FLAT — the client axis is folded into the page axis
    once at construction ([C, .., P, ..] -> [.., C*P, ..]) and per-client
    ownership becomes an allocator convention (client c owns page range
    [c*P, (c+1)*P)), not a tensor axis. That is what keeps the decode tick
    compute-proportional: neither the masked step (vmapped with the pool
    unbatched) nor the compacted step ever reshapes or copies the pool —
    block tables simply carry global page ids."""
    model = get_model(cfg)
    kw = {}
    if window:
        kw["window"] = window
    if quant:
        kw["quant"] = True
    if page_block:
        kw["page_block"] = page_block
        if pool_pages:
            kw["pool_pages"] = pool_pages
    one = model.init_cache(batch, max_seq, dtype, **kw)
    caches = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape)
                          .copy(), one)
    if page_block:
        page_axes = cache_page_axes(cfg, max_seq, **kw)
        caches = jax.tree.map(_fold_pool_leaf, caches, page_axes)
    return caches


# ---------------------------------------------------------------------------
# Mixed inference + fine-tuning (paper §4.4)
# ---------------------------------------------------------------------------

def make_mixed_step(cfg: ModelConfig, acfg: AdapterConfig, tcfg: TrainConfig,
                    scfg: ServeConfig):
    """One step: FT clients take a train step while inference clients decode,
    all against the same resident base params."""
    train_step = make_multi_client_train_step(cfg, acfg, tcfg)
    decode_step = make_multi_client_decode_step(cfg, acfg, scfg)

    def mixed(base, ft_bank, ft_opt, ft_batch, inf_bank, inf_caches, inf_tokens, step):
        ft_bank, ft_opt, metrics = train_step(base, ft_bank, ft_opt, ft_batch, step)
        logits, inf_caches = decode_step(base, inf_bank, inf_caches, inf_tokens)
        return ft_bank, ft_opt, inf_caches, logits, metrics

    return mixed
