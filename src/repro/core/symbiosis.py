"""Symbiosis system composition — the paper's contribution as a composable
JAX module.

Builds the multi-client steps in which ONE frozen base-parameter tree serves
a *bank* of clients (fine-tuning trainers and/or inference sessions):

* ``make_multi_client_train_step`` — C clients fine-tune their own adapters
  against the shared base. Client-side state (adapter params, AdamW state,
  per-client batch) carries a leading client axis (vmapped); base matmuls see
  the merged token batch, so cross-client batching happens inside one XLA
  matmul — the in-graph form of the paper's base-executor batching (§3.7).
* ``make_multi_client_decode_step`` / ``prefill`` — inference banks sharing
  the base, one token per step per request against per-client KV caches.
* ``make_mixed_step`` — inference + fine-tuning clients time-share the base
  in one step (paper §4.4).

The torch-like comparison baseline (each job re-differentiates a private
base copy, saving activations) is available via
``memory_optimized_backward=False`` + ``baseline_dedicated_base=True``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import AdapterConfig, ModelConfig, TrainConfig, ServeConfig
from repro.core import adapters as adapters_lib
from repro.core.virtlayer import make_client_ctx
from repro.models import get_model
from repro.models.losses import lm_loss
from repro.optim import adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def init_system(cfg: ModelConfig, acfg: AdapterConfig, n_clients: int, key,
                adapter_dtype=jnp.float32):
    """Returns (base_params, client_bank_adapters, opt_state_bank)."""
    k_base, k_bank = jax.random.split(key)
    model = get_model(cfg)
    base = model.init_params(k_base)
    bank = adapters_lib.init_client_bank(cfg, acfg, n_clients, k_bank, adapter_dtype)
    opt = jax.vmap(adamw_init)(bank)
    return base, bank, opt


# ---------------------------------------------------------------------------
# Multi-client fine-tuning
# ---------------------------------------------------------------------------

def make_multi_client_train_step(cfg: ModelConfig, acfg: AdapterConfig,
                                 tcfg: TrainConfig, *, moe_dispatch="scatter",
                                 capacity_factor: float = 1.25):
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, memory_optimized=tcfg.memory_optimized_backward)

    def client_loss(adapter, base, batch):
        logits, aux = model.forward(base, batch, ctx, adapter,
                                    remat=tcfg.remat, moe_dispatch=moe_dispatch,
                                    capacity_factor=capacity_factor)
        return lm_loss(logits, batch["labels"], batch.get("mask"), aux)

    grad_fn = jax.value_and_grad(client_loss)

    def _grads(base, bank, batch):
        """(losses [C], grads bank-tree). With tcfg.microbatch > 0 the
        per-client batch axis is split into microbatches accumulated with
        lax.scan — adapter grads are tiny, so accumulation is nearly free
        while activation temps shrink by the microbatch factor."""
        nmb = tcfg.microbatch
        B = batch["tokens"].shape[1]
        if not nmb or nmb <= 1 or B % nmb or B == nmb:
            return jax.vmap(grad_fn, in_axes=(0, None, 0))(bank, base, batch)

        def split(x):   # [C, B, ...] -> [nmb, C, B/nmb, ...]
            return x.reshape(x.shape[0], nmb, B // nmb, *x.shape[2:]).swapaxes(0, 1)

        mb = jax.tree.map(split, batch)
        zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), bank)

        def body(carry, mbatch):
            loss_acc, g_acc = carry
            losses, grads = jax.vmap(grad_fn, in_axes=(0, None, 0))(bank, base, mbatch)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / nmb,
                                 g_acc, grads)
            return (loss_acc + losses / nmb, g_acc), None

        (losses, grads), _ = jax.lax.scan(body, (jnp.zeros((losses_shape(bank),)),
                                                 zero_g), mb)
        return losses, grads

    def losses_shape(bank):
        return jax.tree.leaves(bank)[0].shape[0]

    def train_step(base, bank, opt, batch, step):
        """batch: pytree with leading [C, B, ...] axes; step: scalar int."""
        lr = warmup_cosine(step, tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
        losses, grads = _grads(base, bank, batch)
        new_bank, new_opt, gnorms = jax.vmap(
            lambda p, g, s: adamw_update(p, g, s, lr,
                                         weight_decay=tcfg.weight_decay,
                                         max_grad_norm=tcfg.max_grad_norm)
        )(bank, grads, opt)
        return new_bank, new_opt, {"loss": losses, "gnorm": gnorms, "lr": lr}

    return train_step


def make_baseline_train_step(cfg: ModelConfig, acfg: AdapterConfig,
                             tcfg: TrainConfig):
    """Torch-like baseline: ONE client, differentiates through the base tree
    (grads discarded) — forces activation residuals for every base linear,
    emulating the paper's non-memory-optimized baseline for Fig 9/10."""
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, memory_optimized=False)

    def loss(adapter_and_base, batch):
        adapter, base = adapter_and_base
        logits, aux = model.forward(base, batch, ctx, adapter, remat=tcfg.remat)
        return lm_loss(logits, batch["labels"], batch.get("mask"), aux)

    def train_step(base, adapter, opt, batch, step):
        lr = warmup_cosine(step, tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
        (l, grads) = jax.value_and_grad(loss)((adapter, base), batch)
        g_adapter, _g_base_discarded = grads
        adapter, opt, gnorm = adamw_update(adapter, g_adapter, opt, lr,
                                           weight_decay=tcfg.weight_decay,
                                           max_grad_norm=tcfg.max_grad_norm)
        return adapter, opt, {"loss": l, "gnorm": gnorm, "lr": lr}

    return train_step


# ---------------------------------------------------------------------------
# Multi-client inference
# ---------------------------------------------------------------------------

def make_multi_client_prefill(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                              scfg: ServeConfig, **ctx_kw):
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, **ctx_kw)

    def prefill(base, bank, caches, batch):
        """batch tokens [C, B, S]; caches with leading [C]."""
        def one(adapter, cache, b):
            return model.prefill(base, b, cache, ctx, adapter)
        return jax.vmap(one, in_axes=(0, 0, 0))(bank, caches, batch)

    return prefill


def make_multi_client_decode_step(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                                  scfg: ServeConfig, *, ring: bool = False, **ctx_kw):
    model = get_model(cfg)
    ctx = make_client_ctx(cfg, acfg, **ctx_kw)
    kw = {"ring": True} if ring else {}

    def decode(base, bank, caches, tokens):
        """tokens [C, B] -> (logits [C, B, V], new caches)."""
        def one(adapter, cache, tok):
            return model.decode_step(base, cache, tok, ctx, adapter, **kw)
        return jax.vmap(one, in_axes=(0, 0, 0))(bank, caches, tokens)

    return decode


def init_client_caches(cfg: ModelConfig, n_clients: int, batch: int, max_seq: int,
                       dtype=None, *, window: int = 0, quant: bool = False):
    model = get_model(cfg)
    kw = {}
    if window:
        kw["window"] = window
    if quant:
        kw["quant"] = True
    one = model.init_cache(batch, max_seq, dtype, **kw)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape)
                        .copy(), one)


# ---------------------------------------------------------------------------
# Mixed inference + fine-tuning (paper §4.4)
# ---------------------------------------------------------------------------

def make_mixed_step(cfg: ModelConfig, acfg: AdapterConfig, tcfg: TrainConfig,
                    scfg: ServeConfig):
    """One step: FT clients take a train step while inference clients decode,
    all against the same resident base params."""
    train_step = make_multi_client_train_step(cfg, acfg, tcfg)
    decode_step = make_multi_client_decode_step(cfg, acfg, scfg)

    def mixed(base, ft_bank, ft_opt, ft_batch, inf_bank, inf_caches, inf_tokens, step):
        ft_bank, ft_opt, metrics = train_step(base, ft_bank, ft_opt, ft_batch, step)
        logits, inf_caches = decode_step(base, inf_bank, inf_caches, inf_tokens)
        return ft_bank, ft_opt, inf_caches, logits, metrics

    return mixed
