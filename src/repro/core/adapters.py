"""PEFT adapters: LoRA, IA3, prefix-tuning (paper design goal 6).

An adapter tree mirrors the model's layer containers (``layers`` /
``pre_layers`` / ``groups`` / ``enc_layers`` / ``dec_layers``) so it can ride
along the layer scan. Leaves are keyed by linear-path name; the client
LinearFns hook (core.virtlayer) looks its path up and applies the method.

Multi-client banks: clients with the *same* (method, rank) are stacked along
a leading client axis and vmapped; heterogeneous methods/ranks form separate
banks (DESIGN.md §5). For mixed-rank LoRA banks, ranks may be padded up to
the bank's max rank — zero rows are exact no-ops in the LoRA update.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import AdapterConfig, ModelConfig, RWKV, HYBRID, ENCDEC

# path -> (din, dout) builders per architecture family ---------------------


def _dense_target_dims(cfg: ModelConfig) -> Dict[str, tuple]:
    hd = cfg.hd
    d = cfg.d_model
    dims = {
        "q": (d, cfg.hp * hd),
        "k": (d, cfg.n_kv_heads * hd),
        "v": (d, cfg.n_kv_heads * hd),
        "o": (cfg.hp * hd, d),
        "gate": (d, cfg.d_ff),
        "up": (d, cfg.d_ff),
        "down": (cfg.d_ff, d),
    }
    if cfg.n_experts:
        dims["router"] = (d, cfg.n_experts)
    return dims


def _rwkv_target_dims(cfg: ModelConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    return {
        "r": (d, d), "k": (d, d), "v": (d, d), "g": (d, d), "o": (d, d),
        "cm_k": (d, cfg.d_ff), "cm_v": (cfg.d_ff, d), "cm_r": (d, d),
    }


# RWKV has no q projection; map the conventional q/v targets onto r/v.
_RWKV_ALIAS = {"q": "r"}

# Sensible default target sets per PEFT method (what the CLI driver and
# benchmarks hand to jobs that don't pick their own): LoRA on the full
# attention block, IA3 on its paper placements (k/v activations + the FFN
# intermediate), prefix on q/v (the prefix K/V ride model code, not the
# linear hook — targets only gate which layers carry prefixes).
DEFAULT_TARGETS = {
    "lora": ("q", "k", "v", "o"),
    "ia3": ("k", "v", "down"),
    "prefix": ("q", "v"),
}


def target_dims(cfg: ModelConfig):
    return _rwkv_target_dims(cfg) if cfg.arch == RWKV else _dense_target_dims(cfg)


def resolve_targets(cfg: ModelConfig, acfg: AdapterConfig):
    dims = target_dims(cfg)
    out = []
    for t in acfg.targets:
        t = _RWKV_ALIAS.get(t, t) if cfg.arch == RWKV else t
        if t in dims:
            out.append((t, dims[t]))
    return out


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _lora_leaf(key, din, dout, rank, dtype):
    ka, _ = jax.random.split(key)
    return {
        "A": (jax.random.normal(ka, (din, rank), jnp.float32) / math.sqrt(din)).astype(dtype),
        "B": jnp.zeros((rank, dout), dtype),  # B=0 -> adapter starts as identity
    }


def _ia3_leaf(din, dout, path, dtype):
    # IA3 scales k/v/ffn activations; stored as a vector on the output dim
    # (input dim for 'down', per the paper's use on the FFN intermediate).
    n = din if path == "down" else dout
    return {"scale": jnp.ones((n,), dtype)}


def _layer_adapter(key, cfg, acfg, dtype):
    leaf = {}
    for (path, (din, dout)), k in zip(
            resolve_targets(cfg, acfg),
            jax.random.split(key, max(1, len(resolve_targets(cfg, acfg))))):
        if acfg.method == "lora":
            leaf[path] = _lora_leaf(k, din, dout, acfg.rank, dtype)
        elif acfg.method == "ia3":
            leaf[path] = _ia3_leaf(din, dout, path, dtype)
    if acfg.method == "prefix":
        hd, K = cfg.hd, cfg.n_kv_heads
        k1, k2 = jax.random.split(key)
        leaf["prefix_k"] = (jax.random.normal(k1, (acfg.n_prefix, K, hd), jnp.float32) * 0.02).astype(dtype)
        leaf["prefix_v"] = (jax.random.normal(k2, (acfg.n_prefix, K, hd), jnp.float32) * 0.02).astype(dtype)
    return leaf


def init_adapter(cfg: ModelConfig, acfg: AdapterConfig, key, dtype=jnp.float32):
    """Build one client's adapter tree, mirroring the model's layer layout."""
    if cfg.arch == HYBRID:
        n_groups = cfg.n_layers // cfg.attn_every
        return {"groups": jax.vmap(lambda k: _layer_adapter(k, cfg, acfg, dtype))(
            jax.random.split(key, n_groups))}
    if cfg.arch == ENCDEC:
        k1, k2 = jax.random.split(key)
        return {
            "enc_layers": jax.vmap(lambda k: _layer_adapter(k, cfg, acfg, dtype))(
                jax.random.split(k1, cfg.n_enc_layers)),
            "dec_layers": jax.vmap(lambda k: _layer_adapter(k, cfg, acfg, dtype))(
                jax.random.split(k2, cfg.n_layers)),
        }
    n_pre = cfg.first_dense_layers
    tree = {"layers": jax.vmap(lambda k: _layer_adapter(k, cfg, acfg, dtype))(
        jax.random.split(key, cfg.n_layers - n_pre))}
    if n_pre:
        tree["pre_layers"] = [
            _layer_adapter(k, cfg, acfg, dtype)
            for k in jax.random.split(jax.random.fold_in(key, 7), n_pre)]
    return tree


def init_client_bank(cfg: ModelConfig, acfg: AdapterConfig, n_clients: int, key,
                     dtype=jnp.float32):
    """Stack n_clients adapters along a leading client axis (one bank)."""
    return jax.vmap(lambda k: init_adapter(cfg, acfg, k, dtype))(
        jax.random.split(key, n_clients))


def adapter_shapes(cfg: ModelConfig, acfg: AdapterConfig):
    """Abstract (never-allocated) shape tree of one client's adapter."""
    return jax.eval_shape(
        lambda: init_adapter(cfg, acfg, jax.random.PRNGKey(0)))


def adapter_bytes(cfg: ModelConfig, acfg: AdapterConfig) -> tuple:
    """(param_count, param_bytes) of one client's adapter — what a
    fine-tuning job pins beyond the shared base (admission accounting:
    the AdamW moments add 2 × param_count × 4 bytes on top)."""
    import numpy as np
    leaves = jax.tree.leaves(adapter_shapes(cfg, acfg))
    n = sum(int(np.prod(x.shape)) for x in leaves)
    nbytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)
    return n, nbytes


# ---------------------------------------------------------------------------
# Application (used by the client LinearFns hook)
# ---------------------------------------------------------------------------

def apply_adapter(y, x, path, ad_slice, acfg: AdapterConfig, cfg: ModelConfig):
    """Post-hook: given base output y = base(x), fold in the adapter."""
    if ad_slice is None:
        return y
    key = _RWKV_ALIAS.get(path, path) if cfg.arch == RWKV else path
    leaf = ad_slice.get(key) if isinstance(ad_slice, dict) else None
    if leaf is None:
        return y
    if acfg.method == "lora":
        scale = acfg.alpha / acfg.rank
        delta = jnp.einsum("...r,ro->...o", jnp.einsum("...i,ir->...r", x, leaf["A"].astype(x.dtype)),
                           leaf["B"].astype(x.dtype))
        return y + scale * delta
    if acfg.method == "ia3":
        if key == "down":
            # scale applied to the FFN intermediate => recompute is avoided by
            # scaling the *output-equivalent*: down(l * x) == ... requires
            # pre-scaling; handled via pre_hook below. Post-hook is identity.
            return y
        return y * leaf["scale"].astype(y.dtype)
    return y


def pre_scale(x, path, ad_slice, acfg: AdapterConfig, cfg: ModelConfig):
    """Pre-hook: IA3 scales the input of the 'down' projection."""
    if ad_slice is None or acfg.method != "ia3":
        return x
    leaf = ad_slice.get(path) if isinstance(ad_slice, dict) else None
    if leaf is not None and path == "down":
        return x * leaf["scale"].astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Compacted-batch application (the serving engine's active-slot decode)
# ---------------------------------------------------------------------------
#
# In the compacted decode tick every row of the batch may belong to a
# different client, so the per-layer adapter slice arrives CLIENT-STACKED
# (leaves [C, ...]) together with a row -> client map. LoRA deltas go
# through the SGMV kernel (Punica/S-LoRA's op; ``block_t=1`` = one adapter
# per row) — byte-identical to the per-client vmapped ``apply_adapter``
# path, which is the compact-vs-masked exactness contract. IA3 / prefix
# leaves are gathered per row (elementwise, trivially identical).
#
# MIXED-method batches (several serving banks in one engine) additionally
# pass ``rows_mask`` [n_rows] bool — True where the row belongs to THIS
# bank. Non-member rows must come out bitwise untouched, so every
# application is gated with ``jnp.where`` (a select preserves bits exactly,
# unlike adding a zero delta, which would flip -0.0 to +0.0) and gather
# indices are clamped into the bank's range (a non-member row's local id
# belongs to ANOTHER bank and may be out of range here).


def _row_shape(mask, ref):
    """Broadcast a [n_rows] mask along the remaining axes of ``ref``."""
    return mask.reshape((ref.shape[0],) + (1,) * (ref.ndim - 1))


def apply_adapter_rows(y, x, path, ad_slice, acfg: AdapterConfig,
                       cfg: ModelConfig, rows_client, rows_mask=None):
    """Post-hook for a compacted [n_rows, 1, d] batch. ``ad_slice`` leaves
    are client-stacked [C, ...]; ``rows_client`` [n_rows] int32 (indices
    into THIS bank's client axis); ``rows_mask`` [n_rows] bool marks the
    rows this bank owns (None = all rows, the single-bank fast path)."""
    if ad_slice is None:
        return y
    leaf = ad_slice.get(path) if isinstance(ad_slice, dict) else None
    if leaf is None:
        return y
    if acfg.method == "lora":
        from repro.kernels.sgmv import sgmv   # deferred: kernels import nothing back
        n = x.shape[0]
        # decode rows are [n, 1, d] (block_t=1); compacted PREFILL rows are
        # [n, S, d] — one S-token block per row, all owned by that row's
        # adapter, so block_t=S keeps one sgmv call per dispatch
        S = x.shape[1] if x.ndim == 3 else 1
        ids = rows_client if rows_mask is None else \
            jnp.where(rows_mask, rows_client, -1)    # dead blocks emit zeros
        delta = sgmv(x.reshape(n * S, x.shape[-1]), leaf["A"].astype(x.dtype),
                     leaf["B"].astype(x.dtype), ids, block_t=S,
                     scale=acfg.alpha / acfg.rank)
        out = y + delta.reshape(y.shape)
        return out if rows_mask is None else jnp.where(_row_shape(rows_mask, y),
                                                       out, y)
    if acfg.method == "ia3":
        if path == "down":
            return y                          # pre-scaled (see below)
        C = leaf["scale"].shape[0]
        ids = rows_client if rows_mask is None else jnp.clip(rows_client, 0, C - 1)
        s = leaf["scale"][ids]                # [n, dout]
        out = y * s.reshape((y.shape[0],) + (1,) * (y.ndim - 2) + (-1,)).astype(y.dtype)
        return out if rows_mask is None else jnp.where(_row_shape(rows_mask, y),
                                                       out, y)
    return y


def pre_scale_rows(x, path, ad_slice, acfg: AdapterConfig, cfg: ModelConfig,
                   rows_client, rows_mask=None):
    """Compacted-batch pre-hook: IA3 'down' input scaling, per row (gated
    by ``rows_mask`` in mixed-method batches)."""
    if ad_slice is None or acfg.method != "ia3":
        return x
    leaf = ad_slice.get(path) if isinstance(ad_slice, dict) else None
    if leaf is not None and path == "down":
        C = leaf["scale"].shape[0]
        ids = rows_client if rows_mask is None else jnp.clip(rows_client, 0, C - 1)
        s = leaf["scale"][ids]
        out = x * s.reshape((x.shape[0],) + (1,) * (x.ndim - 2) + (-1,)).astype(x.dtype)
        return out if rows_mask is None else jnp.where(_row_shape(rows_mask, x),
                                                       out, x)
    return x


def compact_adapter_bank(bank, rows_client):
    """Re-lay a client-stacked adapter bank for a compacted row batch.

    Stacked layer containers (leaves [C, L, ...]) become layer-major
    [L, C, ...] so the model's layer scan slices a [C, ...] client-stacked
    slice per layer (applied per row by ``apply_adapter_rows``). Prefix
    leaves are instead gathered per ROW ([n, L?, n_prefix, K, hd]) because
    prefix-tuning flows through model code (``_prefix_attend``), not the
    linear hook. List containers (pre_layers) hold per-layer dicts with
    [C, ...] leaves and pass through (prefix gathered likewise)."""
    if bank is None:
        return None

    def fix_stacked(container):
        out = {}
        for path, leaf in container.items():
            if path in ("prefix_k", "prefix_v"):
                out[path] = jnp.swapaxes(leaf[rows_client], 0, 1)
            else:
                out[path] = jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), leaf)
        return out

    def fix_flat(container):
        return {path: (leaf[rows_client] if path in ("prefix_k", "prefix_v")
                       else leaf)
                for path, leaf in container.items()}

    return {name: ([fix_flat(d) for d in sub] if isinstance(sub, list)
                   else fix_stacked(sub))
            for name, sub in bank.items()}


# ---------------------------------------------------------------------------
# Mixed-method batches (several serving banks in one compacted tick)
# ---------------------------------------------------------------------------

def _mixed_stacked(container, rows, mask):
    """One bank's stacked layer container for a mixed row batch: param
    leaves go layer-major [L, C, ...] (per-row application happens in the
    hook, gated by the method mask); prefix leaves are gathered per ROW with
    clamped local ids and ship the membership mask alongside
    (``prefix_rows``) so the model can gate the prefix-attention add."""
    res = {}
    for path, leaf in container.items():
        if path in ("prefix_k", "prefix_v"):
            C = leaf.shape[0]
            g = leaf[jnp.clip(rows, 0, C - 1)]            # [R, L, P, K, hd]
            res[path] = jnp.swapaxes(g, 0, 1)             # [L, R, P, K, hd]
        else:
            res[path] = jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), leaf)
    if "prefix_k" in res:
        L, R = res["prefix_k"].shape[:2]
        res["prefix_rows"] = jnp.broadcast_to(mask[None], (L, R))
    return res


def _mixed_flat(container, rows, mask):
    """Per-layer (unstacked) variant of ``_mixed_stacked`` for list
    containers (pre_layers)."""
    res = {}
    for path, leaf in container.items():
        if path in ("prefix_k", "prefix_v"):
            C = leaf.shape[0]
            res[path] = leaf[jnp.clip(rows, 0, C - 1)]
        else:
            res[path] = leaf
    if "prefix_k" in res:
        res["prefix_rows"] = mask
    return res


def compact_mixed_bank(banks, rows_local, rows_method):
    """Re-lay SEVERAL method banks for one compacted mixed-method row batch.

    ``banks[m]`` is method m's client-stacked adapter tree (a None entry
    is tolerated defensively and contributes nothing — the engine requires
    every registered bank to carry a tree); ``rows_local`` [n_rows] maps
    each row to its index
    WITHIN its own bank and ``rows_method`` [n_rows] names that bank. The
    result nests each bank's re-laid containers under an ``m<id>`` key —
    ``virtlayer.make_mixed_ctx`` applies bank m's hook to exactly the rows
    whose method id is m, and the model's prefix entries carry their own
    row masks — so every row computes bitwise what its solo single-method
    run computes, whatever its neighbours' methods are."""
    out = {}
    for m, bank in enumerate(banks):
        if bank is None:
            continue
        key = f"m{m}"
        mask = rows_method == m
        for name, sub in bank.items():
            if isinstance(sub, list):
                tgt = out.setdefault(name, [{} for _ in sub])
                for i, d in enumerate(sub):
                    tgt[i][key] = _mixed_flat(d, rows_local, mask)
            else:
                out.setdefault(name, {})[key] = _mixed_stacked(
                    sub, rows_local, mask)
    return out
