"""Base executor: the shared frozen-layer service (paper §3.2).

In-graph, the base executor is simply the set of frozen matmuls that every
client's trace routes through (see core.virtlayer) — XLA compiles the merged
token batch into single MXU matmuls. This module provides the *host-level*
executor used by the opportunistic-batching engine (core.scheduler,
serving.engine): it owns the frozen per-layer weights, accepts per-client
layer requests as ragged token segments, packs them into a token-budget
buffer (core.packing) and executes one fused matmul per (layer, path).

Shape bucketing keeps re-compilation bounded: packed buffers are padded to
the next power-of-two token budget.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.frozen_linear import frozen_dense


def _bucket(n: int, floor: int = 64) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


class BaseExecutor:
    """Holds frozen base weights; serves per-layer batched execution."""

    def __init__(self, layer_weights: Dict[Tuple[int, str], Tuple[jnp.ndarray, jnp.ndarray]]):
        """layer_weights: (layer_idx, path) -> (W [din,dout], b or None)."""
        self.weights = layer_weights
        self._stats = {"calls": 0, "tokens": 0, "batched_requests": 0}

        @functools.partial(jax.jit, static_argnums=(3,))
        def _run(buf, w, b, has_b):
            return frozen_dense(buf, w, b if has_b else None)

        self._run = _run

    def run_layer(self, layer: int, path: str,
                  segments: List[np.ndarray]) -> List[np.ndarray]:
        """Execute one base layer for a batch of client segments.

        segments: list of [Ti, din] arrays (ragged — no padding, paper §3.7).
        Returns the per-client outputs, split back out.
        """
        w, b = self.weights[(layer, path)]
        lens = [s.shape[0] for s in segments]
        total = sum(lens)
        budget = _bucket(total)
        din = w.shape[0]
        S_max = max(lens)
        stacked = np.zeros((len(segments), S_max, din), segments[0].dtype)
        for i, s in enumerate(segments):
            stacked[i, :lens[i]] = s
        packed = packing.pack(jnp.asarray(stacked), jnp.asarray(lens, jnp.int32), budget)
        out = self._run(packed.buf, w, b, b is not None)
        unpacked = packing.unpack(packed, out, S_max)
        unpacked = np.asarray(unpacked)
        self._stats["calls"] += 1
        self._stats["tokens"] += total
        self._stats["batched_requests"] += len(segments)
        return [unpacked[i, :lens[i]] for i in range(len(segments))]

    @property
    def stats(self):
        s = dict(self._stats)
        s["avg_batch"] = s["batched_requests"] / max(1, s["calls"])
        return s


def calibrate_layer_cost(din: int = 512, dout: int = 512, reps: int = 5):
    """Measure (fixed overhead, per-token cost) of a packed base-layer call on
    this host — used to parameterize the scheduler simulation."""
    w = jnp.zeros((din, dout), jnp.float32)
    f = jax.jit(lambda x: frozen_dense(x, w, None))
    costs = {}
    for n in (64, 1024):
        x = jnp.ones((n, din), jnp.float32)
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            f(x).block_until_ready()
        costs[n] = (time.perf_counter() - t0) / reps
    per_token = (costs[1024] - costs[64]) / (1024 - 64)
    overhead = max(1e-6, costs[64] - 64 * per_token)
    return overhead, max(per_token, 1e-9)
