"""Activation privacy for multi-tenancy (paper §3.8).

Threat model: the base-executor service provider observes the activations a
client ships to frozen base layers and could extract the client's adapter
parameters (model-extraction: with LoRA, (C - B)/A in Fig 8). Defense: the
client adds noise ``n`` to the activations; the *noise effect*
``n_eff = n @ W`` is computed ONCE per noise value via a bias-free executor
flow, and subtracted from every noisy output:

    y = ((x + n) @ W + b) - (n @ W)  ==  x @ W + b      (exact, linearity)

Non-linear base layers cannot be protected this way — the paper restricts the
mechanism to nn.Linear/Conv1D, which is what the base executor serves.

Multiple pre-generated noise vectors are rotated across layers/iterations
(key-indexed) so the executor cannot align observed noisy activations with a
single noise value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_noise(key, paths_dims, n_variants: int = 2, scale: float = 1.0,
               dtype=jnp.float32):
    """Per-path noise bank: path -> [n_variants, din]."""
    noise = {}
    for i, (path, (din, _dout)) in enumerate(sorted(paths_dims.items())):
        noise[path] = (jax.random.normal(jax.random.fold_in(key, i),
                                         (n_variants, din), jnp.float32) * scale).astype(dtype)
    return noise


def noise_effect(noise, weights):
    """Pre-compute n_eff = n @ W for every (path, variant).

    ``weights``: path -> W [din, dout] (or stacked [L, din, dout]; the leading
    layer axis broadcasts through the einsum). This is the bias-free executor
    flow of §3.8: the base executor computes Conv1D(n, W) with b nulled.
    """
    eff = {}
    for path, n in noise.items():
        w = weights[path]
        if w.ndim == 2:
            eff[path] = jnp.einsum("vi,io->vo", n.astype(w.dtype), w)
        else:  # [L, din, dout] stacked base layers
            eff[path] = jnp.einsum("vi,lio->lvo", n.astype(w.dtype), w)
    return eff


def private_dense(base_dense, x, w, b, path, n, n_eff):
    """One private base-layer invocation.

    n [din], n_eff [dout] — the variant has been selected by the caller.
    ``base_dense`` is the (possibly memory-optimized) frozen linear.
    """
    y_noisy = base_dense(x + n.astype(x.dtype), w, b)
    return y_noisy - n_eff.astype(y_noisy.dtype)


def select_variant(noise_or_eff, path, variant):
    bank = noise_or_eff[path]
    return jax.lax.dynamic_index_in_dim(bank, variant, axis=bank.ndim - 2, keepdims=False)
