"""VirtLayer: the client-side splice that redirects frozen base layers
(paper §3.2, Figure 4) — JAX form.

In the paper, VirtLayer is an nn.Module stand-in that ships activations to
the base executor over IPC/NCCL. In SPMD JAX the "redirection" is a
compile-time graph splice: ``make_client_ctx`` builds a ``LinCtx`` whose
LinearFns (1) run the frozen base matmul with the memory-optimized backward
(§3.6), (2) apply the client's PEFT adapter for targeted paths, and (3)
optionally wrap the call in the §3.8 noise-privacy protocol. Model code is
untouched (paper design goal 3) — the hook threads through every
architecture in ``repro.models``.

The per-layer adapter/privacy state rides the layer scan as a sliced pytree;
``for_layer`` binds one layer's slice into the hook.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import AdapterConfig, ModelConfig
from repro.core import adapters as adapters_lib
from repro.core import privacy as privacy_lib
from repro.core.frozen_linear import frozen_dense, frozen_expert
from repro.models.blocks import LinearFns
from repro.models.transformer import LinCtx

PRIV_KEY = "_priv"


def _plain_dense(x, w, b, path):
    y = jnp.einsum("...i,io->...o", x, w)
    return y + b if b is not None else y


def _plain_expert(x, w, path):
    return jnp.einsum("eci,eio->eco", x, w)


def make_client_ctx(cfg: ModelConfig, acfg: Optional[AdapterConfig] = None, *,
                    memory_optimized: bool = True,
                    privacy_noise=None, privacy_variant=0) -> LinCtx:
    """Build the Symbiosis client context.

    memory_optimized=False emulates the torch-style baseline in which base
    activations are saved for the backward pass (used for the Fig 9/10
    memory comparison).
    privacy_noise: path -> [V, din] noise bank (client secret). The matching
    per-layer noise effects must have been attached to the adapter tree via
    ``attach_privacy``.
    """
    base_dense = frozen_dense if memory_optimized else _plain_dense_nohook
    base_expert = frozen_expert if memory_optimized else _plain_expert_nohook

    def for_layer(ad_slice) -> LinearFns:
        priv_eff = None
        if isinstance(ad_slice, dict) and PRIV_KEY in ad_slice:
            priv_eff = ad_slice[PRIV_KEY]

        def dense(x, w, b, path):
            if acfg is not None:
                x = adapters_lib.pre_scale(x, path, ad_slice, acfg, cfg)
            if priv_eff is not None and path in priv_eff:
                n = privacy_lib.select_variant(privacy_noise, path, privacy_variant)
                eff = jax.lax.stop_gradient(priv_eff[path])[privacy_variant]
                y = privacy_lib.private_dense(base_dense, x, w, b, path, n, eff)
            else:
                y = base_dense(x, w, b)
            if acfg is not None:
                y = adapters_lib.apply_adapter(y, x, path, ad_slice, acfg, cfg)
            return y

        def expert(x, w, path):
            return base_expert(x, w)

        return LinearFns(dense=dense, expert=expert)

    top = LinearFns(dense=lambda x, w, b, path: base_dense(x, w, b),
                    expert=lambda x, w, path: base_expert(x, w))
    return LinCtx(top=top, for_layer=for_layer)


def make_compact_ctx(cfg: ModelConfig, acfg: Optional[AdapterConfig],
                     rows_client, *, memory_optimized: bool = True) -> LinCtx:
    """Client context for a COMPACTED multi-client batch (the serving
    engine's active-slot decode tick).

    Where ``make_client_ctx`` binds ONE client's adapter slice (the bank is
    vmapped around it), this context serves a batch whose rows belong to
    different clients: ``rows_client`` [n_rows] maps each row to its client
    and the per-layer adapter slices arrive client-stacked (leaves
    [C, ...], see ``adapters.compact_adapter_bank``). LoRA deltas are
    applied per row through the SGMV kernel — byte-identical to the
    per-client vmapped path, which is what makes the compacted decode's
    outputs byte-identical to the masked bank-wide decode."""
    base_dense = frozen_dense if memory_optimized else _plain_dense_nohook
    base_expert = frozen_expert if memory_optimized else _plain_expert_nohook

    def for_layer(ad_slice) -> LinearFns:
        def dense(x, w, b, path):
            if acfg is not None:
                x = adapters_lib.pre_scale_rows(x, path, ad_slice, acfg, cfg,
                                                rows_client)
            y = base_dense(x, w, b)
            if acfg is not None:
                y = adapters_lib.apply_adapter_rows(y, x, path, ad_slice,
                                                    acfg, cfg, rows_client)
            return y

        def expert(x, w, path):
            return base_expert(x, w)

        return LinearFns(dense=dense, expert=expert)

    top = LinearFns(dense=lambda x, w, b, path: base_dense(x, w, b),
                    expert=lambda x, w, path: base_expert(x, w))
    return LinCtx(top=top, for_layer=for_layer)


def make_mixed_ctx(cfg: ModelConfig, acfgs, rows_local, rows_method, *,
                   memory_optimized: bool = True) -> LinCtx:
    """Client context for a MIXED-METHOD compacted batch: the serving
    engine's heterogeneous banks (LoRA + IA3 + prefix concurrently) in one
    decode tick.

    ``acfgs`` is the engine's bank tuple (method id = position; a None
    entry is tolerated defensively and applies nothing), ``rows_local``
    [n_rows] each row's client index WITHIN its bank, ``rows_method``
    [n_rows] its bank id. Per-layer adapter
    slices arrive as ``{"m<id>": <bank slice>}`` (see
    ``adapters.compact_mixed_bank``). Every bank's hook runs over the whole
    batch but is GATED per row: LoRA rows keep the SGMV path (non-member
    rows get dead adapter ids, so the kernel emits zeros for them), IA3
    scales are gathered with clamped ids, and every application is merged
    through ``jnp.where`` on the membership mask — a select preserves the
    non-member rows' bits exactly, which is what keeps each row
    byte-identical to its solo single-method run."""
    base_dense = frozen_dense if memory_optimized else _plain_dense_nohook
    base_expert = frozen_expert if memory_optimized else _plain_expert_nohook
    live = [(m, acfg) for m, acfg in enumerate(acfgs) if acfg is not None]
    masks = {m: rows_method == m for m, _ in live}

    def for_layer(ad_slice) -> LinearFns:
        def sub(m):
            return ad_slice.get(f"m{m}") if isinstance(ad_slice, dict) else None

        def dense(x, w, b, path):
            for m, acfg in live:
                x = adapters_lib.pre_scale_rows(x, path, sub(m), acfg, cfg,
                                                rows_local, rows_mask=masks[m])
            y = base_dense(x, w, b)
            for m, acfg in live:
                y = adapters_lib.apply_adapter_rows(y, x, path, sub(m), acfg,
                                                    cfg, rows_local,
                                                    rows_mask=masks[m])
            return y

        def expert(x, w, path):
            return base_expert(x, w)

        return LinearFns(dense=dense, expert=expert)

    top = LinearFns(dense=lambda x, w, b, path: base_dense(x, w, b),
                    expert=lambda x, w, path: base_expert(x, w))
    return LinCtx(top=top, for_layer=for_layer)


def _plain_dense_nohook(x, w, b=None):
    y = jnp.einsum("...i,io->...o", x, w)
    return y + b if b is not None else y


def _plain_expert_nohook(x, w):
    return jnp.einsum("eci,eio->eco", x, w)


def attach_privacy(adapter_tree, cfg: ModelConfig, base_params, noise,
                   container: str = "layers"):
    """Insert per-layer noise effects (n_eff = n @ W_layer) into the adapter
    tree so they ride the layer scan next to the adapter weights.

    Supports the dense/moe/vlm container layout ('layers'; leaves are stacked
    [L, din, dout]). Returns a new adapter tree with `_priv` per layer.
    """
    attn = base_params[container]["attn"]
    weights = {"q": attn["wq"], "k": attn["wk"], "v": attn["wv"], "o": attn["wo"]}
    if "mlp" in base_params[container]:
        mlp = base_params[container]["mlp"]
        if "gate" in mlp:
            weights.update(gate=mlp["gate"], up=mlp["up"], down=mlp["down"])
    eff = privacy_lib.noise_effect(noise, {p: w for p, w in weights.items() if p in noise})
    out = dict(adapter_tree) if adapter_tree else {}
    layers = dict(out.get(container) or {})
    layers[PRIV_KEY] = eff            # each leaf [L, V, dout] -> sliced per layer
    out[container] = layers
    return out
