"""Memory-optimized frozen base linears (paper §3.6).

The paper's insight: for frozen linear/Conv1D layers the gradient of the
output w.r.t. the input is the parameters themselves, so the base executor
need not store input/output activations for fine-tuning requests — during
the backward pass it computes ``dx = dy @ Wᵀ`` from the (resident) weights.
This (a) makes the base-executor memory footprint constant in the number of
clients (Fig 9/10) and (b) breaks the forward/backward batch lockstep (§3.6).

JAX's partial evaluation already avoids saving ``x`` when ``W`` is not
differentiated, but that behaviour is implicit and easily lost (e.g. if a
caller differentiates w.r.t. base params for a baseline comparison). These
``custom_vjp`` wrappers make the guarantee *structural*: the VJP residual is
the weight (already resident — zero extra memory), never the activations.

``tests/test_frozen_linear.py`` asserts the residual set of a grad-traced
call contains no activation-shaped tensors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def _frozen_dense_nobias(x, w):
    return jnp.einsum("...i,io->...o", x, w)


def _fdn_fwd(x, w):
    # Residual: ONLY the weight — never the activations (paper §3.6).
    return _frozen_dense_nobias(x, w), (w,)


def _fdn_bwd(res, g):
    (w,) = res
    dx = jnp.einsum("...o,io->...i", g, w)
    # Zero cotangent for the frozen weight: XLA DCEs it (never consumed).
    return dx, jnp.zeros_like(w)


_frozen_dense_nobias.defvjp(_fdn_fwd, _fdn_bwd)


@jax.custom_vjp
def _frozen_dense_bias(x, w, b):
    return jnp.einsum("...i,io->...o", x, w) + b


def _fdb_fwd(x, w, b):
    return _frozen_dense_bias(x, w, b), (w, b)


def _fdb_bwd(res, g):
    w, b = res
    return (jnp.einsum("...o,io->...i", g, w), jnp.zeros_like(w), jnp.zeros_like(b))


_frozen_dense_bias.defvjp(_fdb_fwd, _fdb_bwd)


def frozen_dense(x, w, b=None):
    """Frozen base linear with the memory-optimized backward (paper §3.6)."""
    if b is None:
        return _frozen_dense_nobias(x, w)
    return _frozen_dense_bias(x, w, b)


@jax.custom_vjp
def frozen_expert(x, w):
    """x [E, C, din] @ w [E, din, dout] (expert-parallel frozen base)."""
    return jnp.einsum("eci,eio->eco", x, w)


def _fe_fwd(x, w):
    return frozen_expert(x, w), (w,)


def _fe_bwd(res, g):
    (w,) = res
    return jnp.einsum("eco,eio->eci", g, w), jnp.zeros_like(w)


frozen_expert.defvjp(_fe_fwd, _fe_bwd)
