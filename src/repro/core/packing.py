"""Token-budget ragged packing (paper §3.7, opportunistic batching).

The paper flattens all ``batch×seq`` inputs from different clients into a
1-D token stream for nn.Linear/Conv1D base layers, avoiding padding ("the
position of a token does not matter"). The TPU/static-shape analogue is a
fixed-capacity packed buffer: client segments of different lengths are
scattered into a ``[budget, d]`` buffer with a live-token count; base linears
run over the buffer once (compute ∝ budget, not n_clients × max_len).

All functions are jit-compatible (static budget, dynamic lengths).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Packed(NamedTuple):
    buf: jnp.ndarray       # [budget, d]
    seg_ids: jnp.ndarray   # [budget] int32, client id per slot (-1 = dead)
    slot_pos: jnp.ndarray  # [budget] int32, position within the segment
    lengths: jnp.ndarray   # [C] int32
    offsets: jnp.ndarray   # [C] int32 (exclusive cumsum of lengths)

    @property
    def live(self):
        return self.seg_ids >= 0


def pack(inputs: jnp.ndarray, lengths: jnp.ndarray, budget: int) -> Packed:
    """inputs [C, S_max, d] (padded per client), lengths [C] -> Packed.

    Tokens beyond the budget are dropped (the scheduler sizes the budget so
    this doesn't happen in practice; tests cover the overflow path).
    """
    C, S_max, d = inputs.shape
    offsets = jnp.cumsum(lengths) - lengths                        # [C]
    pos = jnp.arange(S_max)[None, :]                               # [1,S]
    valid = pos < lengths[:, None]                                 # [C,S]
    dest = jnp.where(valid, offsets[:, None] + pos, budget)        # OOB -> dropped
    flat_dest = dest.reshape(-1)
    buf = jnp.zeros((budget, d), inputs.dtype).at[flat_dest].set(
        inputs.reshape(C * S_max, d), mode="drop")
    seg = jnp.full((budget,), -1, jnp.int32).at[flat_dest].set(
        jnp.repeat(jnp.arange(C, dtype=jnp.int32), S_max), mode="drop")
    slot = jnp.zeros((budget,), jnp.int32).at[flat_dest].set(
        jnp.tile(jnp.arange(S_max, dtype=jnp.int32), C), mode="drop")
    return Packed(buf=buf, seg_ids=seg, slot_pos=slot, lengths=lengths, offsets=offsets)


def unpack(packed: Packed, buf: jnp.ndarray, S_max: int) -> jnp.ndarray:
    """Gather a processed [budget, d'] buffer back to [C, S_max, d']."""
    C = packed.lengths.shape[0]
    pos = jnp.arange(S_max)[None, :]
    valid = pos < packed.lengths[:, None]
    src = jnp.where(valid, packed.offsets[:, None] + pos, buf.shape[0])  # OOB
    out = buf.at[src.reshape(-1)].get(mode="fill", fill_value=0)
    return out.reshape(C, S_max, buf.shape[-1])


def packed_positions(packed: Packed) -> jnp.ndarray:
    """Per-slot sequence positions (for RoPE over packed token streams)."""
    return packed.slot_pos


def live_token_count(packed: Packed) -> jnp.ndarray:
    return packed.lengths.sum()
