"""Placement router: the service provider's admission + placement logic
(paper §3.3/§3.4).

Given a request (context length, batch, latency sensitivity) and the fleet
(accelerator slots with free HBM, CPU hosts), choose the §3.4 placement:

  * ``gpu``          — client co-located with the base executor (fastest,
                       needs cache + runtime state to fit free HBM)
  * ``gpu_offload``  — cache on host, compute on accelerator (mid contexts)
  * ``hetero``       — client on CPU (huge contexts; constant PCIe traffic)

and an accelerator slot, using the analytic cost model in
``serving.kvcache``. This is the piece the paper assigns to the provider:
"they only need to provision the base executor resources ... the per-token
resource requirement remains constant irrespective of the client-side
configurations" — client placement is decided per request here.

``serving.engine`` uses this as admission control: a request is admitted
only when ``route()`` finds (and commits) a placement; the engine calls
``release()`` when the request's slots free, so queued requests take the
capacity the moment it returns (continuous-batching backpressure).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.config import ModelConfig
from repro.common.hardware import V5E, Chip
from repro.serving.kvcache import cache_bytes, decode_token_cost


@dataclasses.dataclass
class Slot:
    """One accelerator's client-side capacity (base executor excluded)."""
    slot_id: int
    free_hbm: float
    chip: Chip = V5E

    def fits(self, nbytes: float) -> bool:
        return nbytes <= self.free_hbm


@dataclasses.dataclass
class Placement:
    slot_id: Optional[int]        # None -> CPU host
    mode: str                     # gpu | gpu_offload | hetero | train
    est_s_per_token: float
    cache_bytes: int


class PlacementRouter:
    """Routes client sessions onto a fleet of accelerator slots + CPU hosts."""

    def __init__(self, cfg: ModelConfig, slots: List[Slot],
                 *, host_free_bytes: float = 400e9):
        self.cfg = cfg
        self.slots = {s.slot_id: s for s in slots}
        self.host_free = host_free_bytes
        # conservation ledger (docs/robustness.md): initial capacities plus
        # the identity list of outstanding placements. commit/release keep
        # it in sync; conservation_errors() recomputes free capacity from
        # scratch and reports any drift (a leaked or double-released charge).
        self._initial = {s.slot_id: s.free_hbm for s in slots}
        self._host_initial = host_free_bytes
        self._committed: List[Placement] = []

    def route(self, context_len: int, batch: int = 1,
              *, latency_sensitive: bool = True, alloc_tokens: int = 0,
              quant: bool = False) -> Placement:
        """Pick the cheapest placement that fits; latency-sensitive requests
        refuse the CPU unless nothing else fits.

        ``context_len`` drives the latency estimates (tokens actually
        attended); ``alloc_tokens`` drives the HBM charge (tokens the cache
        layout actually pins — a dense engine passes its ``max_seq`` slot
        depth, a paged engine its context already rounded up to whole
        pages). 0 falls back to ``context_len``. ``quant`` prices the int8
        KV layout."""
        # cache_bytes already multiplies by `batch` — `need` is the whole
        # session's footprint, and is what commit()/release() account with.
        need = cache_bytes(self.cfg, alloc_tokens or context_len, batch,
                           quant=quant)
        candidates = []

        gpu = decode_token_cost(self.cfg, context_len, placement="gpu")
        off = decode_token_cost(self.cfg, context_len, placement="gpu_offload")
        het = decode_token_cost(self.cfg, context_len, placement="hetero")

        for s in self.slots.values():
            if gpu.total != float("inf") and s.fits(need):
                candidates.append(Placement(s.slot_id, "gpu",
                                            gpu.total * batch, need))
            # offload only needs working-set HBM (~1 layer of cache)
            if self.host_free >= need and s.fits(need / self.cfg.n_layers):
                candidates.append(Placement(s.slot_id, "gpu_offload",
                                            off.total * batch, need))
        if self.host_free >= need:
            pen = 1.0 if not latency_sensitive else 1.5   # soft CPU aversion
            candidates.append(Placement(None, "hetero",
                                        het.total * batch * pen, need))
        if not candidates:
            raise RuntimeError(
                f"no placement fits {need/1e9:.1f} GB cache "
                f"(context {context_len} × batch {batch})")
        best = min(candidates, key=lambda p: p.est_s_per_token)
        # undo the latency penalty in the reported estimate BEFORE commit:
        # the ledger tracks placements by identity, so the object we commit
        # must be the object the caller later release()s
        if best.mode == "hetero" and latency_sensitive:
            best = dataclasses.replace(best,
                                       est_s_per_token=best.est_s_per_token / 1.5)
        self.commit(best)
        return best

    def route_train(self, nbytes: float, *,
                    latency_sensitive: bool = False) -> Placement:
        """Place one FINE-TUNING job's client-side state: adapter params +
        AdamW moments + activation working set (``training.job_hbm_bytes``).
        Training state is touched every step for the job's whole lifetime,
        so only co-located (accelerator-resident) placements are considered
        — there is no offload tier for optimizer state. Commits the
        capacity; the FinetuneEngine releases it when the job retires.
        ``latency_sensitive`` is accepted for signature symmetry with
        ``route`` (training placements are always co-located)."""
        del latency_sensitive
        for s in self.slots.values():
            if s.fits(nbytes):
                p = Placement(s.slot_id, "train", 0.0, int(nbytes))
                self.commit(p)
                return p
        raise RuntimeError(
            f"no accelerator slot fits {nbytes / 1e9:.2f} GB of training "
            f"state (adapter + optimizer + activations)")

    def route_bank(self, nbytes: float) -> Placement:
        """Charge one SERVING bank's resident client-side weights: the
        client-stacked adapter trees a mixed-method engine keeps on the
        accelerator for its whole lifetime (per-bank HBM accounting of the
        engine's bank registry). Like training state there is no offload
        tier — adapters are read every decode tick. The engine releases the
        charge via ``ServingEngine.release_banks()``."""
        for s in self.slots.values():
            if s.fits(nbytes):
                p = Placement(s.slot_id, "bank", 0.0, int(nbytes))
                self.commit(p)
                return p
        raise RuntimeError(
            f"no accelerator slot fits {nbytes / 1e9:.3f} GB of serving-bank "
            f"adapter weights")

    def commit(self, p: Placement):
        if p.slot_id is not None and p.mode in ("gpu", "train", "bank"):
            self.slots[p.slot_id].free_hbm -= p.cache_bytes
        elif p.slot_id is not None:
            self.slots[p.slot_id].free_hbm -= p.cache_bytes / self.cfg.n_layers
            self.host_free -= p.cache_bytes
        else:
            self.host_free -= p.cache_bytes
        self._committed.append(p)

    def release(self, p: Placement):
        # identity scan, not list.remove: Placement is a value-comparing
        # dataclass, and two tenants can hold field-equal placements
        for i, q in enumerate(self._committed):
            if q is p:
                del self._committed[i]
                break
        else:
            raise RuntimeError(
                f"release of a placement that was never committed (or was "
                f"already released): {p}")
        if p.slot_id is not None and p.mode in ("gpu", "train", "bank"):
            self.slots[p.slot_id].free_hbm += p.cache_bytes
        elif p.slot_id is not None:
            self.slots[p.slot_id].free_hbm += p.cache_bytes / self.cfg.n_layers
            self.host_free += p.cache_bytes
        else:
            self.host_free += p.cache_bytes

    def utilization(self) -> dict:
        """Telemetry snapshot (docs/observability.md): live vs initial
        capacity per slot plus the outstanding-placement ledger. The
        engines fold this into ``router_*`` gauges at admit/retire when an
        ``Obs`` is attached; pure host reads, no device traffic."""
        return {
            "slots": {sid: {"free_hbm": s.free_hbm,
                            "initial_hbm": self._initial.get(sid, s.free_hbm)}
                      for sid, s in self.slots.items()},
            "host_free": self.host_free,
            "host_initial": self._host_initial,
            "placements": len(self._committed),
            "committed_bytes": sum(p.cache_bytes for p in self._committed),
        }

    def conservation_errors(self) -> List[str]:
        """Recompute every capacity from the initial snapshot minus the
        outstanding placements; any drift from the live counters means a
        charge leaked (admission failed after commit) or was double-
        released. Empty list == conserved."""
        errs = []
        want_slot = dict(self._initial)
        want_host = self._host_initial
        for p in self._committed:
            if p.slot_id is not None and p.mode in ("gpu", "train", "bank"):
                want_slot[p.slot_id] -= p.cache_bytes
            elif p.slot_id is not None:
                want_slot[p.slot_id] -= p.cache_bytes / self.cfg.n_layers
                want_host -= p.cache_bytes
            else:
                want_host -= p.cache_bytes
        for sid, s in self.slots.items():
            if sid not in want_slot:        # slot added after construction
                continue
            if abs(s.free_hbm - want_slot[sid]) > 1.0:   # bytes; fp slack
                errs.append(
                    f"slot {sid}: free_hbm {s.free_hbm:.0f} != ledger "
                    f"{want_slot[sid]:.0f} (leaked/double-released charge)")
        if abs(self.host_free - want_host) > 1.0:
            errs.append(f"host: free {self.host_free:.0f} != ledger "
                        f"{want_host:.0f}")
        return errs
