"""Content-keyed, refcounted shared-prefix page index.

Host-side companion to the engine's page allocator (docs/prefix_cache.md).
Prompt prefixes are hashed block-by-block with a *chained* digest -- each
block's key commits to the scope key, the parent block's digest, and the
block's tokens -- so a lookup match at block j implies the full token
prefix ``prompt[: (j+1) * blk]`` matches, not just that one block.

The index stores two kinds of entries:

* **full-block** entries: a published, read-only pool page holding ``blk``
  tokens of KV.  A cache hit maps the page into the new slot's block table
  and takes a reference; the page is recycled only when its refcount drops
  to zero.
* **partial-tail** entries: the publisher's last, partially-filled page
  (``r = (S - 1) % blk`` tokens).  Tails are never mapped shared -- a hit
  copies the page (copy-on-write) into a freshly popped exclusive page and
  resumes writing at token ``r``.  Because the copy happens at admission
  and the source page is itself either exclusive or ref-held by the
  publisher's slot, the tail entry does NOT hold a reference; it is
  invalidated when the owning slot retires.

Only the first ``S - 1`` prompt tokens are sharable: the admitted row must
prefill at least its final token to produce first-token logits, so the
last token's KV is always written by the new slot itself.

The index is deliberately dumb about *placement*: pages keep their global
pool ids, and the engine's conservation audit attributes each live shared
page to the client range it was popped from.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

__all__ = [
    "PrefixIndex",
    "PrefixHit",
    "chain_digests",
    "sharable_tokens",
]


def sharable_tokens(length: int, blk: int) -> tuple[int, int]:
    """Split a prompt of ``length`` tokens into (full_blocks, tail_tokens).

    Only ``length - 1`` tokens are sharable (the last token is always
    prefilled by the consumer), so a 2-block prompt that exactly fills its
    pages still publishes one full block plus a ``blk - 1``-token tail.
    """
    share = max(0, int(length) - 1)
    return share // blk, share % blk


def chain_digests(scope: bytes, tokens: np.ndarray, blk: int) -> list[bytes]:
    """Chained blake2b digest per full block, plus one tail digest.

    Returns ``f + (1 if r else 0)`` digests for ``f`` full sharable blocks
    and an ``r``-token tail (see :func:`sharable_tokens`).  Digest ``j``
    commits to ``scope || digest[j-1] || tokens[j*blk:(j+1)*blk]``.
    """
    toks = np.asarray(tokens, np.int32)
    f, r = sharable_tokens(toks.shape[0], blk)
    out: list[bytes] = []
    parent = b""
    for j in range(f):
        h = hashlib.blake2b(digest_size=16)
        h.update(scope)
        h.update(parent)
        h.update(toks[j * blk : (j + 1) * blk].tobytes())
        parent = h.digest()
        out.append(parent)
    if r:
        h = hashlib.blake2b(digest_size=16)
        h.update(scope)
        h.update(parent)
        h.update(toks[f * blk : f * blk + r].tobytes())
        out.append(h.digest())
    return out


@dataclasses.dataclass
class PrefixHit:
    """Result of a lookup: what an admission can reuse."""

    full_pages: list[int]      # published pages for matched full blocks
    full_digests: list[bytes]  # their digests (for taking refs)
    tail_page: Optional[int]   # page to CoW-copy, or None
    tail_tokens: int           # tokens already written in tail_page
    start: int                 # first token index the consumer must prefill

    @property
    def matched_blocks(self) -> int:
        return len(self.full_pages)


@dataclasses.dataclass
class _Entry:
    page: int
    refs: int          # 0 for tail entries (never ref-held)
    tail: int          # 0 => full block; >0 => tail token count
    owner: tuple       # (client, slot) that published the entry


class PrefixIndex:
    """Digest -> page map with refcounts.  All methods are host-side.

    Refcount protocol (mirrored by the engine's ``_slot_shared``):

    * ``publish`` registers a page at refs=1 held by the publishing slot.
    * ``ref`` bumps an entry when a hit maps its page into another slot.
    * ``deref`` drops one reference; at zero the entry is removed and the
      page id returned so the allocator can recycle it.
    * tail entries carry refs=0 and die with their publisher via
      ``drop_tail``.
    """

    def __init__(self) -> None:
        self._entries: dict[bytes, _Entry] = {}
        self._by_page: dict[int, bytes] = {}

    # -- introspection -------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def refs_of(self, digest: bytes) -> int:
        return self._entries[digest].refs

    def page_refs(self) -> dict[int, int]:
        """page id -> refcount for every ref-held (full-block) entry."""
        return {e.page: e.refs for e in self._entries.values() if not e.tail}

    def live_pages(self) -> set[int]:
        return {e.page for e in self._entries.values()}

    # -- lookup --------------------------------------------------------
    def lookup(self, scope: bytes, tokens: np.ndarray, blk: int) -> PrefixHit:
        """Longest-prefix match of ``tokens`` against published entries.

        Matching stops at the first missing digest.  A tail match is only
        reported when *every* full block matched and the tail entry's
        token count fits inside the sharable region of this prompt.
        """
        digests = chain_digests(scope, tokens, blk)
        f, r = sharable_tokens(np.asarray(tokens).shape[0], blk)
        pages: list[int] = []
        matched: list[bytes] = []
        for j in range(f):
            e = self._entries.get(digests[j])
            if e is None or e.tail:
                break
            pages.append(e.page)
            matched.append(digests[j])
        tail_page = None
        tail_tokens = 0
        if len(pages) == f and r:
            # our own tail digest only matches an identical r-token tail;
            # also accept a published tail SHORTER than ours by probing the
            # publisher-side digest for each candidate tail length.
            for cand in range(r, 0, -1):
                h = hashlib.blake2b(digest_size=16)
                h.update(scope)
                h.update(matched[-1] if matched else b"")
                h.update(np.asarray(tokens, np.int32)[f * blk : f * blk + cand]
                         .tobytes())
                e = self._entries.get(h.digest())
                if e is not None and e.tail == cand:
                    tail_page, tail_tokens = e.page, cand
                    break
        start = len(pages) * blk + tail_tokens
        return PrefixHit(pages, matched, tail_page, tail_tokens, start)

    # -- publish -------------------------------------------------------
    def publish(self, scope: bytes, tokens: np.ndarray, blk: int,
                pages: list[int], owner: tuple) -> list[int]:
        """Register a just-prefilled slot's prefix pages.

        ``pages`` is the slot's page list in block order.  Full sharable
        blocks become refs=1 entries (the publishing slot holds the ref);
        a non-empty tail becomes a refs=0 tail entry.  Duplicate digests
        (another slot published the same content first) are skipped.
        Returns the page ids that were published as ref-held full blocks
        -- the engine moves exactly those from its exclusive list to its
        shared list.
        """
        digests = chain_digests(scope, tokens, blk)
        f, r = sharable_tokens(np.asarray(tokens).shape[0], blk)
        took: list[int] = []
        for j in range(f):
            d = digests[j]
            if d in self._entries:
                continue
            page = pages[j]
            self._entries[d] = _Entry(page=page, refs=1, tail=0, owner=owner)
            self._by_page[page] = d
            took.append(page)
        if r and f < len(pages):
            d = digests[f]
            if d not in self._entries:
                page = pages[f]
                # a tail page stays exclusive to its owner; index it for
                # CoW lookups but never for shared mapping.
                if page not in self._by_page:
                    self._entries[d] = _Entry(page=page, refs=0, tail=r,
                                              owner=owner)
                    self._by_page[page] = d
        return took

    # -- refcounting ---------------------------------------------------
    def ref(self, digest: bytes) -> int:
        e = self._entries[digest]
        if e.tail:
            raise ValueError("tail entries are copy-on-write, never ref-held")
        e.refs += 1
        return e.page

    def deref(self, page: int) -> bool:
        """Drop one reference on the full-block entry holding ``page``.

        Returns True when the refcount hit zero and the entry was removed
        -- the caller recycles the page into the free pool.
        """
        d = self._by_page.get(page)
        if d is None:
            raise KeyError(f"page {page} is not a published prefix page")
        e = self._entries[d]
        if e.tail:
            raise ValueError(f"page {page} is a tail entry; use drop_tail")
        if e.refs <= 0:
            raise RuntimeError(f"double free of shared prefix page {page}")
        e.refs -= 1
        if e.refs == 0:
            del self._entries[d]
            del self._by_page[page]
            return True
        return False

    def drop_tail(self, owner: tuple) -> None:
        """Invalidate tail entries owned by a retiring slot."""
        dead = [d for d, e in self._entries.items()
                if e.tail and e.owner == tuple(owner)]
        for d in dead:
            del self._by_page[self._entries[d].page]
            del self._entries[d]

    # -- persistence ---------------------------------------------------
    def state(self) -> dict:
        return {
            d: (e.page, e.refs, e.tail, tuple(e.owner))
            for d, e in self._entries.items()
        }

    @classmethod
    def from_state(cls, state: dict) -> "PrefixIndex":
        idx = cls()
        for d, (page, refs, tail, owner) in state.items():
            idx._entries[d] = _Entry(page=int(page), refs=int(refs),
                                     tail=int(tail), owner=tuple(owner))
            idx._by_page[int(page)] = d
        return idx
