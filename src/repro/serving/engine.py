"""Continuous-batching multi-client serving engine (paper §3.7 / §4.4).

Drives real model execution for a bank of inference clients that share one
frozen base. The engine realizes the paper's opportunistic-batching claim —
"requests batched at the first layer need not batch at later layers" — as a
live system rather than an offline simulation:

Architecture
------------
* **Slots.** Each client owns ``max_batch_per_client`` sequence slots backed
  by its rows of the bank KV/state cache. A request occupies one slot per
  prompt row for its lifetime; slots free the moment their request finishes
  and are re-admitted from the queue on the next tick — not after the whole
  bank drains (mid-stream join/leave).
* **Bank registry (heterogeneous PEFT methods).** One engine may hold
  SEVERAL serving banks keyed by AdapterConfig — pass ``acfg`` /
  ``client_bank`` as matching sequences — mirroring
  ``training.FinetuneEngine``'s bank grouping: LoRA, IA3 and prefix
  clients (or same-method banks of different rank) served concurrently
  over one frozen base. Clients carry GLOBAL ids in bank concatenation
  order; caches, the page allocator and slot bookkeeping stay keyed by
  the global id (the KV layout is method-independent) while admission
  prefills through the client's own bank's jitted step and ONE compacted
  decode tick carries per-row method ids (see "per-row-method contract"
  below). Mixed banks require the paged layout + compacted decode; a
  router is charged each bank's resident adapter bytes
  (``PlacementRouter.route_bank`` / ``release_banks()``).
* **Per-row-method contract.** In a mixed compacted tick, LoRA rows keep
  the SGMV path (rows of other banks get dead adapter ids, so the kernel
  emits zeros for them), IA3 scales and prefix K/V are gathered per row
  with clamped bank-local ids, and EVERY application — including the
  prefix-attention add inside the model — is merged through a
  ``jnp.where`` on the row's membership mask: a select preserves
  non-member rows' bits exactly, which is what makes each client's stream
  in a mixed batch byte-identical to its solo single-method run
  (tests/test_mixed_serving.py).
* **KV layout.** ``ServeConfig.page_block = 0`` keeps dense fixed-depth
  (``max_seq``) cache rows per slot. ``page_block > 0`` switches to the
  PAGED layout: the device holds ONE global flat pool of
  ``page_block``-token pages per KV leaf; each client owns the page RANGE
  ``[c*P, (c+1)*P)`` (``pool_pages`` = P per client) and the engine runs a
  host-side page allocator — prompt pages are assigned at admission, one
  page is assigned as a slot's decode position crosses each block
  boundary, and a finished request's pages return to the pool for the next
  occupant. The device sees the allocator only through the ``block_tbl``
  cache leaf (global page ids, pushed before prefill/decode whenever it
  changed); attention reads pages in place through the table-aware
  ``kernels/decode_attn`` kernel. ``kv_quant=True`` stores int8 KV entries
  + per-head f32 scales and composes with paging. The paged layout tracks
  the dense one within float tolerance (the kernel's blocked online
  softmax re-associates reductions) with identical greedy streams.
* **Compute-proportional decode.** With the paged layout the engine
  defaults to the COMPACTED decode tick (``compact_decode``; the masked
  bank-wide step stays as the dense-layout path and the
  ``compact_decode=False`` ablation): the actively decoding
  (client, slot) rows are gathered across clients into a dense batch
  (bucketed to a few static sizes to bound recompiles), run through the
  model once — per-row LoRA via ``kernels/sgmv``, attention via the paged
  kernel — and scattered back under the row mask. FLOPs and HBM traffic
  scale with ACTIVE tokens, not provisioned slots; outputs are
  byte-identical to the masked step under every tick policy (the masked
  step lowers to the same flattened computation through the kernels'
  custom_vmap rules). Cache buffers are donated into the jitted steps, so
  a tick updates the bank cache in place instead of copying it.
* **Admission.** A per-engine FIFO queue. A request is admitted when (a) its
  client has enough free slots, (b) its context fits the cache depth,
  (c) under paging, the client pool has enough unreserved pages for the
  full context (reserved up front so a mid-flight sequence can never
  starve; physically assigned lazily as tokens are produced), and (d) the
  optional ``PlacementRouter`` finds it a §3.4 placement (capacity is
  released on finish). The router is charged for what the layout actually
  pins: the dense engine charges a full ``max_seq``-deep slot row, the
  paged engine only the context rounded up to whole pages — the admission
  headroom that motivates paging. Admission triggers the *masked
  single-client prefill* (``symbiosis.make_client_prefill``): one model
  execution for the admitted client, scattered into the bank cache under a
  slot mask — the seed engine instead ran a bank-wide prefill, paying C×
  base compute per admitted request.
* **Cross-client compacted prefill.** On paged attention engines, ALL of
  a tick's admissions — across clients and banks — gather into ONE
  jit-bucketed ragged batch (``symbiosis.make_compact_prefill``, the
  prefill analogue of the compacted decode tick): each row carries its own
  prompt right-padded to a shared suffix bucket, its true ``lengths``
  entry and per-row (client, adapter, bank) ids, so one model execution
  per TICK replaces one per client per tick. Byte-identical to sequential
  per-request admission — rows are independent (per-row positions, causal
  mask, last-token logit gather, length-bounded pool writes). Dense-layout
  attention engines keep the same-client masked ragged batch (the paged
  fold needs page pools); recurrent families and ``ragged_prefill=False``
  keep per-request calls.
* **Shared-prefix page reuse (docs/prefix_cache.md).** Prompt prefixes
  are content-hashed block by block into a refcounted host-side index
  (``serving.prefix_cache.PrefixIndex``): an admission whose prompt
  prefix was already prefilled under the SAME adapter maps the published
  read-only pages into its block table (refs++), CoW-copies a matched
  partial tail page, and prefills only its suffix — the compacted prefill
  attends to the mapped pages as external K/V lanes. Retirement releases
  references; a page recycles only at refcount zero. The router is
  charged only newly-allocated pages. Byte-identical by construction:
  published pages hold exactly the bytes the row's own prefill would have
  written (same adapter, same tokens, same positions), asserted against
  solo serving in tests/test_prefix_cache.py. ``prefix_cache=False``
  disables reuse; int8-quantized pools opt out automatically.
* **Tick API.** ``service_tick()`` runs ONE admission+decode+retire round;
  ``run()`` loops it to completion. ``training.SymbiosisEngine``
  interleaves these ticks with a ``FinetuneEngine``'s train steps so
  inference and fine-tuning time-share the same resident base (§4.4).
* **Tick loop.** Every tick the scheduler policy (``core.scheduler.
  TickPolicy`` — lockstep / nolockstep / opportunistic) picks which *ready*
  clients join the batched decode (``symbiosis.make_masked_decode_step``);
  slots outside the tick keep their cache and position untouched inside the
  jitted step.
* **Sampling.** Greedy, temperature and top-k sampling, seeded per request
  (np.random.Generator keyed on the request's sampling seed + client id),
  so draws depend only on the request's own token stream.
* **Policy-invariance contract.** The policy (and any interleaving of other
  clients) only changes WHICH ready clients execute a given tick, never the
  math of a sequence's own stream — outputs are byte-identical across
  policies and to serving each request alone (paper: "the output with
  Symbiosis is exactly identical to that of the baseline"); asserted in
  tests/test_serving_engine.py.

For latency realism the engine also reports a scheduler-simulated timeline
(``simulate_policy``) calibrated with measured per-op costs.

Seed-engine ablation knobs: ``bank_prefill=True`` restores the bank-wide
prefill path and ``max_inflight_per_client=1`` the one-request-per-client
admission rule — used by benchmarks/bench_multiclient.py to quantify what
continuous batching buys over the seed behaviour.

Machine-checked invariants (docs/invariants.md): the engine's hot-path
contracts — cache pools donated and written in place, jitted steps
compiling only the closed bucket set declared by ``trace_domain()``, no
base-sized collectives, client isolation — are enforced by
``python -m repro.analysis`` and the tier-1 trace guard in
tests/conftest.py; jitted dispatch routes through
``repro.analysis.tracecount.dispatch``.

Observability (docs/observability.md): pass ``obs=repro.obs.Obs()`` to get
tick-phase spans (``jax.profiler`` named scopes + latency histograms),
per-tenant metrics (queue-wait / TTFT / inter-token / end-to-end latency,
token and page counters, HBM charges) and the client-visible event log
(``drain_events(client=...)`` — admissions, retirements, backoff/retry,
quarantines, bank growth, compiles). Telemetry is bitwise-invisible to the
engine's outputs, adds no device syncs inside the tick (host timestamps at
tick/phase boundaries only) and no jit keys; ``obs=None`` (default) is a
hard no-op. Per-request latency is always recorded on the request itself
(``submit_t``/``admit_t``/``first_token_t``/``finish_t`` +
``queue_wait``/``ttft``/``e2e_latency`` properties), and fault handling
always appends to ``Request.fault_history``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
import warnings
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tracecount
from repro.config import ModelConfig, ServeConfig, DENSE, MOE, VLM
from repro.core import adapters as adapters_lib
from repro.core import symbiosis
from repro.core.engine_spec import EngineSpec
from repro.core.scheduler import ClientSpec, TickPolicy, simulate
from repro.faults.health import HealthPolicy, HealthRecord, HealthState, classify
from repro.faults.plan import TransientFault
from repro.serving.prefix_cache import PrefixIndex, sharable_tokens

# disabled-telemetry span: one shared, reusable null context manager — the
# tick loop's `with self._span(name)` costs a function call and nothing
# else, and no timing machinery (repro.obs, jax.profiler) is imported
_NULL_CTX = contextlib.nullcontext()


def _null_span(name: str):
    return _NULL_CTX


def _pin_serving(fn, cfg, scfg, mesh, *, cache_arg=2):
    """Sharded hot path: pin the donated cache tree to its canonical specs
    on the way IN and OUT of a jitted step (``launch.shardings.
    serving_cache_constrain``). Donated state then keeps ONE placement
    across ticks — no per-tick resharding copies, no executable churn —
    and the compiler is told the client/page partition survives the step,
    so compaction never round-trips through a replicated (base-sized)
    layout. ``mesh=None`` returns ``fn`` untouched. Steps return
    ``(*outputs, caches)`` — the probed compact decode carries an extra
    per-row finite output between logits and caches."""
    if mesh is None:
        return fn
    from repro.launch import shardings

    def pinned(*a):
        a = list(a)
        a[cache_arg] = shardings.serving_cache_constrain(
            cfg, scfg, mesh, a[cache_arg])
        *out, caches = fn(*a)
        return (*out,
                shardings.serving_cache_constrain(cfg, scfg, mesh, caches))

    return pinned


# Jitted step builders are memoized on the (frozen, hashable) configs so
# every engine instance over the same model shares one compile cache —
# constructing an engine is cheap and benchmarks don't re-pay compilation.
# ``mesh`` joins the key (jax Meshes hash by shape + axis names + devices):
# a sharded engine gets its own jitted wrapper, keeping the per-engine
# trace accounting clean. The cache tree (arg 2) is DONATED in every step
# that replaces it: the engine always rebinds ``self.caches`` to the
# result, and donation lets XLA update the (potentially multi-GB) bank
# cache in place instead of copying it once per tick — without it,
# per-tick cost grows with bank size no matter how few slots decode.
@functools.lru_cache(maxsize=None)
def _jit_client_prefill(cfg, acfg, scfg, mesh=None):
    return jax.jit(_pin_serving(symbiosis.make_client_prefill(cfg, acfg, scfg),
                                cfg, scfg, mesh),
                   donate_argnums=2)


@functools.lru_cache(maxsize=None)
def _jit_masked_decode(cfg, acfg, scfg, mesh=None):
    return jax.jit(_pin_serving(
        symbiosis.make_masked_decode_step(cfg, acfg, scfg), cfg, scfg, mesh),
                   donate_argnums=2)


@functools.lru_cache(maxsize=None)
def _jit_bank_prefill(cfg, acfg, scfg, mesh=None):
    return jax.jit(_pin_serving(
        symbiosis.make_multi_client_prefill(cfg, acfg, scfg), cfg, scfg, mesh))


@functools.lru_cache(maxsize=None)
def _jit_compact_decode(cfg, acfg, scfg, mesh=None, probe=False):
    return jax.jit(_pin_serving(
        symbiosis.make_compact_decode_step(cfg, acfg, scfg, probe=probe),
        cfg, scfg, mesh),
                   donate_argnums=2)


# The prefill analogue of the compacted decode tick (ISSUE 10 tentpole):
# one jitted program per (row-bucket-independent) ext_blocks value — the
# row bucket and padded suffix length are ordinary shape-keyed recompiles
# inside the one builder, while ext_blocks (how many leading block-table
# entries each row attends to as read-only shared-prefix lanes) must join
# the builder key because it changes the traced program structure.
@functools.lru_cache(maxsize=None)
def _jit_compact_prefill(cfg, acfg, scfg, mesh=None, ext_blocks=0):
    return jax.jit(_pin_serving(
        symbiosis.make_compact_prefill(cfg, acfg, scfg, probe=True,
                                       ext_blocks=ext_blocks),
        cfg, scfg, mesh),
                   donate_argnums=2)


# Copy-on-write page duplication for shared-prefix tails: one tiny donated
# dispatch copying a single pool page (every layer's lanes at once — the
# stored leaves carry an explicit layer axis). src/dst are traced scalars,
# so all copies share ONE compile.
@functools.lru_cache(maxsize=None)
def _jit_page_copy(cfg, scfg, mesh=None):
    fn = symbiosis.make_page_copy(cfg, scfg)
    if mesh is not None:
        from repro.launch import shardings
        inner = fn

        def fn(caches, src, dst):
            caches = shardings.serving_cache_constrain(cfg, scfg, mesh, caches)
            return shardings.serving_cache_constrain(
                cfg, scfg, mesh, inner(caches, src, dst))

    return jax.jit(fn, donate_argnums=0)


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling config. ``seed`` keys the request's private RNG:
    draws are consumed in token order of the request's own stream, so
    sampled outputs (not just greedy) are schedule/policy-invariant."""
    method: str = "greedy"            # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class BankAdmission:
    """Handle for one ``admit_bank()`` call: the bank it joined (or
    created), the new clients' global ids, and the router charge to release
    at ``retire_bank()``."""
    bank_id: int
    client_ids: List[int]
    placement: object = None


@dataclasses.dataclass(eq=False)       # identity eq: queues hold np arrays
class Request:
    client_id: int
    prompt: Optional[np.ndarray]            # [B, S] int32 (B sequence slots)
    max_new_tokens: int = 16
    latency_sensitive: bool = True
    sampling: Optional[SamplingParams] = None   # None -> greedy
    arrive_tick: int = 0                    # earliest tick admission may see it
    # stream-backed prompt delivery (docs/robustness.md): submit with
    # prompt=None and a prompt_stream exposing fetch(); the engine resolves
    # the prompt at admission, where delivery faults back the client off
    # (transient) or reject the request (exhaustion)
    prompt_stream: Optional[object] = None
    # filled by the engine:
    generated: Optional[np.ndarray] = None  # [B, max_new_tokens]
    submit_t: float = 0.0                   # perf_counter at submit()
    admit_t: float = 0.0                    # ... at successful admission
    first_token_t: float = 0.0              # ... when the first token sampled
    finish_t: float = 0.0                   # ... at retirement
    # lifecycle (docs/robustness.md): ok | quarantined (non-finite logits —
    # terminated, slots/pages/charges freed) | rejected (its client was
    # quarantined before this request ran, or its prompt stream ran dry)
    status: str = "ok"
    # client-visible fault trajectory: (tick, kind, reason) tuples, kind in
    # {backoff, retry, quarantine, rejected} — docs/observability.md
    fault_history: List[tuple] = dataclasses.field(default_factory=list)

    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds from submit to admission (None until admitted)."""
        return self.admit_t - self.submit_t if self.admit_t else None

    @property
    def ttft(self) -> Optional[float]:
        """Seconds from submit to the first sampled token."""
        return (self.first_token_t - self.submit_t
                if self.first_token_t else None)

    @property
    def e2e_latency(self) -> Optional[float]:
        """Seconds from submit to retirement (None until finished)."""
        return self.finish_t - self.submit_t if self.finish_t else None


class ServingEngine:
    """One base model continuously serving one or more banks of adapter
    clients.

    BANK REGISTRY (heterogeneous PEFT methods, mirroring
    ``training.FinetuneEngine``'s bank grouping): pass ``acfg`` as a
    sequence of AdapterConfigs and ``client_bank`` as the matching sequence
    of client-stacked adapter trees — e.g. a LoRA bank, an IA3 bank and a
    prefix bank served CONCURRENTLY by one engine over one frozen base.
    Clients get GLOBAL ids in bank concatenation order (bank 0's clients
    first); the KV caches, page allocator and slot bookkeeping stay keyed
    by the global id (the cache layout is method-independent), while
    admission routes each request's prefill through its own bank's jitted
    step and the compacted decode tick carries per-row method ids — LoRA
    rows keep the SGMV path, IA3/prefix rows get per-row gathers, every
    application gated by a membership select (see
    ``symbiosis.make_compact_decode_step``'s mixed mode). A mixed batch is
    byte-identical to each client's solo single-method run. Mixed banks
    require the paged KV layout (the compacted tick is the only decode
    path that can carry per-row methods); an attached ``PlacementRouter``
    is charged each bank's resident adapter bytes (``route_bank``),
    released via ``release_banks()``.

    CONSTRUCTION (``core.engine_spec.EngineSpec``)::

        spec = EngineSpec(cfg=cfg, banks=(BankSpec("lora8", lora, 4),),
                          serve=scfg, mesh=None)
        engine = ServingEngine(spec, base_params, banks)

    where ``banks`` is one client-stacked adapter tree per ``spec.banks``
    entry (a bare tree for a single bank). ``spec.mesh`` set to a
    ``jax.sharding.Mesh`` shards the engine: the frozen base by
    ``launch.shardings.base_param_specs`` (or replicated with
    ``spec.replicate_base``), caches/page pools/banks with their
    client/page axes over the batch axes; ``mesh=None`` is byte-identical
    to today's single-device engine.

    FAULT CONTAINMENT (docs/robustness.md): per-client health records,
    a compiled-in finite probe on prefill and decode logits, quarantine of
    faulty requests/clients with full page/charge release, transactional
    (rollback-exact) admission, and whole-engine ``engine_state()`` /
    ``load_engine_state()`` crash recovery — survivors stay bitwise
    identical to a never-faulted run.

    DEPRECATED: the parallel-sequence positional form
    ``ServingEngine(cfg, acfg, scfg, base_params, client_bank, ...)``
    still works but emits a ``DeprecationWarning`` — migrate to the
    EngineSpec form above (see docs/sharding.md)."""

    def __init__(self, spec, *args, **kwargs):
        if isinstance(spec, EngineSpec):
            self._init_from_spec(spec, *args, **kwargs)
        else:
            warnings.warn(
                "ServingEngine(cfg, acfg, scfg, base_params, client_bank) is "
                "deprecated; construct an EngineSpec and call "
                "ServingEngine(spec, base_params, banks) (docs/sharding.md)",
                DeprecationWarning, stacklevel=2)
            self._setup(spec, *args, **kwargs)

    def _init_from_spec(self, spec: EngineSpec, base_params, banks, *,
                        router=None, policy: Optional[str] = None,
                        bank_prefill: bool = False,
                        max_inflight_per_client: Optional[int] = None,
                        compact_decode: Optional[bool] = None,
                        ragged_prefill: Optional[bool] = None,
                        prefix_cache: Optional[bool] = None,
                        health_policy: Optional[HealthPolicy] = None,
                        debug: bool = False, fault_hook=None, obs=None):
        if spec.serve is None:
            raise ValueError("ServingEngine needs EngineSpec.serve")
        if not spec.banks:
            raise ValueError("ServingEngine needs at least one BankSpec")
        banks = list(banks) if isinstance(banks, (tuple, list)) else [banks]
        if len(banks) != len(spec.banks):
            raise ValueError(f"{len(banks)} adapter trees for "
                             f"{len(spec.banks)} declared banks")
        for bs, tree in zip(spec.banks, banks):
            k = jax.tree.leaves(tree)[0].shape[0]
            if k != bs.capacity:
                raise ValueError(f"bank {bs.name!r}: adapter tree holds {k} "
                                 f"clients, spec capacity is {bs.capacity}")
        single = len(spec.banks) == 1
        self._setup(spec.cfg,
                    spec.banks[0].acfg if single else spec.bank_cfgs(),
                    spec.serve, base_params,
                    banks[0] if single else banks,
                    max_batch_per_client=spec.max_batch_per_client,
                    router=router, policy=policy, bank_prefill=bank_prefill,
                    max_inflight_per_client=max_inflight_per_client,
                    compact_decode=compact_decode,
                    ragged_prefill=ragged_prefill,
                    prefix_cache=prefix_cache,
                    health_policy=health_policy, debug=debug,
                    fault_hook=fault_hook, obs=obs,
                    mesh=spec.mesh, replicate_base=spec.replicate_base,
                    bank_repl=tuple(b.placement == "replicated"
                                    for b in spec.banks),
                    spec=spec)

    def _setup(self, cfg: ModelConfig, acfg, scfg: ServeConfig,
               base_params, client_bank, *, max_batch_per_client: int = 4,
               router=None, policy: Optional[str] = None,
               bank_prefill: bool = False,
               max_inflight_per_client: Optional[int] = None,
               compact_decode: Optional[bool] = None,
               ragged_prefill: Optional[bool] = None,
               prefix_cache: Optional[bool] = None,
               health_policy: Optional[HealthPolicy] = None,
               debug: bool = False, fault_hook=None, obs=None,
               mesh=None, replicate_base: bool = False,
               bank_repl: tuple = (), spec: Optional[EngineSpec] = None):
        self.cfg, self.acfg, self.scfg = cfg, acfg, scfg
        self.spec = spec
        self.mesh = mesh
        self._replicate_base = replicate_base
        self._bank_repl = bank_repl
        if mesh is not None:
            from repro.launch import shardings
            # idempotent + identity-preserving: SymbiosisEngine.from_spec
            # shards the base ONCE and both engines re-run this as a no-op,
            # keeping the shared-base leaf-identity check intact
            base_params = shardings.shard_base_params(
                cfg, mesh, base_params, replicate=replicate_base)
        self.base = base_params
        self.bank = client_bank
        self._mixed = isinstance(acfg, (tuple, list))
        if self._mixed:
            if not isinstance(client_bank, (tuple, list)) or \
                    len(client_bank) != len(acfg):
                raise ValueError("mixed-method serving: client_bank must be "
                                 "a sequence of adapter trees matching acfg")
            self.bank_cfgs = tuple(acfg)
            self.banks = list(client_bank)
            sizes = [jax.tree.leaves(b)[0].shape[0] for b in self.banks]
        else:
            self.bank_cfgs = (acfg,)
            self.banks = [client_bank]
            sizes = [jax.tree.leaves(client_bank)[0].shape[0]]
        self.n_clients = sum(sizes)
        # global client id -> (bank id, index within the bank's adapter tree)
        self._method_of = np.repeat(np.arange(len(sizes)), sizes).astype(np.int32)
        self._local_of = np.concatenate(
            [np.arange(s) for s in sizes]).astype(np.int32)
        self.max_b = max_batch_per_client
        self.router = router
        self.policy = TickPolicy(policy or scfg.policy)
        self.bank_prefill = bank_prefill
        if bank_prefill and max_inflight_per_client not in (None, 1):
            raise ValueError("bank_prefill replaces the whole client cache "
                             "slice; it requires max_inflight_per_client=1")
        self.max_inflight = 1 if bank_prefill else max_inflight_per_client
        cache_kw = symbiosis.serve_cache_kwargs(cfg, scfg)
        self._paged = "page_block" in cache_kw
        self._quant = bool(cache_kw.get("quant"))
        if self._mixed and not self._paged:
            raise ValueError(
                "mixed-method serving banks require the paged KV layout "
                "(ServeConfig.page_block > 0): only the compacted decode "
                "tick can carry per-row methods")
        if self._mixed and compact_decode is False:
            raise ValueError("mixed-method serving banks decode through the "
                             "compacted per-row-method step; the masked "
                             "bank-wide ablation is single-method only")
        if self._mixed and bank_prefill:
            raise ValueError("bank_prefill is a single-method dense-layout "
                             "ablation")
        # per-bank HBM charges: the router accounts each bank's resident
        # adapter weights (released via release_banks()); single-bank
        # engines keep the pre-registry accounting (KV-only) unchanged
        self._bank_placements = []
        if router is not None and self._mixed:
            try:
                for m, a in enumerate(self.bank_cfgs):
                    _, nbytes = adapters_lib.adapter_bytes(cfg, a)
                    self._bank_placements.append(
                        router.route_bank(nbytes * sizes[m]))
            except RuntimeError:
                # a later bank didn't fit: refund the banks already
                # committed, or their charges leak (no engine object ever
                # exists to release them through)
                self.release_banks()
                raise
        if self._paged:
            if bank_prefill:
                raise ValueError("bank_prefill replaces whole cache slices; "
                                 "it is a dense-layout-only ablation")
            self._blk = scfg.page_block
            self._n_blocks = -(-scfg.max_seq // self._blk)
            self._pool_pages = scfg.pool_pages or max_batch_per_client * self._n_blocks
            cache_kw["pool_pages"] = self._pool_pages
            # host-side page allocator: per-client free list + reservation
            # count (pages promised to in-flight requests but not yet
            # assigned), per-slot assigned pages, per-slot next write pos,
            # and the block-table mirror pushed to the device when dirty.
            # Page ids are GLOBAL (client c owns [c*P, (c+1)*P) of the one
            # flat device pool — see symbiosis.init_client_caches); the
            # per-client free lists keep ISSUE-2 admission semantics
            # (per-client pool backpressure) as an allocator convention.
            self._free_pages = [list(range(c * self._pool_pages,
                                           (c + 1) * self._pool_pages))
                                for c in range(self.n_clients)]
            self._reserved = [0] * self.n_clients
            self._slot_pages: Dict[tuple, List[int]] = {}
            self._wpos = np.zeros((self.n_clients, self.max_b), np.int64)
            # unmapped table entries hold an OUT-OF-RANGE sentinel: under
            # the global pool a zero would alias client 0's first page, and
            # any stray write through a stale entry would corrupt it; the
            # sentinel makes such writes scatter-drop (reads through it are
            # clamped and always position-masked). A fixed huge constant —
            # NOT n_clients * pool_pages, which would become a valid page id
            # the moment admit_bank() grows the pool.
            self._tbl_oob = np.int32(1 << 30)
            self._tbl = np.full((self.n_clients, self.max_b, self._n_blocks),
                                self._tbl_oob, np.int32)
            self._tbl_dirty = True
            self._resv_of: Dict[int, int] = {}
            # shared-prefix page reuse (ISSUE 10, docs/prefix_cache.md):
            # the content-keyed refcounted index over published prompt-
            # prefix pages, the per-slot lists of REF-HELD pages (a slot's
            # table = shared pages first, then its exclusive _slot_pages),
            # the per-slot suffix start recorded at admission for the tick's
            # compacted prefill, and the CoW page copies queued for dispatch
            # just before that prefill runs
            self._prefix_index = PrefixIndex()
            self._slot_shared: Dict[tuple, List[int]] = {}
            self._prefill_start: Dict[tuple, int] = {}
            self._pending_copies: List[tuple] = []
        self.caches = symbiosis.init_client_caches(
            cfg, self.n_clients, max_batch_per_client, scfg.max_seq, **cache_kw)
        self._place_on_mesh()
        # one jitted masked-prefill per bank (admission runs the admitted
        # client's OWN method); the masked bank-wide decode exists only for
        # single-method engines (it vmaps one homogeneous adapter tree)
        self._prefill_one = [_jit_client_prefill(cfg, a, scfg, mesh)
                             for a in self.bank_cfgs]
        self._prefill_bank = (_jit_bank_prefill(cfg, acfg, scfg, mesh)
                              if bank_prefill else None)
        self._decode = (None if self._mixed
                        else _jit_masked_decode(cfg, acfg, scfg, mesh))
        # Compute-proportional decode (ISSUE 3 tentpole): gather the active
        # (client, slot) rows into one dense batch and run ONLY those —
        # FLOPs/HBM scale with active tokens, not bank size. Paged layouts
        # only (the page pools are what let the client axis fold away);
        # auto-enabled there, the masked bank-wide step stays as the
        # ablation (compact_decode=False) and the dense-layout path.
        if compact_decode and not self._paged:
            raise ValueError("compact_decode requires the paged KV layout "
                             "(ServeConfig.page_block > 0)")
        self._compact = self._paged if compact_decode is None else compact_decode
        # probe=True compiles the per-row finite reduction INTO the step
        # (docs/robustness.md): non-finite decode logits surface on the
        # host as a cheap [rows] bool without materializing [rows, V]
        self._compact_step = (_jit_compact_decode(
            cfg, self.bank_cfgs if self._mixed else acfg, scfg, mesh,
            probe=True)
            if self._compact else None)
        # jit-bucketed row-batch sizes: 4, 8, ... capped at the bank's rows
        total_rows = self.n_clients * self.max_b
        self._buckets = []
        b = 4
        while b < total_rows:
            self._buckets.append(b)
            b *= 2
        self._buckets.append(total_rows)
        # Ragged shared prefill (ROADMAP): several same-client admissions in
        # one tick batch into ONE masked prefill call with per-row lengths.
        # Right-padding to the longest prompt is exact for attention
        # families only; recurrent state (hybrid/RWKV) would be polluted by
        # pads, so those families keep one call per request.
        can_ragged = cfg.arch in (DENSE, MOE, VLM) and not bank_prefill
        if ragged_prefill and not can_ragged:
            raise ValueError("ragged_prefill right-pads rows to a shared "
                             "bucket; attention families only (and not the "
                             "bank_prefill ablation)")
        self._ragged = can_ragged if ragged_prefill is None else ragged_prefill
        # Cross-client compacted prefill (ISSUE 10 tentpole): on paged
        # attention engines the tick's admissions — ALL clients, ALL banks —
        # gather into ONE jit-bucketed ragged batch through
        # symbiosis.make_compact_prefill (the prefill analogue of the
        # compacted decode tick); the dense layout keeps the same-client
        # masked ragged path and recurrent families / ablations keep
        # per-request calls. Shared-prefix page reuse rides on top of the
        # compacted path: content-matched prompt-prefix pages are mapped at
        # admission (refcounted, read-only) and only the suffix prefills.
        # Sharing needs exact K/V bytes, so int8-quantized pools opt out.
        self._compact_prefill = self._ragged and self._paged
        can_share = self._compact_prefill and not self._quant
        if prefix_cache and not can_share:
            raise ValueError(
                "prefix_cache needs the compacted prefill path (paged "
                "attention-family engine, ragged_prefill not disabled) and "
                "an unquantized pool — int8 K/V doesn't round-trip "
                "(docs/prefix_cache.md)")
        self._share_prefix = can_share if prefix_cache is None else prefix_cache
        self._page_copy = (_jit_page_copy(cfg, scfg, mesh)
                           if self._share_prefix else None)
        # jit-key bookkeeping for the analysis bucket-coverage pass: the
        # epoch is bumped whenever admit_bank() legitimately changes hot-
        # path shapes, so post-growth compiles aren't read as recompiles
        self._trace_epoch = 0
        self._dead_clients: set = set()       # clients of retired banks
        # fault containment (docs/robustness.md): per-client health records,
        # the quarantine set (submit refuses; live requests terminated with
        # their resources freed through the normal retire path), an optional
        # deterministic fault hook for the chaos harness, and the per-tick
        # flag that keeps an injected admission fault from tripping the
        # "can never be admitted" stall detector
        self.health_policy = health_policy or HealthPolicy()
        self.debug = debug
        self.fault_hook = fault_hook
        self._client_health: Dict[int, HealthRecord] = {}
        self._quarantined_clients: set = set()
        self._admission_faulted = False
        self._queue: List[Request] = []
        # incremental service loop state: SymbiosisEngine interleaves
        # service_tick() with a FinetuneEngine's train ticks; run() is the
        # standalone drive-to-completion loop over the same method
        self._waiting: deque = deque()
        self._inflight: List[Request] = []
        self._done: List[Request] = []
        self._tick = 0
        # slot tables + per-request bookkeeping (keyed by id(req); requests
        # stay alive in the done list for the whole run)
        self._slot_owner = [[None] * self.max_b for _ in range(self.n_clients)]
        self._last_tok = np.zeros((self.n_clients, self.max_b), np.int32)
        # Incrementally maintained activity state (admit/retire only — never
        # re-derived from the request list inside the tick loop, whose cost
        # would grow with bank size): the bool mask drives the masked step,
        # the per-client sorted slot lists drive compacted row building.
        self._active_mask = np.zeros((self.n_clients, self.max_b), bool)
        self._active_slots: List[List[int]] = [[] for _ in range(self.n_clients)]
        self._left: Dict[int, int] = {}
        self._slots_of: Dict[int, List[int]] = {}
        self._rng: Dict[int, np.random.Generator] = {}
        self._placement: Dict[int, object] = {}
        # prefill_tokens counts LOGICAL prompt tokens admitted (layout- and
        # sharing-invariant); prefill_tokens_computed counts the tokens the
        # model actually ran — under shared-prefix hits only each row's
        # suffix — so the two diverge exactly by the reused prefix work
        self.stats = {"ticks": 0, "decode_tokens": 0, "prefill_tokens": 0,
                      "batched_clients": 0, "admitted": 0, "prefill_calls": 0,
                      "peak_inflight": 0, "compact_rows": 0, "compact_padded": 0,
                      "ragged_prefill_batches": 0, "faults": 0,
                      "quarantined_requests": 0, "rejected_requests": 0,
                      "quarantined_clients": 0, "compact_prefill_batches": 0,
                      "compact_prefill_rows": 0, "compact_prefill_padded": 0,
                      "prefill_tokens_computed": 0, "prefix_hits": 0,
                      "pages_shared": 0, "cow_copies": 0}
        # telemetry (docs/observability.md): obs=None is a hard no-op — the
        # tick loop sees only `is not None` guards plus the shared null
        # span; attached, all instrumentation is host-side (perf_counter at
        # tick/phase boundaries, no device syncs, no jit keys) and outputs
        # stay bitwise identical (tests/test_obs.py)
        self._obs = obs
        self._span = _null_span if obs is None else obs.span
        self._last_tok_t: Dict[int, float] = {}
        if obs is not None:
            obs.attach("serving", self)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        assert 0 <= req.client_id < self.n_clients
        if req.client_id in self._dead_clients:
            raise ValueError(f"client {req.client_id} belongs to a retired "
                             "bank (see retire_bank)")
        if req.client_id in self._quarantined_clients:
            raise ValueError(f"client {req.client_id} is quarantined "
                             "(docs/robustness.md)")
        if req.prompt is None:
            # stream-backed prompt: shape checks happen at admission, when
            # the fetch resolves (and can fault — docs/robustness.md)
            if req.prompt_stream is None:
                raise ValueError("Request needs a prompt or a prompt_stream")
            assert req.max_new_tokens >= 1
        else:
            B, S = req.prompt.shape
            assert B <= self.max_b, f"request rows {B} > {self.max_b} slots"
            assert req.max_new_tokens >= 1
            assert S + req.max_new_tokens <= self.scfg.max_seq, (
                f"context {S}+{req.max_new_tokens} exceeds cache depth "
                f"{self.scfg.max_seq}")
        if req.sampling is not None and req.sampling.method not in (
                "greedy", "temperature", "top_k"):
            raise ValueError(f"unknown sampling method {req.sampling.method!r}")
        req.submit_t = time.perf_counter()
        self._queue.append(req)

    def pending(self) -> bool:
        """True while any request is queued, waiting, or in flight."""
        return bool(self._queue or self._waiting or self._inflight)

    @property
    def n_inflight(self) -> int:
        """Requests currently holding slots/pages/router capacity (what a
        co-scheduler checks before treating an admission stall as fatal)."""
        return len(self._inflight)

    def drain_done(self) -> List[Request]:
        """Hand over (and forget) the finished-request list. Each record
        carries its latency timeline (``queue_wait`` / ``ttft`` /
        ``e2e_latency`` properties) and ``fault_history``."""
        done, self._done = self._done, []
        return done

    def drain_events(self, *, client=None, kind: Optional[str] = None):
        """Client-visible event stream (docs/observability.md): drain THIS
        engine's telemetry events, optionally filtered to one client id
        and/or one event kind — filtered drains leave other tenants' (and,
        under a shared ``Obs``, the finetune engine's) events queued.
        Returns [] when the engine runs without telemetry (obs=None)."""
        if self._obs is None:
            return []
        if client is None:
            return self._obs.drain_events(kind=kind, engine="serving")
        return self._obs.drain_events(client=client, kind=kind,
                                      engine="serving")

    def service_tick(self) -> bool:
        """ONE engine tick: admission (+ the admitted requests' prefills),
        the policy-chosen decode tick, retirement. The incremental form of
        ``run()`` — ``SymbiosisEngine`` interleaves these with a
        FinetuneEngine's train steps against the same base. Returns True
        while requests remain."""
        obs = self._obs
        t0 = obs.tick_start("serving") if obs is not None else 0.0
        if self._queue:
            # merge new submissions (mid-run submits are allowed; order is
            # stable for equal arrive_ticks)
            self._waiting = deque(sorted(list(self._waiting) + self._queue,
                                         key=lambda r: r.arrive_tick))
            self._queue.clear()
        waiting, inflight = self._waiting, self._inflight
        if not waiting and not inflight:
            return False
        tick = self._tick
        self._admission_faulted = False
        # -- admission (continuous except under lockstep's batch barrier);
        # slots/pages/router capacity are claimed per request, then all of
        # this tick's admissions prefill together (ragged where possible)
        admitted_any = False
        newly = []
        with self._span("admit"):
            # the backoff gate (docs/robustness.md): a SUSPECT client's
            # requests skip admission until its deterministic backoff
            # expires — mirrored from the train-side job gate; backed-off
            # requests don't count as "attempted" for the stall detector
            # (backoff is bounded by HealthPolicy.max_backoff ticks)
            attempted, backing_off = [], 0
            for r in waiting:
                if r.arrive_tick > tick:
                    continue
                rec = self._client_health.get(r.client_id)
                if rec is not None and not rec.eligible(tick):
                    backing_off += 1
                    continue
                attempted.append(r)
            if self.policy.admit_now(len(inflight)):
                for req in attempted:
                    if req.client_id in self._quarantined_clients:
                        continue      # swept to rejected by _quarantine_client
                    if req.status == "rejected":
                        continue      # stream ran dry inside _try_admit
                    slots = self._try_admit(req)
                    if slots is not None:
                        waiting.remove(req)
                        inflight.append(req)
                        newly.append((req, slots))
                        admitted_any = True
        if obs is not None and backing_off:
            obs.metrics.counter("serve_backoff_skips_total").inc(backing_off)
        with self._span("prefill"):
            self._prefill_admitted(newly)

        self.stats["peak_inflight"] = max(self.stats["peak_inflight"],
                                          len(inflight))
        # -- decode tick over the policy-chosen subset of ready clients
        ready = sorted({r.client_id for r in inflight if self._left[id(r)] > 0})
        serve = self.policy.serving_set(ready)
        if serve:
            self._decode_tick(set(serve), inflight)

        # -- retire finished sequences; their slots free immediately
        for req in list(inflight):
            if self._left[id(req)] == 0:
                self._retire(req)
                inflight.remove(req)
                self._done.append(req)

        if (not inflight and attempted and not admitted_any and not serve
                and not self._admission_faulted):
            # nothing in flight to ever free capacity, and admission of
            # every due request just failed -> stuck forever (an injected
            # transient admission fault is NOT stuck: the retry may succeed)
            raise RuntimeError(
                f"{len(attempted)} request(s) can never be admitted "
                f"(no free capacity and nothing in flight)")
        tick += 1
        if not inflight and waiting and all(r.arrive_tick > tick for r in waiting):
            tick = min(r.arrive_tick for r in waiting)           # idle skip
        self._tick = tick
        if self.debug:
            with self._span("health_audit"):
                from repro.faults.audit import serving_conservation
                errs = serving_conservation(self)
                assert not errs, "; ".join(errs)
        if obs is not None:
            obs.tick_end("serving", tick, t0)
        return bool(waiting or inflight)

    def run(self) -> List[Request]:
        """Serve all queued requests to completion; returns finished list."""
        while self.service_tick():
            pass
        return self.drain_done()

    # ------------------------------------------------------------------
    # admission + prefill
    # ------------------------------------------------------------------
    def _try_admit(self, req: Request) -> Optional[List[int]]:
        """Claim capacity for a request: slots, pages (under paging), and a
        router placement. Returns the claimed slot list (admitted — the
        caller prefills via ``_prefill_admitted``) or None (stays queued)."""
        c = req.client_id
        if req.prompt is None and not self._fetch_prompt(req):
            return None
        B, S = req.prompt.shape
        if self.max_inflight is not None:
            owners = {id(o) for o in self._slot_owner[c] if o is not None}
            if len(owners) >= self.max_inflight:
                return None
        free = [s for s in range(self.max_b) if self._slot_owner[c][s] is None]
        if len(free) < B:
            return None
        ctx_tokens = S + req.max_new_tokens
        hits = None
        if self._paged:
            # Reserve pages for the FULL context up front (deadlock freedom:
            # a running sequence can always draw its next page) but assign
            # them lazily — the block table only maps pages whose tokens
            # exist. Admission backpressure = not enough unreserved pages.
            pages_per_row = -(-ctx_tokens // self._blk)
            prompt_pages = -(-S // self._blk)
            need = pages_per_row * B
            if self._share_prefix:
                # shared-prefix lookup (read-only; refs are taken inside
                # the transactional block below): content-matched prefix
                # pages are mapped instead of popped, so backpressure and
                # the router charge count only NEWLY allocated pages
                scope = self._prefix_scope(c)
                hits = [self._prefix_index.lookup(scope, req.prompt[i],
                                                  self._blk)
                        for i in range(B)]
                need -= sum(h.matched_blocks for h in hits)
            if len(self._free_pages[c]) - self._reserved[c] < need:
                return None
        placement = None
        if self.router is not None:
            # charge what the layout pins: whole NEWLY-ALLOCATED pages under
            # paging (shared-prefix pages are already charged to their
            # publisher), a full max_seq-deep dense slot row otherwise
            alloc_tokens = (-(-need * self._blk // B) if self._paged
                            else self.scfg.max_seq)
            try:
                placement = self.router.route(ctx_tokens, B,
                                              latency_sensitive=req.latency_sensitive,
                                              alloc_tokens=alloc_tokens,
                                              quant=self._quant)
            except RuntimeError:
                return None                      # stays queued until capacity frees
        slots = free[:B]
        # TRANSACTIONAL from here on: the router charge is already committed
        # and the page pops below are multi-step — any failure mid-flight
        # must restore every structure exactly or the request leaks its
        # charge/pages forever (docs/robustness.md, admission-leak test)
        done_slots: List[int] = []
        tbl_rows = self._tbl[c, slots].copy() if self._paged else None
        wpos_rows = self._wpos[c, slots].copy() if self._paged else None
        n_copies0 = len(self._pending_copies) if self._paged else 0
        try:
            if self.fault_hook is not None:
                self.fault_hook("serve_admit", c)
            if self._paged:
                for i, s in enumerate(slots):
                    hit = hits[i] if hits is not None else None
                    shared: List[int] = []
                    pages: List[int] = []
                    # register BEFORE popping/reffing so a mid-flight
                    # failure still sees every page and reference taken so
                    # far in the rollback sweep
                    self._slot_shared[(c, s)] = shared
                    self._slot_pages[(c, s)] = pages
                    done_slots.append(s)
                    if hit is not None:
                        for d in hit.full_digests:
                            shared.append(self._prefix_index.ref(d))
                    for _ in range(prompt_pages - len(shared)):
                        pages.append(self._free_pages[c].pop())
                    self._tbl[c, s, :] = self._tbl_oob
                    self._tbl[c, s, :len(shared)] = shared
                    self._tbl[c, s, len(shared):prompt_pages] = pages
                    self._wpos[c, s] = S
                    start = 0
                    if hit is not None:
                        start = hit.start
                        if hit.tail_page is not None:
                            # CoW: the matched partial tail copies into this
                            # row's first exclusive page before the suffix
                            # prefill reads it (flushed in _prefill_compact)
                            self._pending_copies.append(
                                (hit.tail_page, pages[0]))
                    self._prefill_start[(c, s)] = start
                self._resv_of[id(req)] = (pages_per_row - prompt_pages) * B
                self._reserved[c] += self._resv_of[id(req)]
                self._tbl_dirty = True
        except BaseException as e:
            # pop() draws from the END of the free list, so extending with
            # each slot's pages reversed — newest slot first — restores the
            # pool's exact order (a retried admission then draws the SAME
            # pages, keeping the transient-recovery trajectory bitwise);
            # shared-prefix refs drop in the same reverse order (a ref taken
            # here can't be the last one — the publisher still holds its own)
            for s in reversed(done_slots):
                self._free_pages[c].extend(
                    reversed(self._slot_pages.pop((c, s))))
                for p in reversed(self._slot_shared.pop((c, s), [])):
                    if self._prefix_index.deref(p):
                        self._free_pages[p // self._pool_pages].append(p)
                self._prefill_start.pop((c, s), None)
            if self._paged:
                del self._pending_copies[n_copies0:]
                self._tbl[c, slots] = tbl_rows
                self._wpos[c, slots] = wpos_rows
                resv = self._resv_of.pop(id(req), None)
                if resv is not None:
                    self._reserved[c] -= resv
            if placement is not None:
                self.router.release(placement)
            if isinstance(e, TransientFault):
                self._fault_backoff(req, f"admission: {e}")
                return None                      # stays queued; retried next tick
            raise
        self._placement[id(req)] = placement
        for s in slots:
            self._slot_owner[c][s] = req
        req.admit_t = time.perf_counter()
        if hits is not None:
            n_hit = sum(1 for h in hits if h.start > 0)
            if n_hit:
                n_shared = sum(h.matched_blocks for h in hits)
                n_cow = sum(1 for h in hits if h.tail_page is not None)
                self.stats["prefix_hits"] += n_hit
                self.stats["pages_shared"] += n_shared
                self.stats["cow_copies"] += n_cow
                if self._obs is not None:
                    m = self._obs.metrics
                    m.counter("prefix_cache_hits_total", client=c).inc(n_hit)
                    m.counter("pages_shared", client=c).inc(n_shared)
                    if n_cow:
                        m.counter("cow_copies_total", client=c).inc(n_cow)
        if self._obs is not None:
            m = self._obs.metrics
            m.histogram("serve_queue_wait_seconds", client=c).observe(
                req.admit_t - req.submit_t)
            if self._paged:
                m.gauge("serve_pages_free", client=c).set(
                    len(self._free_pages[c]) - self._reserved[c])
            if placement is not None:
                m.counter("serve_hbm_charged_bytes_total", client=c).inc(
                    placement.cache_bytes)
            if self.router is not None:
                u = self.router.utilization()
                m.gauge("router_placements").set(u["placements"])
                m.gauge("router_committed_bytes").set(u["committed_bytes"])
            self._obs.event("admit", engine="serving", tick=self._tick,
                            tenant=c, rows=B, prompt_tokens=int(B * S))
            if req.fault_history:
                # a previously backed-off request made it through: the
                # client-visible signal that its retry succeeded
                self._obs.event("retry", engine="serving", tick=self._tick,
                                tenant=c, attempts=len(req.fault_history))
        return slots

    def _fault_backoff(self, req: Request, reason: str):
        """Shared transient-admission-fault path: health trip -> SUSPECT
        with deterministic backoff (event kind ``backoff``) or, past the
        retry budget, client quarantine. Admission state was already rolled
        back; the request stays queued for a bitwise retry."""
        c = req.client_id
        self._admission_faulted = True
        self.stats["faults"] += 1
        rec = self._client_health.setdefault(c, HealthRecord())
        verdict = rec.trip(self._tick, reason, self.health_policy)
        req.fault_history.append((self._tick, "backoff", reason))
        if self._obs is not None:
            self._obs.event("backoff", engine="serving", tick=self._tick,
                            tenant=c, reason=reason,
                            until=rec.next_eligible_tick)
        if verdict == "quarantine":
            self._quarantine_client(c)

    def _fetch_prompt(self, req: Request) -> bool:
        """Resolve a stream-backed request's prompt at admission time — the
        serving twin of the train-side ``FaultyStream`` injection point
        (docs/robustness.md). Runs BEFORE any admission state commits, so
        a delivery fault needs no rollback: transient errors back the
        client off (the retried fetch draws the same prompt — bitwise);
        exhaustion or an invalid prompt rejects the request. Returns True
        when ``req.prompt`` is resolved and valid."""
        c = req.client_id
        try:
            prompt = np.asarray(req.prompt_stream.fetch(), np.int32)
            if prompt.ndim != 2:
                raise ValueError(f"stream prompt must be [B, S], got "
                                 f"shape {prompt.shape}")
            B, S = prompt.shape
            if B > self.max_b or \
                    S + req.max_new_tokens > self.scfg.max_seq:
                raise ValueError(f"stream prompt [{B}, {S}] does not fit "
                                 f"({self.max_b} slots, depth "
                                 f"{self.scfg.max_seq})")
        except Exception as e:
            if classify(e) == "transient":
                self._fault_backoff(req, f"request stream: {e}")
            else:
                # stream ran dry / delivered garbage: reject this request
                # (and only it — the client stays healthy). Flagging
                # _admission_faulted keeps the removal from tripping the
                # same-tick stall detector.
                self._admission_faulted = True
                req.status = "rejected"
                req.fault_history.append(
                    (self._tick, "rejected", f"request stream: {e}"))
                self._waiting.remove(req)
                self._done.append(req)
                self.stats["rejected_requests"] += 1
                if self._obs is not None:
                    self._obs.event("reject", engine="serving",
                                    tick=self._tick, tenant=c,
                                    reason=f"request stream: {e}")
            return False
        req.prompt = prompt
        return True

    def _finish_admit(self, req: Request, slots: List[int],
                      first_logits: np.ndarray):
        """Post-prefill admission bookkeeping: sample the first token and
        activate the request's slots for decode ticks."""
        c = req.client_id
        B = req.prompt.shape[0]
        sp = req.sampling or SamplingParams()
        self._rng[id(req)] = np.random.default_rng([sp.seed, c])
        bad = ("client quarantined mid-tick"
               if c in self._quarantined_clients else
               "non-finite prefill logits"
               if not np.isfinite(first_logits).all() else None)
        if bad is not None:
            # non-finite prefill logits (poisoned adapter / corrupt weights)
            # quarantine the request before its first token ever samples —
            # left stays 0 so this tick's retire loop frees slots, pages and
            # the router charge through the one normal path
            req.generated = np.zeros((B, req.max_new_tokens), np.int32)
            req.status = "quarantined"
            req.fault_history.append((self._tick, "quarantine", bad))
            self._left[id(req)] = 0
            self._slots_of[id(req)] = slots
            self.stats["quarantined_requests"] += 1
            if self._obs is not None:
                self._obs.event("quarantine", engine="serving",
                                tick=self._tick, tenant=c, scope="request",
                                reason=bad)
            if bad == "non-finite prefill logits":
                self._fault_client(c, bad)
            return
        first = self._sample(first_logits, req)
        req.first_token_t = time.perf_counter()
        req.generated = np.zeros((B, req.max_new_tokens), np.int32)
        req.generated[:, 0] = first
        self._last_tok[c, slots] = first
        self._left[id(req)] = req.max_new_tokens - 1
        self._slots_of[id(req)] = slots
        if self._obs is not None:
            m = self._obs.metrics
            m.counter("serve_prefill_tokens_total", client=c).inc(
                int(req.prompt.size))
            m.histogram("serve_ttft_seconds", client=c).observe(
                req.first_token_t - req.submit_t)
            # the first decode token's inter-token gap measures from here
            self._last_tok_t[id(req)] = req.first_token_t
        if self._left[id(req)] > 0:
            # a request admitted with max_new_tokens == 1 is already done
            # (its one token came from prefill) and must never join a decode
            # tick: its slot's next block-table entry is still unassigned,
            # and decoding through it would write another client's page
            self._active_mask[c, slots] = True
            self._active_slots[c] = sorted(self._active_slots[c] + slots)
        self.stats["admitted"] += 1

    def _prefill_admitted(self, newly: List[tuple]):
        """Prefill this tick's admissions through ONE of three paths:

        * paged attention engines (the default): the CROSS-CLIENT compacted
          prefill — every admitted row this tick, across clients and banks,
          in one jit-bucketed dispatch (``_prefill_compact``), shared-prefix
          rows prefilling only their suffix;
        * dense-layout attention engines with ``ragged_prefill``: the
          same-client masked ragged batch (ISSUE 4) — the paged fold isn't
          available without page pools;
        * recurrent families, ``ragged_prefill=False`` and the
          ``bank_prefill`` ablation: one masked call per request.

        All three are byte-identical per row: rows are independent (per-row
        causal attention, length-bounded writes, disjoint slot masks) —
        asserted across paths in tests/test_serving_engine.py and
        tests/test_prefix_cache.py."""
        if not newly:
            return
        if not self._ragged:
            for req, slots in newly:
                logits = (self._prefill_request_bankwide(req, slots)
                          if self.bank_prefill
                          else self._prefill_request(req, slots))
                self._finish_admit(req, slots, logits)
            return
        if self._compact_prefill:
            self._prefill_compact(newly)
            return
        by_client: Dict[int, List[tuple]] = {}
        for req, slots in newly:
            by_client.setdefault(req.client_id, []).append((req, slots))
        for c, items in by_client.items():
            if len(items) == 1:
                req, slots = items[0]
                self._finish_admit(req, slots,
                                   self._prefill_request(req, slots))
                continue
            logits = self._prefill_ragged(c, items)
            for req, slots in items:
                self._finish_admit(req, slots, logits[slots])

    def _prefill_ragged(self, c: int, items: List[tuple]) -> np.ndarray:
        """One ragged masked prefill for several same-client admissions:
        rows are right-padded to the longest prompt's jit bucket and each
        row's true ``lengths`` entry drives its positions, causal mask,
        last-token logit gather and (under paging) pool-write bounds.
        Returns the full [max_b, V] logits block."""
        S_pad = self._bucket(max(req.prompt.shape[1] for req, _ in items))
        toks = np.zeros((self.max_b, S_pad), np.int32)
        lengths = np.zeros((self.max_b,), np.int32)
        mask = np.zeros((self.max_b,), bool)
        for req, slots in items:
            B, S = req.prompt.shape
            toks[slots, :S] = req.prompt
            lengths[slots] = S
            mask[slots] = True
            self.stats["prefill_tokens"] += B * S
        self._sync_tbl()
        m = int(self._method_of[c])
        with self._mesh_ctx():
            logits, self.caches = tracecount.dispatch(
                self, "prefill", (m, S_pad), self._prefill_one[m],
                self.base, self.banks[m], self.caches, np.int32(c),
                np.int32(self._local_of[c]),
                jnp.asarray(toks), jnp.asarray(lengths), jnp.asarray(mask))
        self.stats["prefill_calls"] += 1
        self.stats["ragged_prefill_batches"] += 1
        return np.asarray(logits)

    def _prefill_compact(self, newly: List[tuple]):
        """ONE compacted prefill for the whole tick's admissions (ISSUE 10
        tentpole): gather every admitted (client, slot) row — cross-client,
        cross-bank — into a jit-bucketed ragged batch and scatter the
        results back under the row mask, the exact prefill analogue of
        ``_decode_tick_compact``. Each row carries the suffix start recorded
        at admission; rows with shared-prefix pages attend to their first
        ``ext_blocks`` block-table entries as read-only prefix lanes and
        prefill only their suffix. Queued CoW tail copies flush first, so
        every prefix page a row reads already holds its final bytes."""
        with self._span("prefill_compact_gather"):
            rows = []                        # (req, slot, row-in-request)
            for req, slots in newly:
                for i, s in enumerate(slots):
                    rows.append((req, s, i))
            n = len(rows)
            nb = self._row_bucket(n)
            starts = np.zeros((nb,), np.int32)
            suffix = np.zeros((n,), np.int32)
            for r, (req, s, i) in enumerate(rows):
                starts[r] = self._prefill_start.pop((req.client_id, s), 0)
                suffix[r] = req.prompt.shape[1] - starts[r]
            S_pad = self._bucket(int(suffix.max()))
            ext = self._ext_bucket(
                int(max(-(-int(starts[r]) // self._blk) for r in range(n))))
            toks = np.zeros((nb, S_pad), np.int32)
            lengths = np.zeros((nb,), np.int32)
            clients = np.zeros((nb,), np.int32)
            slot_ids = np.zeros((nb,), np.int32)
            rmask = np.zeros((nb,), bool)
            for r, (req, s, i) in enumerate(rows):
                toks[r, :suffix[r]] = req.prompt[i, starts[r]:]
                lengths[r] = suffix[r]
                clients[r] = req.client_id
                slot_ids[r] = s
                rmask[r] = True
                self.stats["prefill_tokens"] += int(req.prompt.shape[1])
                self.stats["prefill_tokens_computed"] += int(suffix[r])
        self._flush_page_copies()
        self._sync_tbl()
        fn = _jit_compact_prefill(
            self.cfg, self.bank_cfgs if self._mixed else self.bank_cfgs[0],
            self.scfg, self.mesh, ext)
        key = (nb, S_pad, ext)
        if self._mixed:
            with self._span("jit_dispatch"), self._mesh_ctx():
                logits, finite, self.caches = tracecount.dispatch(
                    self, "compact_prefill", key, fn,
                    self.base, tuple(self.banks), self.caches,
                    jnp.asarray(toks), jnp.asarray(lengths),
                    jnp.asarray(starts), jnp.asarray(clients),
                    jnp.asarray(slot_ids),
                    jnp.asarray(self._method_of[clients]),
                    jnp.asarray(self._local_of[clients]),
                    jnp.asarray(rmask))
        else:
            with self._span("jit_dispatch"), self._mesh_ctx():
                logits, finite, self.caches = tracecount.dispatch(
                    self, "compact_prefill", key, fn,
                    self.base, self.banks[0], self.caches,
                    jnp.asarray(toks), jnp.asarray(lengths),
                    jnp.asarray(starts), jnp.asarray(clients),
                    jnp.asarray(slot_ids), jnp.asarray(rmask))
        with self._span("device_sync"):
            logits = np.asarray(logits)
        self.stats["prefill_calls"] += 1
        self.stats["compact_prefill_batches"] += 1
        self.stats["compact_prefill_rows"] += n
        self.stats["compact_prefill_padded"] += nb - n
        if self._obs is not None:
            h = self._obs.metrics.histogram("admission_prefill_tokens")
            for L in suffix:
                h.observe(float(L))
        rows_of: Dict[int, List[int]] = {}
        for r, (req, s, i) in enumerate(rows):
            rows_of.setdefault(id(req), []).append(r)
        for req, slots in newly:
            self._finish_admit(req, slots, logits[rows_of[id(req)]])
            self._publish_prefix(req, slots)

    def _publish_prefix(self, req: Request, slots: List[int]):
        """Register a freshly prefilled request's prompt-prefix pages in the
        content index (docs/prefix_cache.md). Published full blocks move
        from the slot's exclusive list to its ref-held shared list (refs=1
        — the publisher's own reference); a partially-filled tail page
        stays exclusive but is indexed for copy-on-write hits. Duplicate
        digests (content already published) are skipped inside the index,
        so re-publishing a hit row only extends the chain with its new
        blocks."""
        if not self._share_prefix or req.status != "ok":
            return
        c = req.client_id
        scope = self._prefix_scope(c)
        for i, s in enumerate(slots):
            shared = self._slot_shared[(c, s)]
            pages = self._slot_pages[(c, s)]
            took = self._prefix_index.publish(
                scope, req.prompt[i], self._blk, shared + pages, (c, s))
            for p in took:      # block order is preserved on both lists
                pages.remove(p)
                shared.append(p)

    def _prefix_scope(self, c: int) -> bytes:
        """Digest scope for client ``c``'s prefix pages: the adapter
        identity. ANY adapter changes deeper layers' K/V — a layer-l delta
        shifts the residual stream feeding layer l+1's K/V projections —
        so pages are sharable only between prompts served by the same
        (bank, local adapter) pair, i.e. the same client or a client
        admitted over identical adapter rows."""
        return b"%d:%d" % (int(self._method_of[c]), int(self._local_of[c]))

    def _ext_bucket(self, e: int) -> int:
        """Jit-bucketed ext_blocks: 0 stays 0 (compiles the exact
        no-sharing program), otherwise the next power of two capped at the
        per-slot table depth."""
        if e <= 0:
            return 0
        b = 1
        while b < e:
            b *= 2
        return min(b, self._n_blocks)

    def _flush_page_copies(self):
        """Dispatch the admission-queued CoW page copies. One donated
        jitted program (src/dst are traced scalars) copies a single pool
        page across every layer's lanes; copies run before the compacted
        prefill so shared tails are in place when the suffix reads them."""
        if not self._pending_copies:
            return
        copies, self._pending_copies = self._pending_copies, []
        with self._mesh_ctx():
            for src, dst in copies:
                self.caches = tracecount.dispatch(
                    self, "page_copy", (), self._page_copy,
                    self.caches, jnp.int32(src), jnp.int32(dst))

    def _bucket(self, S: int) -> int:
        """Jit-bucketed prompt length. Attention families tolerate right-
        padding exactly (see model.prefill); recurrent families (hybrid,
        RWKV) must prefill at true length or pads pollute the state."""
        if self.cfg.arch not in (DENSE, MOE, VLM):
            return S
        b = 8
        while b < S:
            b *= 2
        return min(b, self.scfg.max_seq)

    def _mesh_ctx(self):
        """Ambient-mesh context for jitted dispatch: binds the engine mesh
        while tracing/running a step so the soft constraints inside the hot
        path (``common.constrain``) resolve; a no-op single-device."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.launch.mesh import mesh_context
        return mesh_context(self.mesh)

    def _place_on_mesh(self):
        """``device_put`` the engine's mutable state onto the mesh: caches
        (page pools by their page axis, per-slot leaves by the client axis)
        and each adapter bank (client axis; ``BankSpec.placement ==
        "replicated"`` keeps a bank whole on every device). Idempotent —
        re-run after ``admit_bank`` growth to place the appended state."""
        if self.mesh is None:
            return
        from repro.launch import shardings
        self._cache_specs = shardings.serving_cache_specs(
            self.cfg, self.scfg, self.mesh, self.caches)
        self.caches = shardings.put_tree(self.mesh, self.caches,
                                         self._cache_specs)
        for m, b in enumerate(self.banks):
            repl = m < len(self._bank_repl) and self._bank_repl[m]
            self.banks[m] = shardings.put_tree(
                self.mesh, b,
                shardings.bank_state_specs(self.cfg, self.mesh, b,
                                           replicated=repl))
        if not self._mixed:
            self.bank = self.banks[0]

    def _sync_tbl(self):
        """Push the block-table mirror to the device cache tree if the host
        allocator changed it since the last jitted call."""
        if self._paged and self._tbl_dirty:
            tbl = jnp.asarray(self._tbl)
            if self.mesh is not None:
                # commit to the table's canonical placement so the jitted
                # steps see ONE input-sharding signature whether the tick's
                # table came from the host mirror or the previous step
                from jax.sharding import NamedSharding
                tbl = jax.device_put(tbl, NamedSharding(
                    self.mesh, self._cache_specs["block_tbl"]))
            self.caches = dict(self.caches, block_tbl=tbl)
            self._tbl_dirty = False

    def _prefill_request(self, req: Request, slots: List[int]) -> np.ndarray:
        """Masked single-client prefill into the assigned slots.

        Returns the [B, V] logits of the prompt's last position per row."""
        c = req.client_id
        B, S = req.prompt.shape
        if self.bank_prefill:
            return self._prefill_request_bankwide(req, slots)
        S_pad = self._bucket(S)
        toks = np.zeros((self.max_b, S_pad), np.int32)
        toks[slots, :S] = req.prompt
        mask = np.zeros((self.max_b,), bool)
        mask[slots] = True
        # zero length on non-admitted rows: their logits/pos are discarded by
        # the slot-mask merge anyway, and under paging a zero length is what
        # keeps the masked prefill's scatter off other slots' live pages
        lengths = np.where(mask, S, 0).astype(np.int32)
        self._sync_tbl()
        m = int(self._method_of[c])
        with self._mesh_ctx():
            logits, self.caches = tracecount.dispatch(
                self, "prefill", (m, S_pad), self._prefill_one[m],
                self.base, self.banks[m], self.caches, np.int32(c),
                np.int32(self._local_of[c]),
                jnp.asarray(toks), jnp.asarray(lengths), jnp.asarray(mask))
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += B * S
        return np.asarray(logits)[slots]

    def _prefill_request_bankwide(self, req: Request, slots: List[int]) -> np.ndarray:
        """Seed-engine ablation: pad the request into a bank-wide [C, max_b,
        S] prefill (C× the base compute of the masked path) and replace the
        whole client cache slice."""
        c = req.client_id
        B, S = req.prompt.shape
        toks = np.zeros((self.n_clients, self.max_b, S), np.int32)
        toks[c, slots] = req.prompt
        with self._mesh_ctx():
            logits, new_caches = tracecount.dispatch(
                self, "bank_prefill", (S,), self._prefill_bank,
                self.base, self.bank, self.caches, {"tokens": jnp.asarray(toks)})
        sel = np.zeros((self.n_clients,), bool)
        sel[c] = True
        sel = jnp.asarray(sel)

        def merge(old, new):
            return jnp.where(sel.reshape((self.n_clients,) + (1,) * (old.ndim - 1)),
                             new, old)

        self.caches = jax.tree.map(merge, self.caches, new_caches)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += B * S
        return np.asarray(logits)[c, slots]

    # ------------------------------------------------------------------
    # decode + sampling
    # ------------------------------------------------------------------
    def _grow_slot_pages(self, req: Request, c: int, s: int):
        """Assign the next page when this tick's token write crosses a block
        boundary (reservation guarantees the pool can serve it)."""
        w = int(self._wpos[c, s])
        bi = w // self._blk
        pages = self._slot_pages[(c, s)]
        # coverage = ref-held shared prefix pages (block-table front) plus
        # exclusive pages; growth pages are always exclusive — a decode
        # write never lands on a shared page (its block is already full)
        covered = len(self._slot_shared.get((c, s), ())) + len(pages)
        if bi >= covered:
            page = self._free_pages[c].pop()
            pages.append(page)
            self._tbl[c, s, bi] = page
            self._reserved[c] -= 1
            self._resv_of[id(req)] -= 1
            self._tbl_dirty = True
        self._wpos[c, s] = w + 1

    def _row_bucket(self, n: int) -> int:
        """Smallest jit bucket holding n active rows (bounds recompiles)."""
        for b in self._buckets:
            if b >= n:
                return b
        return self._buckets[-1]

    def _decode_tick(self, serve: set, inflight: List[Request]):
        stepping = [r for r in inflight
                    if r.client_id in serve and self._left[id(r)] > 0]
        with self._span("compact_gather"):
            for req in stepping:
                if self._paged:
                    for s in self._slots_of[id(req)]:
                        self._grow_slot_pages(req, req.client_id, s)
            self._sync_tbl()
        if self._compact:
            lookup, finite_of = self._decode_tick_compact(serve)
        else:
            # masked bank-wide step: compose this tick's mask from the
            # incrementally maintained activity mask (admit/retire updates)
            # and the policy's serving set — O(C) per tick, not O(inflight)
            serve_sel = np.zeros((self.n_clients, 1), bool)
            serve_sel[sorted(serve)] = True
            active = self._active_mask & serve_sel
            with self._span("jit_dispatch"), self._mesh_ctx():
                logits, self.caches = tracecount.dispatch(
                    self, "decode", (), self._decode,
                    self.base, self.bank, self.caches,
                    jnp.asarray(self._last_tok), jnp.asarray(active))
            with self._span("device_sync"):
                lg = np.asarray(logits)
            lookup = lambda c, slots: lg[c, slots]
            finite_of = lambda c, slots: bool(np.isfinite(lg[c, slots]).all())
        with self._span("scatter"):
            obs = self._obs
            # ONE host timestamp after the decode's logits landed: every
            # stepping request's inter-token sample this tick shares it
            # (tick-boundary granularity, no per-request syncs)
            t_now = time.perf_counter() if obs is not None else 0.0
            for req in stepping:
                if self._left[id(req)] <= 0:
                    continue          # its client was quarantined mid-tick
                c, slots = req.client_id, self._slots_of[id(req)]
                if not finite_of(c, slots):
                    self._quarantine_request(req, "non-finite decode logits")
                    continue
                nxt = self._sample(lookup(c, slots), req)
                pos = req.max_new_tokens - self._left[id(req)]
                req.generated[:, pos] = nxt
                self._last_tok[c, slots] = nxt
                self._left[id(req)] -= 1
                self.stats["decode_tokens"] += len(slots)
                if obs is not None:
                    obs.metrics.counter("serve_decode_tokens_total",
                                        client=c).inc(len(slots))
                    last = self._last_tok_t.get(id(req))
                    if last is not None:
                        obs.metrics.histogram("serve_intertoken_seconds",
                                              client=c).observe(t_now - last)
                    self._last_tok_t[id(req)] = t_now
        self.stats["ticks"] += 1
        self.stats["batched_clients"] += len(serve)

    def _decode_tick_compact(self, serve: set):
        """Compute-proportional tick: gather the serving clients' active
        (client, slot) rows into a bucketed dense batch, decode only those
        rows, and return a logits lookup for the sampler. The jitted step
        scatters cache writes back under the row mask (symbiosis.
        make_compact_decode_step); outputs are byte-identical to the masked
        bank-wide step — the bucket's padding rows are masked out of every
        write and their logits never read. The step is compiled with
        ``probe=True``, so a per-row finite flag rides along for free;
        returns ``(logits lookup, finite lookup)`` for the sampler."""
        with self._span("compact_gather"):
            rows = [(c, s) for c in sorted(serve)
                    for s in self._active_slots[c]]
            n = len(rows)
            nb = self._row_bucket(n)
            clients = np.zeros((nb,), np.int32)
            slots = np.zeros((nb,), np.int32)
            mask = np.zeros((nb,), bool)
            for i, (c, s) in enumerate(rows):
                clients[i], slots[i], mask[i] = c, s, True
            toks = self._last_tok[clients, slots]
        if self._mixed:
            # per-row method ids + bank-local adapter indices: one tick
            # carries every bank's rows through the mixed compact step
            with self._span("jit_dispatch"), self._mesh_ctx():
                logits, finite, self.caches = tracecount.dispatch(
                    self, "compact_decode", nb, self._compact_step,
                    self.base, tuple(self.banks), self.caches,
                    jnp.asarray(toks),
                    jnp.asarray(clients), jnp.asarray(slots),
                    jnp.asarray(self._method_of[clients]),
                    jnp.asarray(self._local_of[clients]), jnp.asarray(mask))
        else:
            with self._span("jit_dispatch"), self._mesh_ctx():
                logits, finite, self.caches = tracecount.dispatch(
                    self, "compact_decode", nb, self._compact_step,
                    self.base, self.bank, self.caches, jnp.asarray(toks),
                    jnp.asarray(clients), jnp.asarray(slots),
                    jnp.asarray(mask))
        with self._span("device_sync"):
            lg = np.asarray(logits)
            fin = np.asarray(finite)
        row_of = {cs: i for i, cs in enumerate(rows)}
        self.stats["compact_rows"] += n
        self.stats["compact_padded"] += nb - n
        return (lambda c, ss: lg[[row_of[(c, s)] for s in ss]],
                lambda c, ss: bool(fin[[row_of[(c, s)] for s in ss]].all()))

    def _sample(self, logits: np.ndarray, req: Request) -> np.ndarray:
        """logits [rows, V] -> next token per row, via the request's RNG."""
        sp = req.sampling
        if sp is None or sp.method == "greedy":
            return np.argmax(logits, axis=-1).astype(np.int32)
        if sp.method not in ("temperature", "top_k"):
            raise ValueError(f"unknown sampling method {sp.method!r}")
        z = logits.astype(np.float64) / max(sp.temperature, 1e-6)
        k = min(sp.top_k, z.shape[-1])          # top_k > vocab = no truncation
        if sp.method == "top_k" and k > 0:
            kth = np.partition(z, -k, axis=-1)[:, -k][:, None]
            z = np.where(z < kth, -np.inf, z)
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        rng = self._rng[id(req)]
        return np.array([rng.choice(p.shape[-1], p=row) for row in p], np.int32)

    # ------------------------------------------------------------------
    # fault containment (docs/robustness.md)
    # ------------------------------------------------------------------
    def _quarantine_request(self, req: Request, reason: str):
        """Terminate a faulty in-flight request: remaining budget zeroed so
        this tick's retire loop frees its slots, pages and router charge
        through the one normal path. Repeated faults quarantine the client."""
        req.status = "quarantined"
        req.fault_history.append((self._tick, "quarantine", reason))
        self._left[id(req)] = 0
        self.stats["quarantined_requests"] += 1
        if self._obs is not None:
            self._obs.event("quarantine", engine="serving", tick=self._tick,
                            tenant=req.client_id, scope="request",
                            reason=reason)
        self._fault_client(req.client_id, reason)

    def _fault_client(self, c: int, reason: str):
        """Record a fault against a client; quarantine the whole client once
        ``HealthPolicy.client_quarantine_after`` faults accumulate."""
        self.stats["faults"] += 1
        rec = self._client_health.setdefault(c, HealthRecord())
        rec.total_faults += 1
        if rec.state is not HealthState.QUARANTINED:
            rec.state = HealthState.SUSPECT
            rec.history.append((self._tick, "suspect", reason))
            if self._obs is not None:
                self._obs.event("health", engine="serving", tick=self._tick,
                                tenant=c, state="suspect", reason=reason)
        if (c not in self._quarantined_clients and rec.total_faults
                >= self.health_policy.client_quarantine_after):
            self._quarantine_client(c)

    def _quarantine_client(self, c: int):
        """Fence a client off: refuse new submits, reject its queued/waiting
        requests, and terminate its in-flight ones (resources free through
        the normal retire path). Other clients' state is untouched — their
        streams stay bitwise identical to a run without the faulty tenant."""
        if c in self._quarantined_clients:
            return
        self._quarantined_clients.add(c)
        self.stats["quarantined_clients"] += 1
        rec = self._client_health.setdefault(c, HealthRecord())
        if rec.state is not HealthState.QUARANTINED:
            rec.state = HealthState.QUARANTINED
            rec.history.append((self._tick, "quarantined",
                                f"{rec.total_faults} fault(s)"))
        if self._obs is not None:
            self._obs.event("quarantine", engine="serving", tick=self._tick,
                            tenant=c, scope="client",
                            faults=rec.total_faults)
        for pool in (self._queue, self._waiting):
            for r in [r for r in pool if r.client_id == c]:
                pool.remove(r)
                r.status = "rejected"
                r.fault_history.append(
                    (self._tick, "rejected", "client quarantined"))
                self._done.append(r)
                self.stats["rejected_requests"] += 1
                if self._obs is not None:
                    self._obs.event("reject", engine="serving",
                                    tick=self._tick, tenant=c,
                                    reason="client quarantined")
        for r in self._inflight:
            if r.client_id == c and self._left.get(id(r), 0) > 0:
                r.status = "quarantined"
                r.fault_history.append(
                    (self._tick, "quarantine", "client quarantined"))
                self._left[id(r)] = 0
                self.stats["quarantined_requests"] += 1

    def _retire(self, req: Request):
        req.finish_t = time.perf_counter()
        c = req.client_id
        for s in self._slots_of.pop(id(req)):
            self._slot_owner[c][s] = None
            if self._active_mask[c, s]:       # never set for max_new == 1
                self._active_mask[c, s] = False
                self._active_slots[c].remove(s)
            if self._paged:
                # pages (and any unused reservation) return to the pool for
                # the next admit; the table rows are remapped at admission,
                # so stale entries can never be read through. Shared-prefix
                # pages RELEASE A REFERENCE instead of freeing: the page
                # recycles only when the last holder retires, and the
                # slot's tail-page index entries die with it (the tail page
                # itself is exclusive and frees normally)
                self._free_pages[c].extend(self._slot_pages.pop((c, s)))
                if self._share_prefix:
                    self._prefix_index.drop_tail((c, s))
                    for p in self._slot_shared.pop((c, s), []):
                        if self._prefix_index.deref(p):
                            self._free_pages[p // self._pool_pages].append(p)
                else:
                    self._slot_shared.pop((c, s), None)
                self._prefill_start.pop((c, s), None)
                self._wpos[c, s] = 0
        if self._paged:
            self._reserved[c] -= self._resv_of.pop(id(req), 0)
        del self._left[id(req)]
        self._rng.pop(id(req), None)
        placement = self._placement.pop(id(req), None)
        if placement is not None:
            self.router.release(placement)
        if self._obs is not None:
            self._last_tok_t.pop(id(req), None)
            m = self._obs.metrics
            m.histogram("serve_e2e_seconds", client=c).observe(
                req.finish_t - req.submit_t)
            if self._paged:
                m.gauge("serve_pages_free", client=c).set(
                    len(self._free_pages[c]) - self._reserved[c])
            if self.router is not None:
                u = self.router.utilization()
                m.gauge("router_placements").set(u["placements"])
                m.gauge("router_committed_bytes").set(u["committed_bytes"])
            self._obs.event(
                "retire", engine="serving", tick=self._tick, tenant=c,
                status=req.status,
                tokens=(0 if req.generated is None
                        else int(req.generated.size)))

    def release_banks(self):
        """Release the per-bank adapter-HBM charges committed at
        construction (mixed-method engines with a router attached)."""
        for p in self._bank_placements:
            self.router.release(p)
        self._bank_placements = []

    # ------------------------------------------------------------------
    # engine-level crash recovery (docs/robustness.md)
    # ------------------------------------------------------------------
    def _req_record(self, req: Request) -> dict:
        sp = req.sampling
        return {"client_id": req.client_id,
                "prompt": (None if req.prompt is None
                           else np.asarray(req.prompt)),
                "prompt_stream": req.prompt_stream,   # picklable by contract
                "max_new_tokens": req.max_new_tokens,
                "latency_sensitive": req.latency_sensitive,
                "sampling": None if sp is None else dataclasses.asdict(sp),
                "arrive_tick": req.arrive_tick,
                "generated": (None if req.generated is None
                              else np.asarray(req.generated)),
                "status": req.status,
                "fault_history": list(req.fault_history),
                "left": self._left.get(id(req)),
                "slots": self._slots_of.get(id(req)),
                "resv": self._resv_of.get(id(req)) if self._paged else None,
                "rng": (self._rng[id(req)].bit_generator.state
                        if id(req) in self._rng else None),
                "placed": id(req) in self._placement,
                "placement": self._placement.get(id(req))}

    def engine_state(self) -> dict:
        """Whole-engine host+device snapshot for crash recovery: every
        request (with its per-request RNG cursor, slot list, reservation
        and router placement), the page allocator, caches/banks as numpy,
        health records and stats. Restoring into a FRESHLY constructed
        identical engine (``load_engine_state``) resumes every tenant
        bitwise — asserted by the kill/restore tests. Single-device only;
        dynamically admitted banks (``admit_bank``) are not captured."""
        if self.mesh is not None:
            raise NotImplementedError("engine_state: single-device engines "
                                      "only (mesh=None)")
        state = {
            "inflight": [self._req_record(r) for r in self._inflight],
            "waiting": [self._req_record(r) for r in self._waiting],
            "queue": [self._req_record(r) for r in self._queue],
            "done": [self._req_record(r) for r in self._done],
            "caches": jax.tree.map(np.asarray, jax.device_get(self.caches)),
            "banks": [jax.tree.map(np.asarray, jax.device_get(b))
                      for b in self.banks],
            "last_tok": self._last_tok.copy(),
            "tick": self._tick,
            "stats": dict(self.stats),
            "client_health": dict(self._client_health),
            "quarantined_clients": set(self._quarantined_clients),
            "dead_clients": set(self._dead_clients),
        }
        if self._paged:
            state["alloc"] = {
                "free_pages": [list(x) for x in self._free_pages],
                "reserved": list(self._reserved),
                "wpos": self._wpos.copy(),
                "tbl": self._tbl.copy(),
                "slot_pages": {k: list(v)
                               for k, v in self._slot_pages.items()},
                "slot_shared": {k: list(v)
                                for k, v in self._slot_shared.items()},
                "prefix_index": self._prefix_index.state(),
            }
        return state

    def load_engine_state(self, state: dict):
        """Inverse of ``engine_state`` into a freshly constructed engine
        (same spec/base/banks/router capacities as the original — router
        placements are RE-COMMITTED here, so pass a fresh router, not the
        crashed engine's live one)."""
        if self.mesh is not None:
            raise NotImplementedError("load_engine_state: single-device "
                                      "engines only (mesh=None)")
        if self._inflight or self._waiting or self._queue or self._done:
            raise RuntimeError("load_engine_state needs a freshly "
                               "constructed engine")
        if len(state["banks"]) != len(self.banks):
            raise RuntimeError(f"checkpoint holds {len(state['banks'])} "
                               f"banks, engine has {len(self.banks)} "
                               "(admit_bank growth is not captured)")

        def mk(rec: dict) -> Request:
            sp = rec["sampling"]
            req = Request(client_id=rec["client_id"], prompt=rec["prompt"],
                          max_new_tokens=rec["max_new_tokens"],
                          latency_sensitive=rec["latency_sensitive"],
                          sampling=(None if sp is None
                                    else SamplingParams(**sp)),
                          arrive_tick=rec["arrive_tick"],
                          prompt_stream=rec.get("prompt_stream"))
            req.generated = rec["generated"]
            req.status = rec["status"]
            req.fault_history = list(rec.get("fault_history", []))
            if rec["left"] is not None:
                self._left[id(req)] = rec["left"]
            if rec["slots"] is not None:
                slots = list(rec["slots"])
                c = req.client_id
                self._slots_of[id(req)] = slots
                for s in slots:
                    self._slot_owner[c][s] = req
                if rec["left"]:
                    self._active_mask[c, slots] = True
                    self._active_slots[c] = sorted(self._active_slots[c]
                                                   + slots)
            if rec["rng"] is not None:
                rng = np.random.default_rng()
                rng.bit_generator.state = rec["rng"]
                self._rng[id(req)] = rng
            if self._paged and rec["resv"] is not None:
                self._resv_of[id(req)] = rec["resv"]
            if rec["placed"]:
                p = rec["placement"]
                self._placement[id(req)] = p
                if p is not None and self.router is not None:
                    self.router.commit(p)
            return req

        self._inflight = [mk(r) for r in state["inflight"]]
        self._waiting = deque(mk(r) for r in state["waiting"])
        self._queue = [mk(r) for r in state["queue"]]
        self._done = [mk(r) for r in state["done"]]
        self.caches = jax.tree.map(jnp.asarray, state["caches"])
        self.banks = [jax.tree.map(jnp.asarray, b) for b in state["banks"]]
        if not self._mixed:
            self.bank = self.banks[0]
        self._last_tok = state["last_tok"].copy()
        self._tick = state["tick"]
        self.stats.update(state["stats"])
        self._client_health = dict(state["client_health"])
        self._quarantined_clients = set(state["quarantined_clients"])
        self._dead_clients = set(state["dead_clients"])
        if self._paged:
            a = state["alloc"]
            self._free_pages = [list(x) for x in a["free_pages"]]
            self._reserved = list(a["reserved"])
            self._wpos = a["wpos"].copy()
            self._tbl = a["tbl"].copy()
            self._slot_pages = {tuple(k): list(v)
                                for k, v in a["slot_pages"].items()}
            self._slot_shared = {tuple(k): list(v)
                                 for k, v in a.get("slot_shared", {}).items()}
            self._prefix_index = PrefixIndex.from_state(
                a.get("prefix_index", {}))
            self._tbl_dirty = True      # re-push the restored table mirror

    # ------------------------------------------------------------------
    # dynamic bank admission (ROADMAP carry-over: the registry is no
    # longer fixed at construction)
    # ------------------------------------------------------------------
    def admit_bank(self, acfg, client_bank) -> BankAdmission:
        """Admit a bank of clients while the engine is live.

        ``acfg`` matching an existing bank GROWS that bank's client axis;
        a new ``acfg`` registers a new bank (a single-method engine grows
        into the mixed registry: the masked bank-wide decode can't carry
        two methods, so the compacted per-row-method tick becomes the only
        decode path). New clients take the global ids after the current
        ones; the global flat pool appends exactly their page ranges, so
        ``[c*P, (c+1)*P)`` stays the ownership rule and no existing page
        id, table entry or in-flight request moves. The attached router is
        charged the bank's resident adapter bytes HERE (``route_bank``) —
        admission backpressure happens before any state grows — and the
        charge is released by ``retire_bank``. Requires the paged layout +
        compacted decode. The jit keys this creates (grown row buckets,
        the new bank's prefill) are re-declared through ``trace_domain()``
        and a new ``_trace_epoch``, so the analysis bucket-coverage pass
        treats post-growth compiles as legal."""
        if not (self._paged and self._compact):
            raise ValueError("dynamic bank admission requires the paged KV "
                             "layout + compacted decode")
        if self.bank_prefill:
            raise ValueError("bank_prefill is a fixed-registry ablation")
        k = jax.tree.leaves(client_bank)[0].shape[0]
        placement = None
        if self.router is not None:
            _, nbytes = adapters_lib.adapter_bytes(self.cfg, acfg)
            placement = self.router.route_bank(nbytes * k)  # raises: no fit
        old_C = self.n_clients
        if acfg in self.bank_cfgs:
            m = self.bank_cfgs.index(acfg)
            old_local = jax.tree.leaves(self.banks[m])[0].shape[0]
            self.banks[m] = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b.astype(a.dtype)]),
                self.banks[m], client_bank)
            if not self._mixed:
                self.bank = self.banks[m]
            locs = np.arange(old_local, old_local + k, dtype=np.int32)
        else:
            if not self._mixed:
                self._mixed = True
                self._decode = None
                self.bank = None
            m = len(self.banks)
            self.bank_cfgs = self.bank_cfgs + (acfg,)
            self.banks.append(client_bank)
            self._bank_repl = self._bank_repl + (False,)
            self._prefill_one.append(
                _jit_client_prefill(self.cfg, acfg, self.scfg, self.mesh))
            locs = np.arange(k, dtype=np.int32)
        if self._mixed:
            self._compact_step = _jit_compact_decode(
                self.cfg, self.bank_cfgs, self.scfg, self.mesh, probe=True)
        self._method_of = np.concatenate(
            [self._method_of, np.full((k,), m, np.int32)])
        self._local_of = np.concatenate([self._local_of, locs])
        self.n_clients = old_C + k

        # grow the device caches: per-client leaves concat along the leading
        # client axis, pool leaves along the global page axis — the appended
        # pages ARE the new clients' ranges
        cache_kw = symbiosis.serve_cache_kwargs(self.cfg, self.scfg)
        cache_kw["pool_pages"] = self._pool_pages
        fresh = symbiosis.init_client_caches(
            self.cfg, k, self.max_b, self.scfg.max_seq, **cache_kw)
        page_axes = symbiosis.cache_page_axes(
            self.cfg, self.scfg.max_seq, **cache_kw)
        self.caches = jax.tree.map(
            lambda old, new, pax: jnp.concatenate(
                [old, new.astype(old.dtype)], axis=0 if pax is None else pax),
            self.caches, fresh, page_axes)

        # allocator + slot bookkeeping for the new clients
        self._free_pages.extend(
            [list(range(c * self._pool_pages, (c + 1) * self._pool_pages))
             for c in range(old_C, self.n_clients)])
        self._reserved.extend([0] * k)
        self._wpos = np.concatenate(
            [self._wpos, np.zeros((k, self.max_b), np.int64)])
        self._tbl = np.concatenate(
            [self._tbl, np.full((k, self.max_b, self._n_blocks),
                                self._tbl_oob, np.int32)])
        self._tbl_dirty = True
        self._slot_owner.extend([[None] * self.max_b for _ in range(k)])
        self._last_tok = np.concatenate(
            [self._last_tok, np.zeros((k, self.max_b), np.int32)])
        self._active_mask = np.concatenate(
            [self._active_mask, np.zeros((k, self.max_b), bool)])
        self._active_slots.extend([[] for _ in range(k)])

        total_rows = self.n_clients * self.max_b
        self._buckets = []
        b = 4
        while b < total_rows:
            self._buckets.append(b)
            b *= 2
        self._buckets.append(total_rows)
        self._place_on_mesh()       # grown caches + banks take their specs
        self._trace_epoch += 1
        if self._obs is not None:
            self._obs.event("bank_growth", engine="serving", tick=self._tick,
                            bank=m, clients=k, method=acfg.method)
        return BankAdmission(bank_id=m,
                             client_ids=list(range(old_C, self.n_clients)),
                             placement=placement)

    def retire_bank(self, admission: BankAdmission):
        """Retire a dynamically admitted bank: its clients stop accepting
        requests and the ``route_bank`` charge taken at ``admit_bank`` is
        released. Clients must be idle (nothing in flight). Their adapter
        rows, cache slots and pages stay allocated as dead capacity — global
        ids never move, so live clients are untouched."""
        busy = [c for c in admission.client_ids
                if any(o is not None for o in self._slot_owner[c])]
        if busy:
            raise RuntimeError(
                f"bank clients {busy} still have requests in flight")
        self._dead_clients.update(admission.client_ids)
        if admission.placement is not None:
            self.router.release(admission.placement)
            admission.placement = None
        if self._obs is not None:
            self._obs.event("bank_retire", engine="serving", tick=self._tick,
                            bank=admission.bank_id,
                            clients=len(admission.client_ids))

    # ------------------------------------------------------------------
    def trace_domain(self) -> tracecount.TraceDomain:
        """The closed set of legal jit cache keys (analysis 'buckets' pass).

        Computed live so ``admit_bank`` growth re-declares itself: prefill
        compiles (bank, prompt-bucket) pairs — a closed power-of-two set
        for attention families, unbounded for recurrent families which
        prefill at true length by design; the masked decode has one shape;
        compact decode compiles exactly the row buckets; the
        ``bank_prefill`` seed ablation is declared unbounded."""
        d = tracecount.TraceDomain()
        if self.cfg.arch in (DENSE, MOE, VLM):
            sbuckets = set()
            b = 8
            while True:
                sbuckets.add(min(b, self.scfg.max_seq))
                if b >= self.scfg.max_seq:
                    break
                b *= 2
            d.declare("prefill", {(m, s) for m in range(len(self.bank_cfgs))
                                  for s in sbuckets})
        else:
            d.declare("prefill", unbounded=True)
        if self._prefill_bank is not None:
            d.declare("bank_prefill", unbounded=True)
        if self._decode is not None:
            d.declare("decode", {()})
        if self._compact_step is not None:
            d.declare("compact_decode", set(self._buckets))
        if self._compact_prefill:
            # the compacted cross-client prefill compiles (row bucket,
            # suffix bucket, ext bucket) triples — every axis a closed set.
            # ext buckets beyond 0 exist only with shared-prefix reuse on.
            sbuckets = set()
            b = 8
            while True:
                sbuckets.add(min(b, self.scfg.max_seq))
                if b >= self.scfg.max_seq:
                    break
                b *= 2
            ebuckets = {0}
            if self._share_prefix:
                e = 1
                while e < self._n_blocks:
                    ebuckets.add(e)
                    e *= 2
                ebuckets.add(self._n_blocks)
            d.declare("compact_prefill", {(nb, s, e) for nb in self._buckets
                                          for s in sbuckets for e in ebuckets})
            if self._share_prefix:
                d.declare("page_copy", {()})
        return d

    # ------------------------------------------------------------------
    def simulate_policy(self, requests: List[Request], *, policy: str = None,
                        exec_overhead: float = 1e-4, per_token_cost: float = 1e-6,
                        client_side_time: float = 5e-5):
        """Scheduler-simulated timeline for these requests under a policy
        (Tables 4/5 reproduction; real outputs are policy-invariant)."""
        policy = policy or self.policy.name
        clients = [ClientSpec(client_id=r.client_id,
                              n_tokens=int(r.prompt.shape[0]),
                              client_side_time=client_side_time,
                              n_iterations=r.max_new_tokens,
                              latency_sensitive=r.latency_sensitive)
                   for r in requests]
        return simulate(clients, self.cfg.n_layers, policy,
                        exec_overhead, per_token_cost,
                        wait_fraction=self.scfg.wait_fraction)
